#!/usr/bin/env python
"""Policy linter: every compression policy in the repo is well-formed.

Run as a CI step (and as a tier-1 test via ``tests/test_policy.py``) so the
policy surfaces can never silently rot:

1. **Structural checks** on every policy source — each registered arch's
   ``ModelConfig.comp_policy`` default plus any ``.json`` / inline-rule
   arguments passed on the command line:

   * every rule's ``method`` resolves in the compressor registry (including
     downlink channels),
   * every ``pattern`` is a valid regex,
   * exactly ONE rule is a catch-all (``*`` / ``.*`` / empty), and it is the
     LAST rule — so matching is total and no rule is dead by position,
   * attaching a non-trivial elastic :class:`ParticipationSpec` changes no
     per-group operator config (participation is model-wide; group
     resolution must be participation-independent — DESIGN.md §Elasticity).

2. **Coverage checks** (``--no-models`` skips them) — each arch default is
   checked against the arch's actual REDUCED parameter tree via
   ``jax.eval_shape`` (metadata only, no compute): every rule must own at
   least one leaf under first-match semantics, otherwise the pattern has
   rotted against the model code (e.g. a renamed layer) and the policy is
   not doing what it says.

Exit code 0 = clean; 1 = any finding, each printed as ``source: message``.
"""

from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def structural_errors(source: str, policy) -> list:
    """Catch-all discipline (method/regex validity already raised at parse)."""
    errors = []
    catch = [i for i, r in enumerate(policy.rules) if r.is_catch_all]
    if len(catch) != 1:
        errors.append(
            f"{source}: expected exactly one catch-all rule ('*'), found "
            f"{len(catch)} (patterns: {[r.pattern for r in policy.rules]})")
    elif catch[0] != len(policy.rules) - 1:
        errors.append(
            f"{source}: the catch-all rule must be LAST (it is rule "
            f"{catch[0]} of {len(policy.rules)}; later rules are dead)")
    return errors


def elasticity_errors(source: str, policy) -> list:
    """Group resolution is participation-INDEPENDENT (DESIGN.md §Elasticity).

    The elastic spec is model-wide: the one PART_FOLD mask draw covers the
    whole step, so attaching a non-trivial :class:`ParticipationSpec` must
    change NOTHING about how rules resolve to per-group operator configs —
    uplink or downlink, any group count.  A policy that fails here would
    sample different participants per group (biased sums) or leak the spec
    into an lru_cache key mid-round; lint it before it trains.
    """
    from repro.core.participation import ChurnEvent, ParticipationSpec

    probe = policy.replace(participation=ParticipationSpec(
        q=0.5, dropout=0.125, min_workers=2,
        churn=(ChurnEvent(3, 0, "leave"),)))
    errors = []
    for i in range(len(policy.rules)):
        if probe.rule_config(i) != policy.rule_config(i):
            errors.append(
                f"{source}: rule {i} UPLINK config changes when an elastic "
                f"participation spec is attached (got "
                f"{probe.rule_config(i)}, want {policy.rule_config(i)}) — "
                f"participation must stay off per-group configs")
        if probe.rule_down_config(i) != policy.rule_down_config(i):
            errors.append(
                f"{source}: rule {i} DOWNLINK config changes when an elastic "
                f"participation spec is attached — the broadcast is "
                f"replicated determinism, never a sampled sum")
    if probe.participation != ParticipationSpec(
            q=0.5, dropout=0.125, min_workers=2,
            churn=(ChurnEvent(3, 0, "leave"),)):
        errors.append(f"{source}: policy.replace(participation=...) did not "
                      f"round-trip the spec")
    return errors


def load_source(source: str):
    """``(policy, errors)`` from a .json path or an inline rule string."""
    from repro.core.policy import load_policy

    try:
        return load_policy(source), []
    except Exception as e:
        return None, [f"{source}: does not parse ({type(e).__name__}: {e})"]


def coverage_errors(arch: str, policy) -> list:
    """Every rule of an arch default owns >= 1 leaf of the arch's tree."""
    import jax

    from repro.configs import get_config, reduced
    from repro.core.policy import partition_for, tree_paths
    from repro.models import init_model

    cfg = reduced(get_config(arch))
    shapes = jax.eval_shape(
        lambda k: init_model(cfg, k), jax.ShapeDtypeStruct((2,), "uint32"))
    errors = []
    try:
        part = partition_for(policy, shapes)
    except KeyError as e:  # unmatched leaf — impossible with a catch-all
        return [f"{arch}: {e}"]
    owned = set(part.rule_ids)
    for i, rule in enumerate(policy.rules):
        if i not in owned:
            errors.append(
                f"{arch}: rule {i} ({rule.pattern!r} -> {rule.spec.method}) "
                f"matches no parameter leaf (paths: "
                f"{sorted(set(p.rsplit('/', 1)[-1] for p in tree_paths(shapes)))})")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("sources", nargs="*",
                    help="extra policy sources to lint: .json files or "
                         "inline rule strings")
    ap.add_argument("--no-models", action="store_true",
                    help="skip the arch-tree coverage checks (no jax import)")
    args = ap.parse_args(argv)

    from repro.configs import list_archs
    from repro.configs.base import get_config

    errors = []
    for arch in list_archs():
        text = get_config(arch).comp_policy
        if text is None:
            continue
        policy, arch_errs = load_source(text)
        if policy is not None:
            arch_errs += structural_errors(text, policy)
            arch_errs += elasticity_errors(text, policy)
            if not args.no_models and not arch_errs:
                arch_errs += coverage_errors(arch, policy)
        errors += [e.replace(text, f"{arch}.comp_policy", 1) for e in arch_errs]

    for source in args.sources:
        policy, errs = load_source(source)
        errors += errs
        if policy is not None:
            errors += structural_errors(source, policy)
            errors += elasticity_errors(source, policy)

    for e in errors:
        print(e)
    if errors:
        print(f"check_policy: {len(errors)} finding(s)", file=sys.stderr)
        return 1
    print("check_policy: all policies parse, resolve and cover their models")
    return 0


if __name__ == "__main__":
    sys.exit(main())
