#!/usr/bin/env python
"""Chunk-schedule linter: every registry operator's chunked wire route is
alive, oracle-checked, and the pipelined round really overlaps.

Run as a CI step (and from ``tests/test_schedule.py``, mirroring
``tools/check_kernels.py``) so the chunked-wire contract of DESIGN.md
§Topology can never silently rot:

1. **Route**: every canonical operator (and every alias) resolves
   ``compress_bucketed_keys`` — the chunk-sliced key entry point the
   :class:`~repro.core.bucket.ChunkedSchedule` round drives — and a
   multi-chunk compress -> wire round trip -> decode actually runs.

2. **Oracle**: the concatenated per-chunk decode is BITWISE the monolithic
   decode of the same buffer under the same key (the bitwise-equality
   linchpin: chunk keys are slices of the monolithic per-leaf schedule,
   never re-splits).

3. **Overlap**: counted on the traced jaxpr of a >= 3-chunk round: chunk 1's
   all-gather eqn is ISSUED before the first eqn that combines chunk 0's
   gathered payload with the server memory (chunk 0's ``decode_sum_apply``)
   — the async-collective double-buffer contract.  Exposed as
   :func:`overlap_report` for the CI smoke step and the test suite.

Exit code 0 = clean; 1 = any finding, each printed as ``operator: message``.
"""

from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

# method -> config kwargs that make it constructible (sparse operators need k)
METHOD_KW = {"randk": dict(k=4), "topk_ef": dict(k=4),
             "rand-k": dict(k=4), "top-k-ef": dict(k=4)}

# Leaves sized so chunk_bytes=300 packs them into >= 3 whole-leaf chunks and
# no single leaf's flat size collides with the padded buffer size (the
# overlap check identifies h_server by its (Dp,) f32 aval).
_PARAMS_SPEC = {"w1": (20, 13), "b1": (160,), "w2": (9, 31), "b2": (70,)}
CHUNK_BYTES = 300


def _params():
    import jax.numpy as jnp

    return {k: jnp.zeros(s, jnp.float32) for k, s in _PARAMS_SPEC.items()}


def _grid_tree(key):
    """1/64-grid values: partial sums exact in f32, so bitwise comparisons
    are meaningful for every operator (tests/test_convergence_laws.py)."""
    import jax
    import jax.numpy as jnp

    return {
        k: jnp.round(jax.random.normal(jax.random.fold_in(key, i), s) * 64) / 64
        for i, (k, s) in enumerate(_PARAMS_SPEC.items())
    }


def chunk_route_errors(method: str) -> list:
    """The chunked route is reachable and its decode matches the monolithic
    oracle bitwise (wire round trip included)."""
    import jax
    import numpy as np

    from repro.core.bucket import (ChunkedSchedule, bucketed_compressor,
                                   wire_roundtrip)
    from repro.core.diana import _chunk_decode_own, _chunk_payloads, bucket_layout
    from repro.core.policy import CompressionConfig

    try:
        cfg = CompressionConfig(method=method, bucketed=True,
                                **METHOD_KW.get(method, {}))
    except Exception as e:
        return [f"{method}: bucketed config does not construct "
                f"({type(e).__name__}: {e})"]

    comp = cfg.make()
    if not callable(getattr(comp, "compress_bucketed_keys", None)):
        return [f"{method}: no compress_bucketed_keys — the chunked route "
                f"(ChunkedSchedule key slicing) is unreachable"]

    lay = bucket_layout(cfg, _params())
    sched = ChunkedSchedule.for_layout(lay, CHUNK_BYTES)
    errors = []
    if sched.n_chunks < 3:
        errors.append(f"{method}: lint fixture packs into only "
                      f"{sched.n_chunks} chunk(s) — widen _PARAMS_SPEC")

    key = jax.random.PRNGKey(3)
    delta = lay.flatten(_grid_tree(key))
    bcomp = bucketed_compressor(cfg, lay)
    try:
        mono = bcomp.decode(bcomp.compress(delta, key), lay.padded_size)
        pays = [wire_roundtrip(p)
                for p in _chunk_payloads(cfg, sched, delta, key)]
        chunked = _chunk_decode_own(cfg, sched, pays)
    except Exception as e:
        return errors + [f"{method}: chunked round trip does not run "
                         f"({type(e).__name__}: {e})"]
    if not np.array_equal(np.asarray(chunked), np.asarray(mono)):
        err = float(np.abs(np.asarray(chunked) - np.asarray(mono)).max())
        errors.append(f"{method}: chunked decode != monolithic oracle "
                      f"(max |err| = {err:g}) — chunk keys must be slices of "
                      f"the monolithic per-leaf schedule")
    return errors


def _iter_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for x in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(x, "jaxpr", None)
                if inner is not None:
                    yield from _iter_jaxprs(inner)
                elif hasattr(x, "eqns"):
                    yield from _iter_jaxprs(x)


def overlap_report(method: str = "diana", chunk_bytes: int = CHUNK_BYTES):
    """(errors, stats) for the double-buffer contract, counted on the jaxpr.

    Finds the jaxpr level holding the per-chunk ``all_gather`` eqns, then the
    first eqn transitively depending on BOTH chunk 0's gathered payload AND
    the ``h_server`` input — the head of chunk 0's ``decode_sum_apply``.  The
    pipelined trace issues chunk 1's gather BEFORE that eqn; a sequential
    gather->decode->gather trace puts it after, which is the finding.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import (CompressionConfig, DianaState, aggregate_shardmap,
                            init_state)
    from repro.core.bucket import ChunkedSchedule
    from repro.core.diana import bucket_layout
    from repro.launch.mesh import make_mesh

    cfg = CompressionConfig(method=method, bucketed=True,
                            chunk_bytes=chunk_bytes,
                            **METHOD_KW.get(method, {}))
    params = _params()
    lay = bucket_layout(cfg, params)
    dp = lay.padded_size
    n_chunks = ChunkedSchedule.for_layout(lay, chunk_bytes).n_chunks
    if n_chunks < 3:
        return ([f"{method}: overlap fixture packs into only {n_chunks} "
                 f"chunk(s)"], {})

    mesh = make_mesh((1, 1), ("data", "model"))
    n = 1
    state = init_state(params, cfg, n)
    grads = {k: jnp.zeros((n,) + v.shape, jnp.float32)
             for k, v in params.items()}

    def body(gs, h_w, h_s, k):
        g_local = jax.tree_util.tree_map(lambda g: g[0], gs)
        ghat, ns = aggregate_shardmap(g_local, DianaState(h_w, h_s), k, cfg,
                                      axis_names=("data",), n_workers=n)
        return ghat, ns.h_worker, ns.h_server

    f = shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P("data"), grads),
                  P("data"), P(), P()),
        out_specs=(jax.tree_util.tree_map(lambda _: P(), params),
                   P("data"), P()),
        axis_names={"data"}, check_vma=False)
    jaxpr = jax.make_jaxpr(f)(grads, state.h_worker, state.h_server,
                              jax.random.PRNGKey(0))

    target = None
    for j in _iter_jaxprs(jaxpr.jaxpr):
        gi = [i for i, e in enumerate(j.eqns)
              if e.primitive.name == "all_gather"]
        if len(gi) >= 2:
            target = (j, gi)
            break
    if target is None:
        return ([f"{method}: no jaxpr level with >= 2 all_gather eqns "
                 f"({n_chunks} chunks expected one gather each)"], {})
    j, gi = target

    errors = []
    if len(gi) != n_chunks:
        errors.append(f"{method}: {len(gi)} all_gather eqns for {n_chunks} "
                      f"chunks — the wire is not one collective per chunk")

    # h_server is the unique (Dp,) f32 input at this jaxpr level.
    h_vars = [v for v in list(j.invars) + list(j.constvars)
              if getattr(v.aval, "shape", None) == (dp,)
              and getattr(v.aval, "dtype", None) == jnp.float32]
    if len(h_vars) != 1:
        return (errors + [f"{method}: cannot identify h_server input "
                          f"({len(h_vars)} candidates of shape ({dp},))"], {})

    def downstream(seed_vars):
        live, idxs = set(seed_vars), set()
        for i, e in enumerate(j.eqns):
            if any(not hasattr(v, "val") and v in live for v in e.invars):
                idxs.add(i)
                live.update(e.outvars)
        return idxs

    joint = sorted(downstream(j.eqns[gi[0]].outvars) & downstream(h_vars))
    stats = {"n_chunks": n_chunks, "gather_eqns": gi,
             "first_decode_apply_eqn": joint[0] if joint else None}
    if not joint:
        return (errors + [f"{method}: no eqn combines chunk 0's gather with "
                          f"h_server — decode_sum_apply not found"], stats)
    stats["gathers_in_flight"] = sum(1 for g in gi[1:] if g < joint[0])
    if stats["gathers_in_flight"] < 1:
        errors.append(
            f"{method}: chunk 1's all_gather (eqn {gi[1]}) is issued AFTER "
            f"chunk 0's decode_sum_apply begins (eqn {joint[0]}) — the "
            f"chunked wire lost its double-buffer pipeline")
    return errors, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the jaxpr overlap check (no tracing, faster)")
    args = ap.parse_args(argv)

    from repro.core.compressors.registry import available_methods

    errors = []
    for method in available_methods():
        errors += chunk_route_errors(method)
    stats = {}
    if not args.no_trace:
        errs, stats = overlap_report()
        errors += errs

    for e in errors:
        print(e)
    if errors:
        print(f"check_schedule: {len(errors)} finding(s)", file=sys.stderr)
        return 1
    msg = (f"check_schedule: all {len(available_methods())} operators keep "
           f"the chunked route reachable and bitwise on the monolithic "
           f"oracle")
    if stats:
        msg += (f"; overlap: {stats['gathers_in_flight']} collective(s) in "
                f"flight when chunk 0's decode_sum_apply begins "
                f"(gathers at eqns {stats['gather_eqns']}, decode head at "
                f"eqn {stats['first_decode_apply_eqn']})")
    print(msg)
    return 0


if __name__ == "__main__":
    sys.exit(main())
