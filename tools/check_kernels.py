#!/usr/bin/env python
"""Kernel-capability linter: every registry operator's kernel surface is
well-formed.

Run as a CI step (and as a tier-1 test via ``tests/test_kernel_coverage.py``,
mirroring ``tools/check_policy.py``) so the kernel contract of DESIGN.md
§Kernels can never silently rot:

1. **Capability**: every canonical operator (and every alias) constructs with
   ``use_kernel=True``, ``use_kernel=False`` and ``use_kernel=None`` (auto),
   and the instance resolves the flag to a plain bool — the auto policy is an
   operator-owned decision, never an unresolved None on the hot path.

2. **Oracle**: every operator names its interpret-mode oracle in
   ``kernel_oracle`` as a ``"module::symbol"`` string that imports and
   resolves to a callable — the pure-jnp function its kernel route is
   bitwise-validated against in CI.

3. **Fallback reachability**: with ``use_kernel=False`` a one-worker
   compress -> decode_sum round trip runs WITHOUT a single ``pallas_call`` in
   the traced jaxpr (counted, not assumed), and with ``use_kernel=True`` the
   same round trip still traces — so both routes of the bitwise-equality
   contract stay alive on every backend.

Exit code 0 = clean; 1 = any finding, each printed as ``operator: message``.
"""

from __future__ import annotations

import argparse
import importlib
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

# method -> config kwargs that make it constructible (sparse operators need k)
METHOD_KW = {"randk": dict(k=4), "topk_ef": dict(k=4),
             "rand-k": dict(k=4), "top-k-ef": dict(k=4)}


def _make(method: str, use_kernel):
    from repro.core.policy import CompressionConfig

    cfg = CompressionConfig(method=method, use_kernel=use_kernel,
                            **METHOD_KW.get(method, {}))
    return cfg.make()


def capability_errors(method: str) -> list:
    errors = []
    for flag in (True, False, None):
        try:
            comp = _make(method, flag)
        except Exception as e:
            errors.append(f"{method}: use_kernel={flag} does not construct "
                          f"({type(e).__name__}: {e})")
            continue
        if not isinstance(comp.use_kernel, bool):
            errors.append(
                f"{method}: use_kernel={flag} resolved to "
                f"{comp.use_kernel!r}, not a bool — the auto policy must "
                f"resolve at construction")
        if flag is not None and comp.use_kernel != flag:
            errors.append(
                f"{method}: explicit use_kernel={flag} was overridden to "
                f"{comp.use_kernel} — explicit opt-in/out must win over auto")
    return errors


def oracle_errors(method: str) -> list:
    comp = _make(method, None)
    oracle = type(comp).kernel_oracle
    if not oracle:
        return [f"{method}: declares no kernel_oracle — every operator must "
                f"name the interpret-mode reference its kernels are "
                f"validated against"]
    if "::" not in oracle:
        return [f"{method}: kernel_oracle {oracle!r} is not 'module::symbol'"]
    mod_name, sym = oracle.split("::", 1)
    try:
        mod = importlib.import_module(mod_name)
    except Exception as e:
        return [f"{method}: kernel_oracle module {mod_name!r} does not "
                f"import ({type(e).__name__}: {e})"]
    fn = getattr(mod, sym, None)
    if not callable(fn):
        return [f"{method}: kernel_oracle symbol {oracle!r} does not resolve "
                f"to a callable"]
    return []


def _count_pallas(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    n += _count_pallas(inner)
    return n


def fallback_errors(method: str) -> list:
    """Trace a one-worker round trip both ways and count pallas launches."""
    import jax
    import jax.numpy as jnp

    errors = []
    d = 256

    def round_trip(comp, g):
        pay = comp.compress(g, jax.random.PRNGKey(0))
        gathered = jax.tree_util.tree_map(lambda x: x[None], pay)
        return comp.decode_sum(gathered, 1, d)

    for flag, want_kernel in ((False, False), (True, None)):
        comp = _make(method, flag)
        try:
            jaxpr = jax.make_jaxpr(
                lambda g: round_trip(comp, g))(jnp.zeros((d,), jnp.float32))
        except Exception as e:
            errors.append(f"{method}: use_kernel={flag} round trip does not "
                          f"trace ({type(e).__name__}: {e})")
            continue
        launches = _count_pallas(jaxpr.jaxpr)
        if want_kernel is False and launches:
            errors.append(
                f"{method}: use_kernel=False round trip still traces "
                f"{launches} pallas_call(s) — the lax fallback is no longer "
                f"reachable")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the jaxpr fallback-reachability checks "
                         "(no tracing, much faster)")
    args = ap.parse_args(argv)

    from repro.core.compressors.registry import available_methods

    errors = []
    for method in available_methods():
        errs = capability_errors(method)
        if not errs:
            errs += oracle_errors(method)
        if not errs and not args.no_trace:
            errs += fallback_errors(method)
        errors += errs

    for e in errors:
        print(e)
    if errors:
        print(f"check_kernels: {len(errors)} finding(s)", file=sys.stderr)
        return 1
    print(f"check_kernels: all {len(available_methods())} operators declare "
          f"use_kernel, name a resolving interpret oracle, and keep the lax "
          f"fallback reachable")
    return 0


if __name__ == "__main__":
    sys.exit(main())
