#!/usr/bin/env python
"""Docs linter: DESIGN.md section citations + docs/paper_map.md references.

Two fast checks, run as a CI step (and as a tier-1 test via
tests/test_docs_map.py), so the documentation map can never silently rot:

1. **DESIGN.md citations** — every ``DESIGN.md §<id>`` string anywhere in the
   repo's Python sources resolves to an actual ``## §<id>`` heading in
   DESIGN.md (sections are cited by the first whitespace-delimited token of
   their heading: ``## §Perf notes`` is citable as ``§Perf``).

2. **paper_map references** — every backticked code reference in
   ``docs/paper_map.md`` resolves:

   * ``path/to/file.py::symbol`` — the file exists; for files under ``src/``
     the module IMPORTS and ``symbol`` (dotted attributes allowed) resolves
     via ``getattr``; for tests/benchmarks the symbol is located textually
     (``def``/``class``) so the linter never triggers test-collection side
     effects.
   * ``path/to/file.py`` or ``path/`` — the path exists.

Exit code 0 = clean; 1 = any unresolved citation/reference, each printed as
``file:line: message``.
"""

from __future__ import annotations

import argparse
import importlib
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# \s+ spans newlines: citations wrapped across docstring lines still match
CITE_RE = re.compile(r"DESIGN\.md\s+§([A-Za-z0-9_-]+)")
HEADING_RE = re.compile(r"^##\s+§(\S+)", re.M)
# backticked code refs in the paper map: `a/b.py::symbol`, `a/b.py`, `a/b/`
REF_RE = re.compile(r"`([\w./-]+?\.py)(?:::([\w.]+))?`|`([\w./-]+/)`")


def design_sections(design_path: str) -> set:
    with open(design_path) as f:
        return set(HEADING_RE.findall(f.read()))


_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules",
              "venv", ".venv", "env", ".env", "site-packages", ".tox",
              ".eggs", "build", "dist"}


def iter_py_files(root: str):
    for base, dirs, files in os.walk(root):
        dirs[:] = [d for d in dirs if d not in _SKIP_DIRS]
        for f in files:
            if f.endswith(".py"):
                yield os.path.join(base, f)


def check_design_citations(repo: str) -> list:
    """Every 'DESIGN.md section' citation in a Python source resolves."""
    errors = []
    sections = design_sections(os.path.join(repo, "DESIGN.md"))
    for path in iter_py_files(repo):
        rel = os.path.relpath(path, repo)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        # whole-text match, not per-line: citations wrap across docstring
        # line breaks ("DESIGN.md\n§Bidirectional")
        for m in CITE_RE.finditer(text):
            sec = m.group(1)
            if sec not in sections:
                lineno = text.count("\n", 0, m.start()) + 1
                errors.append(
                    f"{rel}:{lineno}: cites DESIGN.md §{sec} but "
                    f"DESIGN.md has no '## §{sec}' heading "
                    f"(have: {', '.join(sorted(sections))})")
    return errors


def _symbol_in_source(path: str, symbol: str) -> bool:
    """Textual def/class lookup (used for tests/ and benchmarks/ so the
    linter never imports test modules)."""
    top = symbol.split(".")[0]
    pat = re.compile(rf"^\s*(?:def|class)\s+{re.escape(top)}\b", re.M)
    with open(path, encoding="utf-8") as f:
        return bool(pat.search(f.read()))


def _resolve_import(relpath: str, symbol: str):
    """Import a src/ module and getattr the (possibly dotted) symbol."""
    mod_rel = os.path.splitext(relpath)[0]
    parts = mod_rel.split(os.sep)
    if parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    mod = importlib.import_module(".".join(parts))
    obj = mod
    for attr in symbol.split("."):
        obj = getattr(obj, attr)
    return obj


def check_paper_map(repo: str, map_path: str = "docs/paper_map.md") -> list:
    """Every backticked file/symbol reference in the paper map resolves."""
    errors = []
    full = os.path.join(repo, map_path)
    if not os.path.exists(full):
        return [f"{map_path}: file not found"]
    sys.path.insert(0, os.path.join(repo, "src"))
    try:
        with open(full, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for fpath, symbol, dirpath in REF_RE.findall(line):
                    target = fpath or dirpath
                    where = f"{map_path}:{lineno}"
                    if not os.path.exists(os.path.join(repo, target)):
                        errors.append(f"{where}: path {target!r} does not exist")
                        continue
                    if not symbol:
                        continue
                    if fpath.startswith("src" + os.sep) or fpath.startswith("src/"):
                        try:
                            _resolve_import(fpath, symbol)
                        except Exception as e:  # import or attribute error
                            errors.append(
                                f"{where}: {fpath}::{symbol} does not "
                                f"import/resolve ({type(e).__name__}: {e})")
                    elif not _symbol_in_source(os.path.join(repo, fpath), symbol):
                        errors.append(
                            f"{where}: no def/class {symbol.split('.')[0]!r} "
                            f"in {fpath}")
    finally:
        sys.path.pop(0)
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--repo", default=REPO)
    args = ap.parse_args(argv)
    errors = check_design_citations(args.repo) + check_paper_map(args.repo)
    for e in errors:
        print(e)
    if errors:
        print(f"check_docs: {len(errors)} unresolved reference(s)", file=sys.stderr)
        return 1
    print("check_docs: all DESIGN.md citations and paper_map references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
