"""Checkpoint save/restore tests (bf16, nesting, atomicity, errors)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _tree():
    return {
        "params": {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((2,), jnp.bfloat16) * 1.5,
        },
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.zeros((2, 2)), (jnp.ones(3, jnp.int8),)],
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 42, tree)
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32) if a.dtype == jnp.bfloat16 else np.asarray(a),
                                      np.asarray(b, np.float32) if b.dtype == jnp.bfloat16 else np.asarray(b))


def test_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 5, {"x": jnp.zeros(2)})
    assert latest_step(str(tmp_path)) == 5


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros(2)})


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros(3)})


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros(2), "y": jnp.zeros(1)})


def test_no_tmp_litter(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


# ---------------------------------------------------------------------------
# DianaState round-trips: bucketed layout, the VR slot, the downlink memory
# ---------------------------------------------------------------------------

def _diana_state(bucketed: bool, vr: bool, down: bool = False):
    """A populated (non-zero) DianaState in the requested layout."""
    from repro.core import CompressionConfig, init_state

    params = {"w": jnp.ones((6, 4), jnp.bfloat16) * 0.5, "b": jnp.zeros((10,))}
    cfg = CompressionConfig(method="diana", block_size=16, bucketed=bucketed,
                            vr=vr, vr_p=0.25 if vr else None,
                            down_method="diana" if down else None)
    st = init_state(params, cfg, 3)
    fill = lambda t: jax.tree_util.tree_map(
        lambda x: (jnp.arange(x.size, dtype=jnp.float32)
                   .reshape(x.shape).astype(x.dtype)), t)
    st = st._replace(h_worker=fill(st.h_worker), h_server=fill(st.h_server))
    if vr:
        st = st._replace(vr=st.vr._replace(mu=fill(st.vr.mu)))
    if down:
        st = st._replace(h_down=fill(st.h_down))
    return st


@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
@pytest.mark.parametrize("vr", [False, True], ids=["plain", "vr"])
def test_diana_state_roundtrip(tmp_path, bucketed, vr):
    """The bucketed single-buffer layout and the VR (snapshot, mu) slot
    round-trip exactly — dtypes (incl. the bf16 snapshot leaves), shapes and
    values; with vr off the state carries no vr keys at all."""
    st = _diana_state(bucketed, vr)
    save_checkpoint(str(tmp_path), 11, {"diana": st})
    restored, step = restore_checkpoint(str(tmp_path), {"diana": st})
    assert step == 11
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    import json
    with open(os.path.join(tmp_path, "manifest.json")) as f:
        keys = json.load(f)["keys"]
    assert any("/vr/" in k for k in keys) == vr


def test_pre_vr_checkpoint_into_vr_template_hints(tmp_path):
    """Restoring a vr=False checkpoint into a vr-enabled template fails with
    a KeyError that names the missing vr slot (no silent zero-filling)."""
    save_checkpoint(str(tmp_path), 0, {"diana": _diana_state(True, False)})
    with pytest.raises(KeyError, match="vr"):
        restore_checkpoint(str(tmp_path), {"diana": _diana_state(True, True)})


@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
def test_downlink_state_roundtrip(tmp_path, bucketed):
    """The downlink memory h_down round-trips exactly in both layouts, and a
    downlink-off checkpoint carries no h_down keys at all (byte-identity of
    uplink-only checkpoints to pre-downlink ones)."""
    st = _diana_state(bucketed, vr=False, down=True)
    save_checkpoint(str(tmp_path), 4, {"diana": st})
    restored, step = restore_checkpoint(str(tmp_path), {"diana": st})
    assert step == 4
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    import json
    with open(os.path.join(tmp_path, "manifest.json")) as f:
        keys = json.load(f)["keys"]
    assert any("h_down" in k.split("/") for k in keys)
    save_checkpoint(str(tmp_path), 5, {"diana": _diana_state(bucketed, False)})
    with open(os.path.join(tmp_path, "manifest.json")) as f:
        keys_off = json.load(f)["keys"]
    assert not any("h_down" in k.split("/") for k in keys_off)


def test_pre_downlink_checkpoint_into_downlink_template_hints(tmp_path):
    """Restoring a downlink-off checkpoint into a bidirectional template
    fails with a KeyError naming the missing h_down memory."""
    save_checkpoint(str(tmp_path), 0, {"diana": _diana_state(True, False)})
    with pytest.raises(KeyError, match="h_down"):
        restore_checkpoint(str(tmp_path),
                           {"diana": _diana_state(True, False, down=True)})


# ---------------------------------------------------------------------------
# Elastic state: mid-churn round-trip + participation restore hint
# ---------------------------------------------------------------------------

def _elastic_spec():
    from repro.core import ChurnEvent, ParticipationSpec

    return ParticipationSpec(q=0.5, dropout=0.2, min_workers=2,
                             churn=(ChurnEvent(1, 2, "leave"),
                                    ChurnEvent(3, 2, "join")))


def test_elastic_state_roundtrip_mid_churn(tmp_path):
    """A DianaState saved MID-CHURN (after a worker left, before it
    re-joined) round-trips exactly — the frozen row included — and the
    elastic spec itself rides the manifest metadata via the serialized
    policy, so a restore can rebuild both state and schedule."""
    from repro.core import (CompressionConfig, as_policy, reference_init,
                            reference_step)
    from repro.checkpoint import load_metadata

    spec = _elastic_spec()
    cfg = CompressionConfig(method="diana", block_size=16, bucketed=True,
                            participation=spec)
    params = {"w": jnp.ones((6, 4)) * 0.5, "b": jnp.zeros((10,))}
    key = jax.random.PRNGKey(3)
    state = reference_init(params, cfg, 4)
    for t in range(2):  # worker 2 leaves at step 1: step 1 runs masked
        grads = jax.tree_util.tree_map(
            lambda p: jnp.ones((4,) + p.shape) * 0.25, params)
        _, state = reference_step(grads, state, jax.random.fold_in(key, t),
                                  cfg, step=t)
    policy_doc = as_policy(cfg).to_json_dict()
    save_checkpoint(str(tmp_path), 2, {"diana": state},
                    metadata={"policy": policy_doc})
    restored, step = restore_checkpoint(str(tmp_path), {"diana": state})
    assert step == 2
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the spec survives the manifest round-trip
    from repro.core import CompressionPolicy

    meta = load_metadata(str(tmp_path))
    assert CompressionPolicy.from_json_dict(meta["policy"]).participation == spec
    # ...and the trajectory continues bitwise from the restored state
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones((4,) + p.shape) * 0.25, params)
    v_a, _ = reference_step(grads, state, jax.random.fold_in(key, 2), cfg, step=2)
    v_b, _ = reference_step(grads, restored["diana"], jax.random.fold_in(key, 2),
                            cfg, step=2)
    for a, b in zip(jax.tree_util.tree_leaves(v_a), jax.tree_util.tree_leaves(v_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_participation_restore_hint_on_spec_change(tmp_path):
    """Changing the elastic spec between save and restore cannot be caught
    by state-shape checks (participation adds no leaves), so the dedicated
    hint compares the manifest policy against the restore template's: a
    mismatch names both specs, matching specs (or both-trivial) stay silent."""
    from repro.core import CompressionConfig, ParticipationSpec, as_policy
    from repro.checkpoint import participation_restore_hint

    spec = _elastic_spec()
    cfg = CompressionConfig(method="diana", block_size=16, participation=spec)
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)},
                    metadata={"policy": as_policy(cfg).to_json_dict()})
    # same spec: no hint
    assert participation_restore_hint(str(tmp_path), as_policy(cfg)) is None
    # changed spec: hint names the mismatch
    changed = CompressionConfig(method="diana", block_size=16,
                                participation=ParticipationSpec(q=0.25))
    hint = participation_restore_hint(str(tmp_path), as_policy(changed))
    assert hint is not None and "participation" in hint and "0.25" in hint
    # dropped spec entirely: also hinted
    plain = CompressionConfig(method="diana", block_size=16)
    assert participation_restore_hint(str(tmp_path), as_policy(plain)) is not None
    # pre-elastic checkpoint (no policy metadata) + trivial template: silent
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    assert participation_restore_hint(str(tmp_path), as_policy(plain)) is None
