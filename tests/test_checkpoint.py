"""Checkpoint save/restore tests (bf16, nesting, atomicity, errors)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _tree():
    return {
        "params": {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((2,), jnp.bfloat16) * 1.5,
        },
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.zeros((2, 2)), (jnp.ones(3, jnp.int8),)],
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 42, tree)
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32) if a.dtype == jnp.bfloat16 else np.asarray(a),
                                      np.asarray(b, np.float32) if b.dtype == jnp.bfloat16 else np.asarray(b))


def test_latest_step(tmp_path):
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 5, {"x": jnp.zeros(2)})
    assert latest_step(str(tmp_path)) == 5


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros(2)})


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros(3)})


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros(2)})
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), {"x": jnp.zeros(2), "y": jnp.zeros(1)})


def test_no_tmp_litter(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
