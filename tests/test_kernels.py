"""Pallas kernel validation: shape/dtype/p sweep vs the pure-jnp oracles."""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import quantize_pack, quantize_pack_prng, unpack_reduce
from repro.kernels.ref import ref_quantize_pack, ref_unpack_reduce, uniform_from_bits

KEY = jax.random.PRNGKey(0)


def _bits(key, shape):
    return jax.random.bits(key, shape, dtype=jnp.uint32)


@pytest.mark.parametrize("m", [1, 5, 8, 32])
@pytest.mark.parametrize("b", [128, 256, 2048])
@pytest.mark.parametrize("p", [1.0, 2.0, math.inf])
def test_quantize_pack_matches_ref(m, b, p):
    delta = jax.random.normal(KEY, (m, b)) * 3.0
    bits = _bits(jax.random.PRNGKey(m * b), (m, b))
    pk, sc = quantize_pack(delta, bits, p=p, interpret=True)
    pk_r, sc_r = ref_quantize_pack(delta, bits, p)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pk_r))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_r), rtol=1e-6)


@pytest.mark.parametrize("in_dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_quantize_pack_dtypes(in_dtype):
    delta = (jax.random.normal(KEY, (8, 128))).astype(in_dtype)
    bits = _bits(KEY, (8, 128))
    pk, sc = quantize_pack(delta.astype(jnp.float32), bits, p=2.0, interpret=True)
    pk_r, sc_r = ref_quantize_pack(delta.astype(jnp.float32), bits, 2.0)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pk_r))


def test_quantize_pack_zero_and_extremes():
    delta = jnp.zeros((4, 128))
    bits = _bits(KEY, (4, 128))
    pk, sc = quantize_pack(delta, bits, p=math.inf, interpret=True)
    assert np.all(np.asarray(sc) == 0)
    back = ref_unpack_reduce(pk[None], sc[None, :, :])
    assert np.all(np.asarray(back) == 0)


def test_quantize_pack_rejects_bad_block():
    with pytest.raises(ValueError):
        quantize_pack(jnp.zeros((2, 100)), _bits(KEY, (2, 100)), p=2.0, interpret=True)


@pytest.mark.parametrize("n", [1, 2, 8])
@pytest.mark.parametrize("m,b", [(3, 128), (8, 256), (16, 512)])
def test_unpack_reduce_matches_ref(n, m, b):
    pks, scs = [], []
    for i in range(n):
        delta = jax.random.normal(jax.random.PRNGKey(i), (m, b))
        bits = _bits(jax.random.PRNGKey(100 + i), (m, b))
        pk, sc = quantize_pack(delta, bits, p=2.0, interpret=True)
        pks.append(pk)
        scs.append(sc)
    packed, scales = jnp.stack(pks), jnp.stack(scs)
    out = unpack_reduce(packed, scales, interpret=True)
    out_r = ref_unpack_reduce(packed, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=1e-6)


def test_kernel_distribution_is_unbiased():
    """Kernel-quantized estimates are unbiased like the reference operator."""
    d, b = 512, 128
    x = jax.random.normal(KEY, (4, b))
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(3), n)

    def one(k):
        bits = jax.random.bits(k, x.shape, dtype=jnp.uint32)
        pk, sc = quantize_pack(x, bits, p=math.inf, interpret=True)
        return ref_unpack_reduce(pk[None], sc[None])

    samp = np.asarray(jax.jit(jax.vmap(one))(keys))
    err = np.abs(samp.mean(0) - np.asarray(x)).max()
    assert err < 0.15, err


def test_quantize_pack_prng_wrapper_shapes():
    """The in-kernel-PRNG variant is compiled-TPU-only, but its wrapper
    (padding, grid spec, out shapes) is validated abstractly everywhere."""
    out = jax.eval_shape(
        functools.partial(quantize_pack_prng, p=2.0),
        jax.ShapeDtypeStruct((5, 256), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.int32),
    )
    assert out[0].shape == (5, 64) and out[0].dtype == jnp.uint8
    assert out[1].shape == (5, 1) and out[1].dtype == jnp.float32
    with pytest.raises(ValueError):
        jax.eval_shape(
            functools.partial(quantize_pack_prng, p=2.0),
            jax.ShapeDtypeStruct((5, 100), jnp.float32),  # not lane-aligned
            jax.ShapeDtypeStruct((2,), jnp.int32),
        )


@pytest.mark.skipif(jax.default_backend() != "tpu", reason="compiled Mosaic only")
def test_quantize_pack_prng_unbiased_on_tpu():
    """On a real TPU the in-kernel PRNG must reproduce the operator's
    statistics: unbiased decode, same wire format as the oracle."""
    x = jax.random.normal(KEY, (4, 256))
    n = 2000

    def one(k):
        from repro.kernels.ops import _key_words

        pk, sc = quantize_pack_prng(x, _key_words(k), p=math.inf)
        return ref_unpack_reduce(pk[None], sc[None])

    samp = np.asarray(jax.jit(jax.vmap(one))(jax.random.split(jax.random.PRNGKey(5), n)))
    assert np.abs(samp.mean(0) - np.asarray(x)).max() < 0.2


def test_uniform_from_bits_range():
    bits = _bits(KEY, (10_000,))
    u = np.asarray(uniform_from_bits(bits))
    assert u.min() >= 0.0 and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.02
