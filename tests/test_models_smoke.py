"""Per-architecture smoke tests (assignment requirement): a REDUCED variant of
each family (<= 2 pattern periods, d_model <= 512, <= 4 experts) runs one
forward/train step on CPU with asserted output shapes and no NaNs, plus
decode-vs-prefill parity for one arch per mixer family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import (
    count_params,
    decode_step,
    forward,
    init_caches,
    init_model,
    train_loss,
)
from repro.models.transformer import FRONTEND_DIM

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.frontend in FRONTEND_DIM:
        k = "vision_embeds" if cfg.frontend == "vision" else "audio_embeds"
        batch[k] = jax.random.normal(KEY, (b, cfg.frontend_tokens, FRONTEND_DIM[cfg.frontend]))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = init_model(cfg, KEY)
    assert count_params(params) > 0
    batch = make_batch(cfg)
    logits, aux, _ = forward(params, batch, cfg)
    s_total = 32 + (cfg.frontend_tokens if cfg.frontend != "none" else 0)
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    loss, grads = jax.value_and_grad(lambda p: train_loss(p, batch, cfg))(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad at {path}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = init_model(cfg, KEY)
    caches = init_caches(cfg, 2, 64)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    logits, caches = decode_step(params, tok, caches, cfg)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    logits2, _ = decode_step(params, tok, caches, cfg)
    assert not bool(jnp.isnan(logits2).any())


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-130m", "jamba-v0.1-52b"])
def test_prefill_decode_parity(arch):
    """Chunked/parallel train path == step-by-step decode (per mixer family)."""
    cfg = reduced(get_config(arch))
    params = init_model(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    full_logits, _, _ = forward(params, {"tokens": tokens}, cfg)
    caches = init_caches(cfg, 2, 16)
    outs = []
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    for t in range(16):
        lg, caches = step(params, tokens[:, t : t + 1], caches)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), atol=2e-4)


def test_sliding_window_parity():
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_model(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    w = 6
    full_logits, _, _ = forward(params, {"tokens": tokens}, cfg, window=w)
    caches = init_caches(cfg, 2, 16, window=w)
    outs = []
    for t in range(16):
        lg, caches = decode_step(params, tokens[:, t : t + 1], caches, cfg, window=w)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits), atol=2e-4)


def test_moe_load_balance_loss_nonzero():
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    params = init_model(cfg, KEY)
    _, aux, _ = forward(params, make_batch(cfg), cfg)
    assert float(aux) > 0.0


def test_vocab_padding():
    cfg = get_config("granite-moe-3b-a800m")
    assert cfg.padded_vocab % 4096 == 0 and cfg.padded_vocab >= cfg.vocab
