"""Chunked wire schedule + hierarchical topology (ISSUE 8, DESIGN.md §Topology):

* `ChunkedSchedule` packing invariants and the split/concat round trip,
  property-swept over chunk sizes that do NOT divide the buffer (hypothesis
  when installed, a seeded deterministic sweep otherwise);
* chunked reference == monolithic BITWISE for every registry operator, and
  composed with VR, the downlink, and elastic participation;
* the overlap contract, counted on the traced jaxpr (tools/check_schedule's
  counter): chunk 1's all-gather is issued before chunk 0's decode_sum_apply;
* per-chunk checksum tails are counted in the wire accounting;
* a corrupt landing mid-chunk excludes the worker WHOLE — bitwise like a
  churn leave, h rows unperturbed (never a half-applied payload);
* hierarchical topology: node rows exactly duplicated, h_server == mean of
  node memories, node_size=1 degenerates to flat bitwise, and chunked×hier
  == monolithic×hier;
* distributed chunked (and hierarchical) == the reference on a real 4-worker
  mesh (subprocess, like tests/test_distributed.py).
"""

import json
import math
import os
import subprocess
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step
from repro.core.bucket import (
    CHECKSUM_BYTES,
    BucketLayout,
    ChunkedSchedule,
    bucketed_compressor,
    checksum_tail_bits_per_dim,
    fuse_payload,
    wire_roundtrip,
)
from repro.core.diana import _chunk_decode_own, _chunk_payloads, bucket_layout
from repro.core.participation import (
    ChurnEvent,
    FaultEvent,
    FaultPlan,
    ParticipationSpec,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))
import check_schedule  # noqa: E402  (tools/ is not a package)

KEY = jax.random.PRNGKey(0)

# Several smallish leaves: chunk_bytes=300 packs them into >= 3 whole-leaf
# chunks for every operator's alignment, and no chunk boundary divides the
# buffer evenly.
PARAMS = {
    "emb": jnp.zeros((24, 16)),
    "w1": jnp.zeros((20, 13)),
    "b1": jnp.zeros((160,)),
    "w2": jnp.zeros((9, 31)),
    "b2": jnp.zeros((70,)),
    "s": jnp.zeros(()),
}
CHUNK_BYTES = 300

OPERATORS = [
    ("diana", dict(block_size=16)),
    ("natural", {}),
    ("randk", dict(k=9)),
    ("topk_ef", dict(k=9)),
    ("none", {}),
]
OP_IDS = [m for m, _ in OPERATORS]


def _grid(key, shape, scale=64):
    """1/64-grid values: partial sums are exact in f32, so bitwise equality
    is meaningful for every operator including identity's pmean."""
    return jnp.round(jax.random.normal(key, shape) * scale) / scale


def _stacked(n, key, tag=0):
    return {
        k: _grid(jax.random.fold_in(key, tag * 100 + i), (n,) + v.shape)
        for i, (k, v) in enumerate(PARAMS.items())
    }


def _assert_trees_equal(a, b, what=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=what)


def _two_steps(cfg, n=4, key=KEY, faults=None):
    state = reference_init(PARAMS, cfg, n)
    vs = []
    needs_step = faults is not None or (
        cfg.participation is not None and cfg.participation.churn)
    for s in range(2):
        kw = dict(step=s) if needs_step else {}
        if faults is not None:
            kw["faults"] = faults
        v, state = reference_step(_stacked(n, key, tag=s), state,
                                  jax.random.fold_in(key, 1000 + s), cfg, **kw)
        vs.append(v)
    return vs, state


def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


# ---------------------------------------------------------------------------
# Packing invariants: property-swept (hypothesis when installed)
# ---------------------------------------------------------------------------

def _check_schedule_roundtrip(leaf_sizes, chunk_bytes, align):
    tree = {f"l{i}": jnp.arange(s, dtype=jnp.float32) + i
            for i, s in enumerate(leaf_sizes)}
    lay = BucketLayout.for_tree(tree, align=align)
    sched = ChunkedSchedule.for_layout(lay, chunk_bytes)
    # bounds partition the leaves, strictly increasing
    assert sched.bounds[0] == 0 and sched.bounds[-1] == lay.n_leaves
    assert list(sched.bounds) == sorted(set(sched.bounds))
    # chunk geometry tiles the padded buffer exactly
    assert sum(sched.chunk_sizes) == lay.padded_size
    nxt = list(sched.chunk_offsets[1:]) + [lay.padded_size]
    for off, sz, n_off in zip(sched.chunk_offsets, sched.chunk_sizes, nxt):
        assert off + sz == n_off
    # sub-layouts rebase to the chunk origin and partition the leaves
    cls_ = sched.chunk_layouts
    assert sum(cl.n_leaves for cl in cls_) == lay.n_leaves
    assert all(cl.offsets[0] == 0 for cl in cls_)
    for cl, sz in zip(cls_, sched.chunk_sizes):
        assert cl.padded_size == sz
    # split/concat round-trips even when chunk_bytes does not divide the
    # buffer (the greedy packer closes on whole leaves, never mid-leaf)
    flat = lay.flatten(tree)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(sched.split(flat))), np.asarray(flat))
    # per-chunk key slices reassemble the monolithic schedule, in order
    keys = jax.random.split(KEY, lay.n_leaves)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(
            [sched.chunk_keys(keys, c) for c in range(sched.n_chunks)])),
        np.asarray(keys))


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None)
    @given(
        leaf_sizes=st.lists(st.integers(1, 600), min_size=1, max_size=10),
        chunk_bytes=st.integers(-64, 5000),
        align=st.sampled_from([1, 4, 16, 128]),
    )
    def test_chunk_schedule_roundtrip_property(leaf_sizes, chunk_bytes, align):
        _check_schedule_roundtrip(leaf_sizes, chunk_bytes, align)

except ImportError:  # no hypothesis in the image: seeded deterministic sweep

    @pytest.mark.parametrize("seed", range(25))
    def test_chunk_schedule_roundtrip_property(seed):
        rng = np.random.RandomState(seed)
        leaf_sizes = rng.randint(1, 600, size=rng.randint(1, 11)).tolist()
        chunk_bytes = int(rng.randint(-64, 5000))
        align = int(rng.choice([1, 4, 16, 128]))
        _check_schedule_roundtrip(leaf_sizes, chunk_bytes, align)


def test_degenerate_chunk_bytes_is_monolithic():
    lay = bucket_layout(CompressionConfig(method="diana", bucketed=True), PARAMS)
    for cb in (0, -1, 10 ** 9):
        sched = ChunkedSchedule.for_layout(lay, cb)
        assert sched.n_chunks == 1
        assert sched.chunk_layouts[0].padded_size == lay.padded_size


# ---------------------------------------------------------------------------
# Chunked == monolithic, bitwise: every operator, every composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,kw", OPERATORS, ids=OP_IDS)
def test_chunked_wire_decode_matches_monolithic(method, kw):
    """Per-chunk compress -> uint8 wire round trip -> decode, concatenated,
    is bitwise the monolithic decode (chunk keys are slices of the monolithic
    per-leaf schedule — never re-splits)."""
    cfg = CompressionConfig(method=method, bucketed=True, **kw)
    lay = bucket_layout(cfg, PARAMS)
    delta = lay.flatten({k: _grid(jax.random.fold_in(KEY, i), v.shape)
                         for i, (k, v) in enumerate(PARAMS.items())})
    comp = bucketed_compressor(cfg, lay)
    mono = comp.decode(comp.compress(delta, KEY), lay.padded_size)
    sched = ChunkedSchedule.for_layout(lay, CHUNK_BYTES)
    assert sched.n_chunks >= 3
    pays = [wire_roundtrip(p) for p in _chunk_payloads(cfg, sched, delta, KEY)]
    np.testing.assert_array_equal(
        np.asarray(_chunk_decode_own(cfg, sched, pays)), np.asarray(mono))


@pytest.mark.parametrize("method,kw", OPERATORS, ids=OP_IDS)
def test_chunked_reference_bitwise_equals_monolithic(method, kw):
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True, **kw)
    vs_m, st_m = _two_steps(cfg)
    vs_c, st_c = _two_steps(replace(cfg, chunk_bytes=CHUNK_BYTES))
    _assert_trees_equal(vs_m, vs_c, f"{method}: ghat")
    _assert_trees_equal(st_m.h_worker, st_c.h_worker, f"{method}: h_worker")
    _assert_trees_equal(st_m.h_server, st_c.h_server, f"{method}: h_server")


@pytest.mark.parametrize("method,kw", OPERATORS, ids=OP_IDS)
def test_chunked_vr_reference_bitwise(method, kw):
    """VR control-variates before the layout decision; the chunked wire must
    keep the (snapshot, mu) rows bitwise too."""
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True,
                            vr=True, vr_p=0.5, **kw)
    n = 4
    g_snap, mu_cand = _stacked(n, KEY, tag=7), _stacked(n, KEY, tag=8)

    def run(c):
        state = reference_init(PARAMS, c, n)
        state = state._replace(vr=state.vr._replace(
            snapshot=_stacked(n, KEY, tag=5), mu=_stacked(n, KEY, tag=6)))
        return reference_step(_stacked(n, KEY), state, KEY, c,
                              vr_aux=(g_snap, mu_cand), params=PARAMS)

    v_m, ns_m = run(cfg)
    v_c, ns_c = run(replace(cfg, chunk_bytes=CHUNK_BYTES))
    _assert_trees_equal(v_m, v_c, f"{method}: ghat")
    _assert_trees_equal(ns_m.vr, ns_c.vr, f"{method}: vr state")
    _assert_trees_equal(ns_m.h_worker, ns_c.h_worker, f"{method}: h_worker")


@pytest.mark.parametrize("method,kw", OPERATORS, ids=OP_IDS)
def test_chunked_downlink_reference_bitwise(method, kw):
    """chunk_bytes is inherited by the downlink config: the broadcast wire
    chunks too, and stays bitwise the monolithic broadcast."""
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True,
                            down_method="natural", **kw)
    vs_m, st_m = _two_steps(cfg)
    vs_c, st_c = _two_steps(replace(cfg, chunk_bytes=CHUNK_BYTES))
    _assert_trees_equal(vs_m, vs_c, f"{method}: ghat")
    _assert_trees_equal(st_m.h_down, st_c.h_down, f"{method}: h_down")
    _assert_trees_equal(st_m.h_worker, st_c.h_worker, f"{method}: h_worker")


@pytest.mark.parametrize("method,kw", OPERATORS, ids=OP_IDS)
def test_chunked_participation_reference_bitwise(method, kw):
    spec = ParticipationSpec(q=0.75, churn=(ChurnEvent(1, 2, "leave"),))
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True,
                            participation=spec, **kw)
    vs_m, st_m = _two_steps(cfg)
    vs_c, st_c = _two_steps(replace(cfg, chunk_bytes=CHUNK_BYTES))
    _assert_trees_equal(vs_m, vs_c, f"{method}: ghat")
    _assert_trees_equal(st_m.h_worker, st_c.h_worker, f"{method}: h_worker")
    _assert_trees_equal(st_m.h_server, st_c.h_server, f"{method}: h_server")


# ---------------------------------------------------------------------------
# Checksum tails: one per wire buffer == one per chunk
# ---------------------------------------------------------------------------

def test_checksum_tail_counted_per_chunk():
    cfg = CompressionConfig(method="diana", block_size=16, bucketed=True)
    lay = bucket_layout(cfg, PARAMS)
    one = checksum_tail_bits_per_dim(lay, 0)
    assert one == pytest.approx(CHECKSUM_BYTES * 8.0 / lay.size)
    n_chunks = ChunkedSchedule.for_layout(lay, CHUNK_BYTES).n_chunks
    assert n_chunks >= 3
    assert checksum_tail_bits_per_dim(lay, CHUNK_BYTES) == pytest.approx(
        one * n_chunks)


def test_policy_bits_count_checksum_tail_only_when_armed():
    from repro.core.policy import as_policy, policy_bits_per_dim

    cfg = CompressionConfig(method="diana", block_size=16, bucketed=True,
                            chunk_bytes=CHUNK_BYTES)
    pol = as_policy(cfg)
    lay = bucket_layout(cfg, PARAMS)
    plain = policy_bits_per_dim(pol, PARAMS)
    armed = policy_bits_per_dim(pol, PARAMS, checksum=True)
    n_chunks = ChunkedSchedule.for_layout(lay, CHUNK_BYTES).n_chunks
    assert armed > plain
    assert armed - plain == pytest.approx(
        CHECKSUM_BYTES * 8.0 * n_chunks / lay.size)
    # per-leaf groups carry no tail (the fault harness is bucketed-only)
    pol_pl = as_policy(CompressionConfig(method="diana", block_size=16))
    assert policy_bits_per_dim(pol_pl, PARAMS, checksum=True) == \
        policy_bits_per_dim(pol_pl, PARAMS)


# ---------------------------------------------------------------------------
# Faults: a corrupt landing mid-chunk excludes the worker WHOLE
# ---------------------------------------------------------------------------

def test_corrupt_mid_chunk_excludes_worker_like_churn_leave():
    """The corrupt event addresses the concatenated body; landing in a
    non-first chunk must exclude the victim exactly like a churn leave —
    same ghat, same h_server, surviving h rows untouched, victim's h frozen
    (never a half-applied payload)."""
    cfg = CompressionConfig(method="diana", block_size=16, p=math.inf,
                            bucketed=True, chunk_bytes=CHUNK_BYTES)
    lay = bucket_layout(cfg, PARAMS)
    sched = ChunkedSchedule.for_layout(lay, CHUNK_BYTES)
    assert sched.n_chunks >= 3
    delta = lay.flatten({k: _grid(jax.random.fold_in(KEY, i), v.shape)
                         for i, (k, v) in enumerate(PARAMS.items())})
    sizes = [int(fuse_payload(p).size)
             for p in _chunk_payloads(cfg, sched, delta, KEY)]
    byte = sizes[0] + sizes[1] // 2          # middle of the SECOND chunk
    plan = FaultPlan(events=(FaultEvent(step=0, worker=1, kind="corrupt",
                                        byte=byte),))

    n = 4
    grads = _stacked(n, KEY)
    v_f, ns_f = reference_step(grads, reference_init(PARAMS, cfg, n), KEY,
                               cfg, step=0, faults=plan)
    cfg_churn = replace(cfg, participation=ParticipationSpec(
        churn=(ChurnEvent(0, 1, "leave"),)))
    v_c, ns_c = reference_step(grads, reference_init(PARAMS, cfg_churn, n),
                               KEY, cfg_churn, step=0)
    _assert_trees_equal(v_f, v_c, "ghat")
    _assert_trees_equal(ns_f.h_server, ns_c.h_server, "h_server")
    for w in (0, 2, 3):
        np.testing.assert_array_equal(np.asarray(ns_f.h_worker[w]),
                                      np.asarray(ns_c.h_worker[w]))
    # victim's memory is frozen at its pre-step value (zeros at step 0)
    assert float(jnp.abs(ns_f.h_worker[1]).max()) == 0.0
    # and the surviving rows really moved (the step was not degraded)
    assert float(jnp.abs(ns_f.h_worker[0]).max()) > 0.0

    # outcome-equality with the monolithic wire: the same body byte names
    # the same victim, so the round is bitwise the monolithic fault round
    cfg_mono = replace(cfg, chunk_bytes=0)
    v_m, ns_m = reference_step(grads, reference_init(PARAMS, cfg_mono, n),
                               KEY, cfg_mono, step=0, faults=plan)
    _assert_trees_equal(v_f, v_m, "ghat chunked-vs-monolithic")
    _assert_trees_equal(ns_f.h_worker, ns_m.h_worker, "h_worker")
    _assert_trees_equal(ns_f.h_server, ns_m.h_server, "h_server")


def test_churn_mid_run_composes_with_chunked_faults():
    """Churn (worker 2 leaves at step 1) + a mid-chunk corrupt on worker 1:
    the chunked run tracks the monolithic run bitwise across both steps."""
    spec = ParticipationSpec(churn=(ChurnEvent(1, 2, "leave"),))
    base = CompressionConfig(method="diana", block_size=16, p=math.inf,
                             bucketed=True, participation=spec)
    lay = bucket_layout(base, PARAMS)
    sizes = [int(fuse_payload(p).size) for p in _chunk_payloads(
        replace(base, chunk_bytes=CHUNK_BYTES),
        ChunkedSchedule.for_layout(lay, CHUNK_BYTES),
        jnp.zeros((lay.padded_size,), jnp.float32), KEY)]
    plan = FaultPlan(events=(FaultEvent(step=0, worker=1, kind="corrupt",
                                        byte=sizes[0] + 3),))
    vs_m, st_m = _two_steps(base, faults=plan)
    vs_c, st_c = _two_steps(replace(base, chunk_bytes=CHUNK_BYTES),
                            faults=plan)
    _assert_trees_equal(vs_m, vs_c, "ghat")
    _assert_trees_equal(st_m.h_worker, st_c.h_worker, "h_worker")
    _assert_trees_equal(st_m.h_server, st_c.h_server, "h_server")


# ---------------------------------------------------------------------------
# Overlap: the double-buffer contract, counted on the jaxpr
# ---------------------------------------------------------------------------

def test_chunked_round_overlaps_gather_with_decode():
    """tools/check_schedule's counter: with C chunks the round traces one
    all_gather per chunk, and chunk 1's gather is ISSUED before the first
    eqn combining chunk 0's gathered payload with h_server (chunk 0's
    decode_sum_apply) — a collective is in flight during another chunk's
    decode."""
    errors, stats = check_schedule.overlap_report()
    assert not errors, errors
    assert stats["n_chunks"] >= 3
    assert len(stats["gather_eqns"]) == stats["n_chunks"]
    assert stats["gathers_in_flight"] >= 1
    assert stats["gather_eqns"][1] < stats["first_decode_apply_eqn"]


def test_check_schedule_lint_is_clean():
    """The chunked route + oracle lint (CI step) passes on every operator."""
    for method in ("diana", "natural", "randk", "topk_ef", "none"):
        assert check_schedule.chunk_route_errors(method) == []


# ---------------------------------------------------------------------------
# Layout resolution: downgrades warn, and the resolved layout is queryable
# ---------------------------------------------------------------------------

def test_resolve_bucketed_downgrade_warns_and_is_surfaced(monkeypatch):
    """The old-XLA fallback is no longer silent: one structured RuntimeWarning
    names the reason and resulting layout, and `resolved_layout` (the bench
    row surface) reports 'per-leaf (downgraded)'."""
    import types
    import warnings as _warnings

    import repro.compat
    from repro.launch.train import resolve_bucketed, resolved_layout
    from repro.optim import DianaOptimizer

    # resolve_bucketed reads only axis_names and devices.shape — a stub mesh
    # with a live model axis exercises the downgrade without 2 devices.
    mesh = types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=types.SimpleNamespace(shape=(4, 2)))
    waxes = ("data",)
    opt = DianaOptimizer(compression=CompressionConfig(
        method="diana", block_size=16, bucketed=True))

    monkeypatch.setattr(repro.compat, "supports_nested_manual", lambda: False)
    with pytest.warns(RuntimeWarning) as rec:
        resolved = resolve_bucketed(opt, mesh, waxes)
    assert not resolved.policy.any_bucketed()
    msgs = [str(w.message) for w in rec
            if "resolve_bucketed" in str(w.message)]
    assert len(msgs) == 1
    assert "reason=no-nested-manual" in msgs[0]
    assert "resulting_layout=per-leaf" in msgs[0]
    # resolved_layout answers without re-emitting the warning
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert resolved_layout(opt, mesh, waxes) == "per-leaf (downgraded)"

    monkeypatch.setattr(repro.compat, "supports_nested_manual", lambda: True)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert resolved_layout(opt, mesh, waxes) == "bucketed"
        assert resolve_bucketed(opt, mesh, waxes).policy.any_bucketed()
    # per-leaf configs resolve per-leaf with no warning on any toolchain
    opt_pl = DianaOptimizer(compression=CompressionConfig(
        method="diana", block_size=16))
    assert resolved_layout(opt_pl, mesh, waxes) == "per-leaf"


# ---------------------------------------------------------------------------
# Hierarchical topology: node memories and the h == mean(h_i) invariant
# ---------------------------------------------------------------------------

def _hier_cfg(**kw):
    return CompressionConfig(method="diana", block_size=16, p=math.inf,
                             bucketed=True, topology="hierarchical", **kw)


def test_hierarchical_node_size_one_is_flat_bitwise():
    cfg_flat = CompressionConfig(method="diana", block_size=16, p=math.inf,
                                 bucketed=True)
    vs_f, st_f = _two_steps(cfg_flat)
    vs_h, st_h = _two_steps(_hier_cfg(node_size=1))
    _assert_trees_equal(vs_f, vs_h, "ghat")
    _assert_trees_equal(st_f.h_worker, st_h.h_worker, "h_worker")


def test_hierarchical_reference_node_memory_invariants():
    """Three rounds of the two-level exchange: every worker of a node stores
    the identical node row (bitwise), and the server memory is the node mean
    — Lemma 2's recursion runs over nodes, h == mean(h_nodes)."""
    cfg = _hier_cfg(node_size=2)
    n = 4
    state = reference_init(PARAMS, cfg, n)
    for s in range(3):
        _, state = reference_step(_stacked(n, KEY, tag=s), state,
                                  jax.random.fold_in(KEY, s), cfg)
    hw = np.asarray(state.h_worker)
    assert np.abs(hw).max() > 0.0
    np.testing.assert_array_equal(hw[0], hw[1])      # node 0 duplicated
    np.testing.assert_array_equal(hw[2], hw[3])      # node 1 duplicated
    leaders = hw[::2]
    np.testing.assert_allclose(leaders.mean(axis=0),
                               np.asarray(state.h_server),
                               rtol=1e-5, atol=1e-6)


def test_hierarchical_chunked_bitwise_equals_monolithic():
    vs_m, st_m = _two_steps(_hier_cfg(node_size=2))
    vs_c, st_c = _two_steps(_hier_cfg(node_size=2, chunk_bytes=CHUNK_BYTES))
    _assert_trees_equal(vs_m, vs_c, "ghat")
    _assert_trees_equal(st_m.h_worker, st_c.h_worker, "h_worker")
    _assert_trees_equal(st_m.h_server, st_c.h_server, "h_server")


def test_hierarchical_gates_compositions():
    cfg = _hier_cfg(node_size=2)
    n = 4
    grads = _stacked(n, KEY)
    with pytest.raises(AssertionError):
        reference_step(grads, reference_init(PARAMS, cfg, n), KEY, cfg,
                       step=0, faults=FaultPlan())
    cfg3 = _hier_cfg(node_size=3)  # 3 does not divide 4
    with pytest.raises(AssertionError):
        reference_step(grads, reference_init(PARAMS, cfg3, n), KEY, cfg3)
    # grouped policies keep topology flat
    from repro.core.policy import ChannelSpec, CompressionPolicy, Rule

    pol = CompressionPolicy(
        rules=(Rule("emb", ChannelSpec(method="diana", block_size=16)),
               Rule(".*", ChannelSpec(method="natural"))),
        bucketed=True, topology="hierarchical", node_size=2)
    with pytest.raises(NotImplementedError):
        reference_step(grads, reference_init(PARAMS, pol, n), KEY, pol)


# ---------------------------------------------------------------------------
# Distributed: chunked + hierarchical == reference on a 4-worker mesh
# ---------------------------------------------------------------------------

DIST_COMMON = """
import jax, jax.numpy as jnp, numpy as np, json, math
from dataclasses import replace
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import CompressionConfig, DianaState, aggregate_shardmap, init_state
from repro.core.diana import reference_init, reference_step
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 1), ("data", "model"))
n = 4
params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((24,)), "e": jnp.zeros((20, 13))}
key = jax.random.PRNGKey(42)
grid = lambda k, s: jnp.round(jax.random.normal(k, s) * 64) / 64
grads = {k: grid(jax.random.fold_in(key, i), (n,) + v.shape)
         for i, (k, v) in enumerate(params.items())}

def dist_fn(cfg, state, node_size=1):
    def body(grads_stacked, h_worker, h_server, key):
        g_local = jax.tree_util.tree_map(lambda g: g[0], grads_stacked)
        # hierarchical caller contract: fold the NODE index, not the worker
        wkey = jax.random.fold_in(key, jax.lax.axis_index("data") // node_size)
        ghat, new_state = aggregate_shardmap(
            g_local, DianaState(h_worker, h_server), wkey, cfg,
            axis_names=("data",), n_workers=n)
        return ghat, new_state.h_worker, new_state.h_server
    return shard_map(body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P("data"), grads),
                  jax.tree_util.tree_map(lambda _: P("data"), state.h_worker),
                  jax.tree_util.tree_map(lambda _: P(), state.h_server), P()),
        out_specs=(jax.tree_util.tree_map(lambda _: P(), params),
                   jax.tree_util.tree_map(lambda _: P("data"), state.h_worker),
                   jax.tree_util.tree_map(lambda _: P(), state.h_server)),
        axis_names={"data"}, check_vma=False)

def errs(cfg, node_size=1):
    v_ref, ref_new = reference_step(grads, reference_init(params, cfg, n), key, cfg)
    state = init_state(params, cfg, n)
    ghat, h_w, h_s = jax.jit(dist_fn(cfg, state, node_size))(
        grads, state.h_worker, state.h_server, key)
    return dict(
        ghat=max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(ghat), jax.tree_util.tree_leaves(v_ref))),
        h_w=float(jnp.abs(h_w - ref_new.h_worker).max()),
        h_s=float(jnp.abs(h_s - ref_new.h_server).max()),
    )
"""


def test_chunked_distributed_bitwise_equals_reference():
    """Distributed chunked rounds == the chunked reference, exactly, for all
    five operators on a real 4-worker mesh."""
    code = DIST_COMMON + """
out = {}
for method, kw in [("diana", dict(block_size=16)), ("natural", {}),
                   ("randk", dict(k=9)), ("topk_ef", dict(k=9)), ("none", {})]:
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True,
                            chunk_bytes=300, **kw)
    out[method] = errs(cfg)
print(json.dumps(out))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    for method, e in out.items():
        for name, err in e.items():
            assert err == 0.0, (method, name, e)


def test_hierarchical_distributed_bitwise_equals_reference():
    """Two-level rounds (node_size=2, with and without chunking) == the
    hierarchical reference, exactly, on a real 4-worker mesh."""
    code = DIST_COMMON + """
out = {}
for label, cb in [("hier", 0), ("hier_chunked", 300)]:
    cfg = CompressionConfig(method="diana", block_size=16, p=math.inf,
                            bucketed=True, topology="hierarchical",
                            node_size=2, chunk_bytes=cb)
    out[label] = errs(cfg, node_size=2)
print(json.dumps(out))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    for label, e in out.items():
        for name, err in e.items():
            assert err == 0.0, (label, name, e)
