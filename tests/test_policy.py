"""Compression-policy API (ISSUE 5): ChannelSpec / CompressionPolicy.

The two laws this file pins:

* **Back-compat law** — ``CompressionPolicy.uniform(cfg)`` IS the legacy flat
  path: the flat config round-trips exactly, and init/reference/distributed
  results are bitwise-identical to passing the config itself (which the rest
  of the suite pins against the pre-policy seed behaviour), for all five
  operators, per-leaf and bucketed, VR and downlink on/off.

* **Grouped-round law** — a mixed policy (>=3 distinct operators across
  groups) runs ``aggregate_shardmap == reference_step`` bitwise on a
  4-worker mesh in the grouped-bucketed layout, with at most ONE
  compress/all-gather/decode_sum per group per direction.
"""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ChannelSpec,
    CompressionConfig,
    CompressionPolicy,
    Rule,
    init_state,
    parse_rules,
    partition_for,
    policy_bits_per_dim,
    reference_init,
    reference_step,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

METHODS = ["diana", "natural", "randk", "topk_ef", "identity"]


def tree_eq(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def small_params():
    return {"emb": jnp.ones((12, 4)), "w": jnp.ones((8, 8)), "b": jnp.ones((6,))}


def small_grads(params, n, key):
    return {
        k: jax.random.normal(jax.random.fold_in(key, i), (n,) + v.shape)
        for i, (k, v) in enumerate(params.items())
    }


# ---------------------------------------------------------------------------
# Back-compat law: uniform(cfg) == the flat path
# ---------------------------------------------------------------------------

FLAT_GRID = [
    dict(method=m, k=4, block_size=16) for m in METHODS
] + [
    dict(method="diana", block_size=16, bucketed=True),
    dict(method="topk_ef", k=4, bucketed=True),
    dict(method="randk", k=4, down_method="natural"),
    dict(method="diana", block_size=16, down_method="topk_ef", down_k=3,
         down_bucketed=True, bucketed=True),
    dict(method="natural", vr=True, vr_p=0.5),
    dict(method="diana", block_size=16, p=2.0, alpha=0.125, use_kernel=False,
         h_dtype=jnp.bfloat16, worker_axes=("data",)),
]


@pytest.mark.parametrize("kw", FLAT_GRID, ids=lambda kw: "-".join(
    f"{k}={v}" for k, v in kw.items() if k != "h_dtype"))
def test_uniform_flat_config_roundtrip(kw):
    """uniform(cfg).flat_config() == cfg for the whole flat surface — the
    precondition for the uniform policy reaching the identical code path."""
    cfg = CompressionConfig(**kw)
    pol = CompressionPolicy.uniform(cfg)
    assert pol.is_uniform
    assert pol.flat_config() == cfg
    # the memoized compressor cache sees ONE config object
    assert pol.flat_config().make() is cfg.make()


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("bucketed", [False, True])
def test_uniform_reference_bitwise(method, bucketed):
    """reference_step(policy) == reference_step(flat cfg) bitwise: v and every
    state leaf — per operator, both layouts."""
    cfg = CompressionConfig(method=method, k=4, block_size=16, bucketed=bucketed)
    pol = CompressionPolicy.uniform(cfg)
    params = small_params()
    key = jax.random.PRNGKey(3)
    grads = small_grads(params, 4, key)

    s_cfg = reference_init(params, cfg, 4)
    s_pol = reference_init(params, pol, 4)
    tree_eq(s_cfg, s_pol)
    assert jax.tree_util.tree_structure(s_cfg) == jax.tree_util.tree_structure(s_pol)

    v_cfg, s_cfg = reference_step(grads, s_cfg, key, cfg, beta=0.9)
    v_pol, s_pol = reference_step(grads, s_pol, key, pol, beta=0.9)
    tree_eq(v_cfg, v_pol)
    tree_eq(s_cfg, s_pol)


@pytest.mark.parametrize("extra", [
    dict(vr=True, vr_p=0.5),
    dict(down_method="natural"),
    dict(down_method="topk_ef", down_k=3, vr=True, vr_p=0.5),
], ids=["vr", "down", "vr+down"])
@pytest.mark.parametrize("bucketed", [False, True])
def test_uniform_reference_bitwise_vr_downlink(extra, bucketed):
    """The law extends to VR and downlink composition (both layouts)."""
    cfg = CompressionConfig(method="diana", block_size=16, bucketed=bucketed,
                            **extra)
    pol = CompressionPolicy.uniform(cfg)
    params = small_params()
    key = jax.random.PRNGKey(5)
    grads = small_grads(params, 4, key)
    kwargs = {}
    if cfg.vr:
        g_snap = small_grads(params, 4, jax.random.fold_in(key, 99))
        kwargs = dict(vr_aux=(g_snap, grads), params=params)

    s_cfg = reference_init(params, cfg, 4)
    s_pol = reference_init(params, pol, 4)
    v_cfg, s_cfg = reference_step(grads, s_cfg, key, cfg, **kwargs)
    v_pol, s_pol = reference_step(grads, s_pol, key, pol, **kwargs)
    tree_eq(v_cfg, v_pol)
    tree_eq(s_cfg, s_pol)


def test_uniform_init_state_layout_identical():
    """init_state under a uniform policy keeps the legacy tree STRUCTURE
    (not just values) — existing checkpoints restore unchanged."""
    params = small_params()
    for kw in (dict(method="diana", block_size=16),
               dict(method="diana", block_size=16, bucketed=True,
                    down_method="natural")):
        cfg = CompressionConfig(**kw)
        a = init_state(params, cfg, 4)
        b = init_state(params, CompressionPolicy.uniform(cfg), 4)
        assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
        tree_eq(a, b)


# ---------------------------------------------------------------------------
# DianaOptimizer: policy= argument, deprecation shim equivalence
# ---------------------------------------------------------------------------

def test_optimizer_shim_equals_policy_api():
    """The legacy vr/vr_p/down_method/down_k kwargs build the IDENTICAL
    policy as the explicit policy.replace/with_down calls, with a
    DeprecationWarning."""
    from repro.optim import DianaOptimizer, momentum

    cfg = CompressionConfig(method="diana", block_size=16)
    with pytest.deprecated_call():
        shim = DianaOptimizer(cfg, momentum(0.9), vr=True, vr_p=0.25,
                              down_method="natural", down_k=8)
    explicit = DianaOptimizer(
        inner=momentum(0.9),
        policy=CompressionPolicy.uniform(cfg)
        .replace(vr=True, vr_p=0.25)
        .with_down(method="natural", k=8),
    )
    assert shim.policy == explicit.policy
    assert shim.variance_reduced and shim.bidirectional
    # the shimmed policy still collapses to a flat config (uniform)
    flat = shim.policy.flat_config()
    assert flat.vr and flat.vr_p == 0.25
    assert flat.down_method == "natural" and flat.down_k == 8


def test_optimizer_rejects_both_surfaces():
    from repro.optim import DianaOptimizer, momentum

    cfg = CompressionConfig()
    with pytest.raises(ValueError):
        DianaOptimizer(cfg, momentum(0.9),
                       policy=CompressionPolicy.uniform(cfg))


def test_optimizer_compression_property_roundtrips():
    from repro.optim import DianaOptimizer, momentum

    cfg = CompressionConfig(method="randk", k=8, bucketed=True)
    opt = DianaOptimizer(cfg, momentum(0.9))
    assert opt.compression == cfg
    assert opt.policy.is_uniform


# ---------------------------------------------------------------------------
# Matching + partition semantics
# ---------------------------------------------------------------------------

def test_first_match_wins_and_order_stable():
    pol = CompressionPolicy(rules=(
        Rule("^emb$", ChannelSpec(method="topk_ef", k=4)),
        Rule("emb|w", ChannelSpec(method="natural")),
        Rule(".*", ChannelSpec(method="diana", block_size=16)),
    ))
    assert pol.match("emb") == 0       # first match, not best match
    assert pol.match("w") == 1
    assert pol.match("b") == 2
    part = partition_for(pol, small_params())
    assert part.group_names == ("g00_topk_ef", "g01_natural", "g02_ternary")
    assert [len(ids) for ids in part.group_leaf_ids] == [1, 1, 1]


def test_unmatched_leaf_raises():
    pol = CompressionPolicy(rules=(Rule("^emb$", ChannelSpec()),))
    with pytest.raises(KeyError, match="catch-all"):
        partition_for(pol, small_params())


def test_partition_split_merge_roundtrip():
    pol = CompressionPolicy(rules=parse_rules("emb=natural,*=diana:block=16"))
    params = small_params()
    part = partition_for(pol, params)
    merged = part.merge(part.split(params))
    assert jax.tree_util.tree_structure(merged) == jax.tree_util.tree_structure(params)
    tree_eq(merged, params)


def test_rule_config_inheritance():
    """Unset spec knobs inherit flat defaults; down specs inherit the uplink
    spec first (the legacy down_k-inherits-k semantics)."""
    pol = CompressionPolicy(rules=(
        Rule(".*", ChannelSpec(method="randk", k=8),
             down=ChannelSpec(method="topk_ef")),
    ), bucketed=True)
    up = pol.rule_config(0)
    down = pol.rule_down_config(0)
    assert up.k == 8 and up.bucketed and up.block_size == 2048
    assert down.method == "topk_ef" and down.k == 8 and down.bucketed
    # layouts can diverge per direction
    pol2 = CompressionPolicy(rules=(
        Rule(".*", ChannelSpec(method="randk", k=8),
             down=ChannelSpec(method="topk_ef", layout="perleaf")),
    ), bucketed=True)
    assert pol2.rule_config(0).bucketed
    assert not pol2.rule_down_config(0).bucketed


def test_force_perleaf_downgrade():
    pol = CompressionPolicy(rules=parse_rules(
        "emb=topk_ef:k=4:layout=bucketed,*=diana/natural"), bucketed=True)
    assert pol.any_bucketed()
    down = pol.force_perleaf()
    assert not down.any_bucketed()
    # uniform policies keep the legacy downgrade semantics bitwise
    cfg = CompressionConfig(method="diana", bucketed=True, down_method="natural")
    flat_down = CompressionPolicy.uniform(cfg).force_perleaf().flat_config()
    assert flat_down.bucketed is False and flat_down.down_bucketed is False


# ---------------------------------------------------------------------------
# Inline syntax + JSON serialization
# ---------------------------------------------------------------------------

def test_parse_rules_inline_syntax():
    rules = parse_rules(
        "scale$|bias=identity,emb=topk_ef:k=256,"
        "*=diana:block=1024:p=inf/natural:alpha=0.5")
    assert rules[0] == Rule("scale$|bias", ChannelSpec(method="identity"))
    assert rules[1] == Rule("emb", ChannelSpec(method="topk_ef", k=256))
    assert rules[2].pattern == ".*" and rules[2].is_catch_all
    assert rules[2].spec == ChannelSpec(method="diana", block_size=1024,
                                        p=math.inf)
    assert rules[2].down == ChannelSpec(method="natural", alpha=0.5)


def test_parse_rules_rejects_garbage():
    with pytest.raises(ValueError):
        parse_rules("no_equals_sign")
    with pytest.raises(ValueError):
        parse_rules("*=diana:frobnicate=3")
    with pytest.raises(KeyError):
        parse_rules("*=made_up_method")


def test_json_roundtrip_and_file_loading(tmp_path):
    from repro.core import load_policy

    pol = CompressionPolicy(
        rules=parse_rules("emb=topk_ef:k=4,*=diana:block=16/natural"),
        bucketed=True, vr=True, vr_p=0.25, worker_axes=("data",))
    assert CompressionPolicy.from_json(pol.to_json()) == pol

    path = tmp_path / "policy.json"
    path.write_text(pol.to_json())
    loaded = load_policy(str(path))
    assert loaded == pol
    # inline strings load too, with globals supplied by the caller
    inline = load_policy("*=diana:block=16", bucketed=True)
    assert inline.bucketed and inline.rules[0].spec.block_size == 16


def test_policy_bits_per_dim_weighted():
    """Size-weighted mean across groups matches the hand computation."""
    params = {"a": jnp.ones((100,)), "b": jnp.ones((300,))}
    pol = CompressionPolicy(rules=parse_rules("^a$=none,*=topk_ef:k=30"))
    per_dim = policy_bits_per_dim(pol, params)
    # identity: 32 bits/dim on 100; topk: (32+16)*30/300 bits/dim on 300
    expect = (32.0 * 100 + (32 + 16) * 30.0 / 300 * 300) / 400
    assert per_dim == pytest.approx(expect)


# ---------------------------------------------------------------------------
# tools/check_policy.py linter
# ---------------------------------------------------------------------------

def test_check_policy_repo_defaults_clean():
    """Every arch default policy parses, resolves and covers its model —
    the CI step, run in-process."""
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_policy
        assert check_policy.main([]) == 0
    finally:
        sys.path.pop(0)


def test_check_policy_catches_structural_rot():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_policy

        # no catch-all
        assert check_policy.main(["emb=diana", "--no-models"]) == 1
        # two catch-alls
        assert check_policy.main(["*=diana,*=natural", "--no-models"]) == 1
        # catch-all not last (dead rule)
        assert check_policy.main(["*=diana,emb=natural", "--no-models"]) == 1
        # unknown method / broken regex do not crash the linter
        assert check_policy.main(["*=frobnicate", "--no-models"]) == 1
        assert check_policy.main(["(((=diana,*=diana", "--no-models"]) == 1
        # and a clean one passes
        assert check_policy.main(["emb=natural,*=diana", "--no-models"]) == 0
    finally:
        sys.path.pop(0)


# ---------------------------------------------------------------------------
# Grouped state: checkpointing with policy metadata
# ---------------------------------------------------------------------------

def test_grouped_state_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_metadata, restore_checkpoint, save_checkpoint

    pol = CompressionPolicy(
        rules=parse_rules("^b$=identity,emb=topk_ef:k=4,*=diana:block=16"),
        bucketed=True)
    params = small_params()
    key = jax.random.PRNGKey(0)
    state = reference_init(params, pol, 4)
    _, state = reference_step(small_grads(params, 4, key), state, key, pol)

    save_checkpoint(str(tmp_path), 1, {"diana": state},
                    metadata={"policy": pol.to_json_dict()})
    restored, step = restore_checkpoint(str(tmp_path), {"diana": state})
    assert step == 1
    tree_eq(restored["diana"], state)
    # the serialized policy rebuilds EQUAL — enough to re-derive the grouped
    # state template on restore
    meta = load_metadata(str(tmp_path))
    assert CompressionPolicy.from_json_dict(meta["policy"]) == pol


def test_sortfree_topk_matches_lax_topk():
    """The partial-manual top-k fallback selects the IDENTICAL set as
    lax.top_k, ties and zeros included (the decode is order-invariant)."""
    from repro.core.compressors.topk_ef import _select_topk_sortfree

    key = jax.random.PRNGKey(0)
    d = 97
    for trial in range(24):
        k2 = jax.random.fold_in(key, trial)
        kk = int(jax.random.randint(jax.random.fold_in(k2, 1), (), 1, d + 1))
        x = jax.random.normal(jax.random.fold_in(k2, 2), (d,))
        if trial % 3 == 0:
            x = jnp.round(x * 2) / 2  # force ties (and zeros)
        a = np.sort(np.asarray(_select_topk_sortfree(jnp.abs(x), kk)))
        b = np.sort(np.asarray(jax.lax.top_k(jnp.abs(x), kk)[1]))
        np.testing.assert_array_equal(a, b, err_msg=f"trial={trial} k={kk}")
    a = np.sort(np.asarray(_select_topk_sortfree(jnp.zeros((d,)), 5)))
    np.testing.assert_array_equal(a, np.arange(5))


# ---------------------------------------------------------------------------
# The grouped-round law: mixed policy on a real 4-worker mesh (subprocess)
# ---------------------------------------------------------------------------

def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


MESH_COMMON = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import (CompressionPolicy, parse_rules, init_state,
                        reference_init, reference_step, DianaState)
from repro.core.diana import aggregate_shardmap, DOWN_FOLD
from repro.launch.mesh import make_mesh

n = 4
params = {"emb": jnp.ones((32, 8)), "w1": jnp.ones((16, 16)),
          "w2": jnp.ones((16, 16)), "norm": jnp.ones((16,)), "b": jnp.ones((8,))}
key = jax.random.PRNGKey(7)
grads = {k: jax.random.normal(jax.random.fold_in(key, i), (n,) + v.shape)
         for i, (k, v) in enumerate(params.items())}
tmap = jax.tree_util.tree_map

def dist_outputs(pol):
    mesh = make_mesh((n, 1), ("data", "model"))
    state = init_state(params, pol, n)
    def body(gs, h_w, h_s, h_d, k):
        g_local = tmap(lambda g: g[0], gs)
        wkey = jax.random.fold_in(k, jax.lax.axis_index("data"))
        ghat, new = aggregate_shardmap(
            g_local, DianaState(h_w, h_s, None, h_d), wkey, pol,
            axis_names=("data",), n_workers=n,
            down_key=jax.random.fold_in(k, DOWN_FOLD))
        return ghat, new.h_worker, new.h_server, new.h_down
    hd_spec = tmap(lambda _: P(), state.h_down)
    fn = shard_map(body, mesh=mesh,
        in_specs=(tmap(lambda _: P("data"), grads),
                  tmap(lambda _: P("data"), state.h_worker),
                  tmap(lambda _: P(), state.h_server), hd_spec, P()),
        out_specs=(tmap(lambda _: P(), params),
                   tmap(lambda _: P("data"), state.h_worker),
                   tmap(lambda _: P(), state.h_server), hd_spec),
        axis_names={"data"}, check_vma=False)
    return fn, jax.jit(fn)(grads, state.h_worker, state.h_server,
                           state.h_down, key), state
"""


def test_mixed_policy_distributed_matches_reference_bitwise():
    """ISSUE 5 acceptance: >=4 distinct operators across groups, grouped-
    bucketed layout, downlink on one group — aggregate_shardmap ==
    reference_step BITWISE (ghat, h_worker, h_server, h_down), with exactly
    ONE all-gather per group (per uplink direction)."""
    code = MESH_COMMON + """
pol = CompressionPolicy(
    rules=parse_rules("^norm$|^b$=natural,^emb$=topk_ef:k=16,"
                      "^w2$=randk:k=8/natural,*=diana:block=16"),
    bucketed=True)
fn, (ghat, hw, hs, hd), state = dist_outputs(pol)
rstate = reference_init(params, pol, n)
v, rs2 = reference_step(grads, rstate, key, pol)

def eq(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
eq(v, ghat); eq(rs2.h_worker, hw); eq(rs2.h_server, hs); eq(rs2.h_down, hd)

def count(jaxpr, names, acc=None):
    acc = {} if acc is None else acc
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            acc[eqn.primitive.name] = acc.get(eqn.primitive.name, 0) + 1
        for x in eqn.params.values():
            for y in (x if isinstance(x, (list, tuple)) else [x]):
                inner = getattr(y, "jaxpr", None)
                if inner is not None: count(inner, names, acc)
                elif hasattr(y, "eqns"): count(y, names, acc)
    return acc
jx = jax.make_jaxpr(fn)(grads, state.h_worker, state.h_server, state.h_down, key)
c = count(jx.jaxpr, ("all_gather",))
assert len(state.h_worker) == 4, list(state.h_worker)
print(json.dumps({"groups": sorted(state.h_worker), "gathers": c.get("all_gather", 0)}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["groups"] == ["g00_natural", "g01_topk_ef", "g02_randk",
                             "g03_ternary"]
    # one fused gather per group — the grouped BucketLayout invariant
    assert out["gathers"] == 4, out


def test_mixed_policy_with_identity_group_close():
    """Identity groups keep their pmean fast path (documented exemption from
    the bitwise contract) — the merged result still matches the reference to
    f32 tolerance, and the identity leaves are EXACT zero-error means."""
    code = MESH_COMMON + """
pol = CompressionPolicy(
    rules=parse_rules("^norm$|^b$=identity,^emb$=topk_ef:k=16,*=diana:block=16"),
    bucketed=True)
fn, (ghat, hw, hs, hd), state = dist_outputs(pol)
rstate = reference_init(params, pol, n)
v, rs2 = reference_step(grads, rstate, key, pol)
for k2 in ("norm", "b"):
    np.testing.assert_allclose(np.asarray(ghat[k2]),
                               np.asarray(grads[k2].mean(0)), rtol=1e-6)
for x, y in zip(jax.tree_util.tree_leaves(v), jax.tree_util.tree_leaves(ghat)):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-7)
# the compressed groups stay bitwise
np.testing.assert_array_equal(np.asarray(v["emb"]), np.asarray(ghat["emb"]))
np.testing.assert_array_equal(np.asarray(v["w1"]), np.asarray(ghat["w1"]))
print("ok")
"""
    run_py(code)


def test_trainer_runs_grouped_default_policy():
    """make_optimizer(policy='default') trains llama-reduced end-to-end on a
    4-worker mesh: grouped h state, decreasing loss, and the policy survives
    resolve_bucketed's downgrade on a live-model-axis mesh."""
    code = """
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh, resolve_train_mesh
from repro.launch.train import (build_train_step, init_train_state,
                                make_optimizer, resolve_bucketed)
from repro.launch.sharding_rules import batch_specs
from repro.data import make_lm_batch

cfg = reduced(get_config("llama3.2-1b"))
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((4, 1), ("data", "model"))
opt = make_optimizer(cfg, lr=0.02, policy="default")
assert not opt.policy.is_uniform
key = jax.random.PRNGKey(0)
params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
groups = sorted(opt_state.diana.h_worker)
step_fn = build_train_step(cfg, opt, mesh, shape)
smesh, _ = resolve_train_mesh(mesh, opt.policy.worker_axes)
losses = []
for step in range(6):
    hb = make_lm_batch(cfg, shape, step)
    bs = batch_specs(hb, smesh)
    batch = jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, NamedSharding(smesh, s)), hb, bs)
    params, opt_state, m = step_fn(params, opt_state, batch,
                                   jax.random.fold_in(key, step))
    losses.append(float(m["loss"]))
h_sum = float(sum(jnp.abs(l).sum()
                  for l in jax.tree_util.tree_leaves(opt_state.diana.h_worker)))

# live model axis: the downgrade forces every group per-leaf on this toolchain
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
smesh3, rw3 = resolve_train_mesh(mesh3, opt.policy.worker_axes)
from repro.compat import supports_nested_manual
downgraded = not resolve_bucketed(opt, smesh3, rw3).policy.any_bucketed()
assert downgraded == (not supports_nested_manual())
print(json.dumps({"groups": groups, "losses": losses, "h_sum": h_sum}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["groups"] == ["g00_identity", "g01_topk_ef", "g02_ternary"]
    assert out["losses"][-1] < out["losses"][0], out
    assert out["h_sum"] > 0
