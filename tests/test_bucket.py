"""Flat-buffer (bucketed) aggregation: layout, wire fusion, and the bitwise
contract of ISSUE 2:

* `BucketLayout` round-trips arbitrary pytrees (all alignments);
* payload fuse/unfuse is exact for every field combination;
* bucketed `reference_step` == per-leaf `reference_step` BITWISE for every
  registry operator, including the kernel (`interpret=True`) route;
* distributed bucketed aggregation == both references on a 4-worker mesh
  (subprocess, like tests/test_distributed.py);
* the bucketed round really is ONE compress + ONE all-gather + ONE
  decode_sum: counted on the traced jaxpr;
* satellites: sparse index dtype narrowing, memoized `CompressionConfig.make`,
  the generic bucketed fallback hooks for operators without fused overrides.
"""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step
from repro.core.bucket import (
    BucketLayout,
    fuse_payload,
    payload_recipe,
    unfuse_payload,
)
from repro.core.compressors import Payload, payload_nbits
from repro.core.compressors.base import Compressor, index_dtype
from repro.core.diana import bucket_layout

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(0)

PARAMS = {"a": jnp.zeros((13, 5)), "b": jnp.zeros((70,)), "c": jnp.zeros((3, 3, 3))}

METHODS = [
    ("diana", dict(block_size=16)),
    ("qsgd", dict(block_size=16)),
    ("natural", {}),
    ("randk", dict(k=9)),
    ("topk_ef", dict(k=9)),
    ("none", {}),
]


def _grads(params, n, key=KEY):
    return {
        k: jax.random.normal(jax.random.fold_in(key, i), (n,) + v.shape)
        for i, (k, v) in enumerate(params.items())
    }


def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("align", [1, 4, 16, 128])
def test_layout_roundtrip(align):
    tree = {
        "w": jnp.arange(60, dtype=jnp.float32).reshape(12, 5),
        "nested": {"b": jnp.ones((7,), jnp.bfloat16), "s": jnp.float32(3.0).reshape(())},
    }
    lay = BucketLayout.for_tree(tree, align=align)
    flat = lay.flatten(tree)
    assert flat.shape == (lay.padded_size,)
    assert lay.padded_size % align == 0
    assert lay.padded_size >= lay.size == sum(int(np.prod(l.shape)) for l in
                                              jax.tree_util.tree_leaves(tree))
    back = lay.unflatten(flat)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    # offsets are aligned, disjoint, and ordered
    for off, ps in zip(lay.offsets, lay.padded_sizes):
        assert off % align == 0 and ps % align == 0
    assert list(lay.offsets) == sorted(lay.offsets)
    assert lay.offsets[-1] + lay.padded_sizes[-1] == lay.padded_size
    # pads are zero
    mask = np.zeros(lay.padded_size, bool)
    for off, size in zip(lay.offsets, lay.sizes):
        mask[off:off + size] = True
    assert np.all(np.asarray(flat)[~mask] == 0.0)


def test_layout_is_hashable_cache_key():
    l1 = BucketLayout.for_tree(PARAMS, align=16)
    l2 = BucketLayout.for_tree(PARAMS, align=16)
    assert l1 == l2 and hash(l1) == hash(l2)
    assert l1 != BucketLayout.for_tree(PARAMS, align=4)


# ---------------------------------------------------------------------------
# Wire fusion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pay", [
    Payload(packed=jnp.arange(40, dtype=jnp.uint8).reshape(5, 8),
            scales=jnp.linspace(0.1, 2.0, 5, dtype=jnp.float32)),
    Payload(packed=jnp.arange(-6, 6, dtype=jnp.int16)),
    Payload(indices=jnp.arange(9, dtype=jnp.uint16),
            values=jnp.linspace(-1, 1, 9, dtype=jnp.float32)),
    Payload(values=jnp.linspace(-3, 3, 11, dtype=jnp.float32)),
], ids=["ternary", "natural", "sparse", "dense"])
def test_fuse_unfuse_roundtrip(pay):
    buf = fuse_payload(pay)
    assert buf.dtype == jnp.uint8 and buf.ndim == 2
    back = unfuse_payload(buf, payload_recipe(pay))
    for f, g in zip(pay, back):
        if f is None:
            assert g is None
        else:
            assert g.dtype == f.dtype and g.shape == f.shape
            np.testing.assert_array_equal(np.asarray(f), np.asarray(g))
    # and with a leading (gathered) worker axis
    stacked = jnp.stack([buf, buf, buf])
    back_n = unfuse_payload(stacked, payload_recipe(pay))
    for f, g in zip(pay, back_n):
        if f is not None:
            assert g.shape == (3,) + f.shape
            np.testing.assert_array_equal(np.asarray(f), np.asarray(g[1]))


# ---------------------------------------------------------------------------
# Bitwise equality: bucketed reference == per-leaf reference
# ---------------------------------------------------------------------------

def _assert_reference_paths_equal(params, cfg_pl, cfg_bk, n=4, beta=0.9, key=KEY):
    grads = _grads(params, n, key)
    v_pl, ns_pl = reference_step(grads, reference_init(params, cfg_pl, n), key,
                                 cfg_pl, beta=beta)
    v_bk, ns_bk = reference_step(grads, reference_init(params, cfg_bk, n), key,
                                 cfg_bk, beta=beta)
    for a, b in zip(jax.tree_util.tree_leaves(v_pl), jax.tree_util.tree_leaves(v_bk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    lay = bucket_layout(cfg_bk, params)
    hws = jax.tree_util.tree_leaves(ns_pl.h_worker)
    hss = jax.tree_util.tree_leaves(ns_pl.h_server)
    for i, (off, size) in enumerate(zip(lay.offsets, lay.sizes)):
        np.testing.assert_array_equal(
            np.asarray(ns_bk.h_worker[:, off:off + size]), np.asarray(hws[i]))
        np.testing.assert_array_equal(
            np.asarray(ns_bk.h_server[off:off + size]), np.asarray(hss[i]))


@pytest.mark.parametrize("method,kw", METHODS,
                         ids=[m for m, _ in METHODS])
def test_bucketed_reference_bitwise_equals_perleaf(method, kw):
    from dataclasses import replace

    cfg = CompressionConfig(method=method, p=math.inf, **kw)
    _assert_reference_paths_equal(PARAMS, cfg, replace(cfg, bucketed=True))


def test_bucketed_kernel_route_bitwise_equals_perleaf():
    """The Pallas route (interpret=True on CPU): one quantize_pack launch and
    one unpack_reduce launch over the whole model, bitwise-equal to the
    per-leaf kernel calls."""
    from dataclasses import replace

    params = {"a": jnp.zeros((40, 10)), "b": jnp.zeros((300,))}
    cfg = CompressionConfig(method="diana", block_size=128, use_kernel=True)
    _assert_reference_paths_equal(params, cfg, replace(cfg, bucketed=True), n=3)


def test_bucketed_generic_fallback_hooks():
    """An operator with NO fused overrides still runs bucketed (the base
    per-segment fallback) and matches its per-leaf results bitwise."""
    from repro.core.bucket import BucketedCompressor

    class CoarseCompressor(Compressor):
        """Toy operator: keeps the per-segment mean (1 value per leaf)."""
        name = "coarse"
        unbiased = False

        def compress(self, delta, key):
            del key
            return Payload(values=jnp.mean(delta, keepdims=True))

        def decode(self, payload, d):
            return jnp.broadcast_to(payload.values, (d,)).astype(jnp.float32)

        def bits_per_dim(self, d=None):
            return 32.0 / (d or 1)

    comp = CoarseCompressor()
    lay = BucketLayout.for_tree(PARAMS, align=comp.bucket_align())
    bcomp = BucketedCompressor(comp, lay)
    tree = {k: jax.random.normal(jax.random.fold_in(KEY, i), v.shape)
            for i, (k, v) in enumerate(PARAMS.items())}
    flat = lay.flatten(tree)
    pay = bcomp.compress(flat, KEY)
    dec = bcomp.decode(pay, lay.padded_size)
    # per-leaf comparison
    for leaf, seg in zip(jax.tree_util.tree_leaves(tree), lay.split_padded(dec)):
        ref = comp.decode(comp.compress(leaf.reshape(-1), KEY), leaf.size)
        np.testing.assert_array_equal(np.asarray(seg[:leaf.size]), np.asarray(ref))
    # decode_sum default recurrence over a stacked payload
    stacked = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]), pay)
    np.testing.assert_allclose(np.asarray(bcomp.decode_sum(stacked, 2, lay.padded_size)),
                               2 * np.asarray(dec), rtol=1e-6)


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------

def test_sparse_index_dtype_narrows_payload():
    assert index_dtype(256) == jnp.uint8
    assert index_dtype(257) == jnp.uint16
    assert index_dtype(1 << 16) == jnp.uint16
    assert index_dtype((1 << 16) + 1) == jnp.uint32

    k = 16
    for d, idt, ibits in [(200, jnp.uint8, 8), (1000, jnp.uint16, 16)]:
        for method in ("randk", "topk_ef"):
            comp = CompressionConfig(method=method, k=k).make()
            pay = comp.compress(jax.random.normal(KEY, (d,)), KEY)
            assert pay.indices.dtype == idt
            assert payload_nbits(pay) == k * (32 + ibits)
            assert comp.bits_per_dim(d) == pytest.approx((32 + ibits) * k / d)
            # decode still lands on the right coordinates
            dec = comp.decode(pay, d)
            assert int((dec != 0).sum()) <= k


def test_compression_config_make_is_memoized():
    cfg = CompressionConfig(method="diana", block_size=64)
    assert cfg.make() is cfg.make()
    assert cfg.make() is CompressionConfig(method="diana", block_size=64).make()
    assert cfg.make() is not CompressionConfig(method="diana", block_size=128).make()


def test_bucketed_compressor_is_cached():
    from repro.core import bucketed_compressor

    cfg = CompressionConfig(method="diana", block_size=16, bucketed=True)
    lay = bucket_layout(cfg, PARAMS)
    assert bucketed_compressor(cfg, lay) is bucketed_compressor(
        cfg, bucket_layout(cfg, PARAMS))


# ---------------------------------------------------------------------------
# Distributed: one collective, one decode kernel, bitwise-equal
# ---------------------------------------------------------------------------

DIST_COMMON = """
import jax, jax.numpy as jnp, numpy as np, json, math
from dataclasses import replace
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import CompressionConfig, DianaState, aggregate_shardmap, init_state
from repro.core.diana import reference_init, reference_step, bucket_layout
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 1), ("data", "model"))
n = 4
params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((24,))}
key = jax.random.PRNGKey(42)
grads = {"w": jax.random.normal(key, (n, 32, 16)), "b": jax.random.normal(key, (n, 24))}

def dist_fn(cfg, state):
    def body(grads_stacked, h_worker, h_server, key):
        g_local = jax.tree_util.tree_map(lambda g: g[0], grads_stacked)
        wkey = jax.random.fold_in(key, jax.lax.axis_index("data"))
        ghat, new_state = aggregate_shardmap(
            g_local, DianaState(h_worker, h_server), wkey, cfg,
            axis_names=("data",), n_workers=n)
        return ghat, new_state.h_worker, new_state.h_server
    return shard_map(body, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P("data"), grads),
                  jax.tree_util.tree_map(lambda _: P("data"), state.h_worker),
                  jax.tree_util.tree_map(lambda _: P(), state.h_server), P()),
        out_specs=(jax.tree_util.tree_map(lambda _: P(), params),
                   jax.tree_util.tree_map(lambda _: P("data"), state.h_worker),
                   jax.tree_util.tree_map(lambda _: P(), state.h_server)),
        axis_names={"data"}, check_vma=False)
"""


def test_bucketed_distributed_bitwise_equals_references():
    """Distributed bucketed == bucketed reference == per-leaf reference,
    exactly, for ternary / natural / rand-k / top-k."""
    code = DIST_COMMON + """
out = {}
for method, kw in [("diana", dict(block_size=64)), ("natural", {}),
                   ("randk", dict(k=8)), ("topk_ef", dict(k=8))]:
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True, **kw)
    cfg_pl = replace(cfg, bucketed=False)
    v_ref, ref_new = reference_step(grads, reference_init(params, cfg, n), key, cfg)
    v_pl, _ = reference_step(grads, reference_init(params, cfg_pl, n), key, cfg_pl)
    state = init_state(params, cfg, n)
    ghat, h_w, h_s = jax.jit(dist_fn(cfg, state))(grads, state.h_worker, state.h_server, key)
    errs = dict(
        dist_vs_bucket_ref=max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(ghat), jax.tree_util.tree_leaves(v_ref))),
        bucket_ref_vs_perleaf_ref=max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(v_ref), jax.tree_util.tree_leaves(v_pl))),
        h_w=float(jnp.abs(h_w - ref_new.h_worker).max()),
        h_s=float(jnp.abs(h_s - ref_new.h_server).max()),
    )
    out[method] = errs
print(json.dumps(out))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    for method, errs in out.items():
        for name, err in errs.items():
            assert err == 0.0, (method, name, errs)


def test_bucketed_round_is_one_collective_one_decode_kernel():
    """Counted on the traced jaxpr: the bucketed kernel-route round contains
    exactly ONE all-gather and exactly TWO pallas_call launches (fused encode
    + fused decode_sum); the per-leaf layout pays per leaf."""
    code = DIST_COMMON + """
def count_prims(jaxpr, names, acc=None):
    acc = {} if acc is None else acc
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            acc[eqn.primitive.name] = acc.get(eqn.primitive.name, 0) + 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                inner = getattr(x, "jaxpr", None)
                if inner is not None:
                    count_prims(inner, names, acc)
                elif hasattr(x, "eqns"):
                    count_prims(x, names, acc)
    return acc

names = ("all_gather", "pallas_call")
out = {}
for tag, bucketed in (("bucketed", True), ("perleaf", False)):
    cfg = CompressionConfig(method="diana", block_size=128, use_kernel=True,
                            bucketed=bucketed)
    state = init_state(params, cfg, n)
    jaxpr = jax.make_jaxpr(dist_fn(cfg, state))(grads, state.h_worker, state.h_server, key)
    out[tag] = count_prims(jaxpr.jaxpr, names)
# natural: no kernel, but still exactly one collective
cfg = CompressionConfig(method="natural", bucketed=True)
state = init_state(params, cfg, n)
jaxpr = jax.make_jaxpr(dist_fn(cfg, state))(grads, state.h_worker, state.h_server, key)
out["natural_bucketed"] = count_prims(jaxpr.jaxpr, names)
print(json.dumps(out))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["bucketed"].get("all_gather", 0) == 1, out
    assert out["bucketed"].get("pallas_call", 0) == 2, out
    assert out["natural_bucketed"].get("all_gather", 0) == 1, out
    # per-leaf pays per leaf (2 leaves -> 2 field-pairs gathered, 2x2 launches)
    assert out["perleaf"].get("all_gather", 0) > 1, out
    assert out["perleaf"].get("pallas_call", 0) > 2, out


def test_bucketed_train_step_runs_on_worker_mesh():
    """End-to-end: the trainer keeps the bucketed layout on a pure-worker
    mesh (single flat h buffers) and downgrades to per-leaf under a live
    auto 'model' axis on toolchains without nested-manual support."""
    code = """
import jax, jax.numpy as jnp, json
from dataclasses import replace
from jax.sharding import NamedSharding
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh, resolve_train_mesh
from repro.launch.train import build_train_step, init_train_state, make_optimizer, resolve_bucketed
from repro.launch.sharding_rules import batch_specs
from repro.data import make_lm_batch

cfg = reduced(get_config("llama3.2-1b"))
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((8, 1), ("data", "model"))
opt = make_optimizer(cfg, lr=0.02)
key = jax.random.PRNGKey(0)
params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
step_fn = build_train_step(cfg, opt, mesh, shape)
smesh, rw = resolve_train_mesh(mesh, opt.compression.worker_axes)
assert resolve_bucketed(opt, smesh, rw).compression.bucketed
# bucketed state: ONE (n, Dp) h_worker buffer
hw0 = jax.tree_util.tree_leaves(opt_state.diana.h_worker)
assert len(hw0) == 1 and hw0[0].ndim == 2 and hw0[0].shape[0] == 8, hw0[0].shape
losses = []
for step in range(6):
    hb = make_lm_batch(cfg, shape, step)
    bs = batch_specs(hb, smesh)
    batch = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, NamedSharding(smesh, s)), hb, bs)
    params, opt_state, m = step_fn(params, opt_state, batch, jax.random.fold_in(key, step))
    losses.append(float(m["loss"]))
h_sum = float(jnp.abs(jax.tree_util.tree_leaves(opt_state.diana.h_worker)[0]).sum())

# live model axis on this toolchain: resolver downgrades, state is per-leaf
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
smesh3, rw3 = resolve_train_mesh(mesh3, opt.compression.worker_axes)
from repro.compat import supports_nested_manual
downgraded = not resolve_bucketed(opt, smesh3, rw3).compression.bucketed
assert downgraded == (not supports_nested_manual())
print(json.dumps({"losses": losses, "h_sum": h_sum, "downgraded": downgraded}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["losses"][-1] < out["losses"][0], out
    assert out["h_sum"] > 0


# ---------------------------------------------------------------------------
# Perf regression: bucketed rand-k must not lose to per-leaf (ISSUE 6)
# ---------------------------------------------------------------------------

def test_randk_bucketed_not_slower_than_perleaf_reference():
    """The rand-k bucketed regression (BENCH_step_time.json speedup 0.76 at
    the small size): index SELECTION is the per-leaf cost both layouts re-pay
    (the key schedule is the bitwise contract), and with `choice`'s
    argsort-of-permutation it dwarfed the bucketed layout's structural win
    (one gather + one scatter + one concat for the whole model).  The
    `top_k`-of-random-tags selection shrinks that shared cost ~2.4x, so
    bucketed must now be at least as fast on the small bench model.

    Timing on a shared CPU is noisy: medians over interleaved reps (the
    bench's own discipline), best of three attempts."""
    import time
    from dataclasses import replace

    spec = [("emb", (64, 32))] + [
        (f"l{i}.{nm}", shp)
        for i in range(8)
        for nm, shp in [("wq", (32, 32)), ("wo", (32, 32)),
                        ("mlp", (32, 64)), ("b", (64,))]
    ]
    params = {name: jnp.zeros(shape, jnp.float32) for name, shape in spec}
    n = 4
    grads = _grads(params, n)
    cfg_pl = CompressionConfig(method="randk", k=32)
    cfg_bk = replace(cfg_pl, bucketed=True)

    steps = {}
    for tag, cfg in (("pl", cfg_pl), ("bk", cfg_bk)):
        state = reference_init(params, cfg, n)
        step = jax.jit(lambda g, s, k, cfg=cfg: reference_step(g, s, k, cfg))
        jax.block_until_ready(step(grads, state, KEY))  # compile + warm
        steps[tag] = (step, state)

    def _ratio(reps=15):
        ts = {"pl": [], "bk": []}
        for _ in range(reps):
            for tag, (step, state) in steps.items():
                t0 = time.perf_counter()
                jax.block_until_ready(step(grads, state, KEY))
                ts[tag].append(time.perf_counter() - t0)
        med = {k: sorted(v)[len(v) // 2] for k, v in ts.items()}
        return med["pl"] / med["bk"]

    ratios = []
    for _ in range(3):
        ratios.append(_ratio())
        if ratios[-1] >= 1.0:
            break
    assert max(ratios) >= 1.0, f"bucketed rand-k slower than per-leaf: {ratios}"


def test_diana_bucketed_not_slower_than_perleaf_reference():
    """The ternary (diana/qsgd) analogue of the rand-k regression above
    (BENCH_step_time.json speedup 0.886 at the small size): the per-block
    sign-draw is the per-leaf PRNG cost both layouts re-pay, and the
    one-call-per-leaf `jax.random.bits` dispatch dwarfed the bucketed
    layout's structural win.  Batching the equal-row-count draws through one
    vmapped `bits` call (bitwise identical: threefry is counter-mode per
    key) shrinks that shared cost, so bucketed must now be at least as fast
    on the small bench model.

    Same discipline as above: interleaved medians, best of three."""
    import time
    from dataclasses import replace

    spec = [("emb", (64, 32))] + [
        (f"l{i}.{nm}", shp)
        for i in range(8)
        for nm, shp in [("wq", (32, 32)), ("wo", (32, 32)),
                        ("mlp", (32, 64)), ("b", (64,))]
    ]
    params = {name: jnp.zeros(shape, jnp.float32) for name, shape in spec}
    n = 4
    grads = _grads(params, n)
    cfg_pl = CompressionConfig(method="diana", block_size=256, p=math.inf)
    cfg_bk = replace(cfg_pl, bucketed=True)

    steps = {}
    for tag, cfg in (("pl", cfg_pl), ("bk", cfg_bk)):
        state = reference_init(params, cfg, n)
        step = jax.jit(lambda g, s, k, cfg=cfg: reference_step(g, s, k, cfg))
        jax.block_until_ready(step(grads, state, KEY))  # compile + warm
        steps[tag] = (step, state)

    def _ratio(reps=15):
        ts = {"pl": [], "bk": []}
        for _ in range(reps):
            for tag, (step, state) in steps.items():
                t0 = time.perf_counter()
                jax.block_until_ready(step(grads, state, KEY))
                ts[tag].append(time.perf_counter() - t0)
        med = {k: sorted(v)[len(v) // 2] for k, v in ts.items()}
        return med["pl"] / med["bk"]

    ratios = []
    for _ in range(3):
        ratios.append(_ratio())
        if ratios[-1] >= 1.0:
            break
    assert max(ratios) >= 1.0, f"bucketed diana slower than per-leaf: {ratios}"
