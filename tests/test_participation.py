"""Elastic DIANA — partial participation, stragglers, churn and wire faults
(DESIGN.md §Elasticity).

Contracts under test:

* the PART_FOLD mask stream: deterministic, identical on every worker, and
  independent of every other PRNG consumer (enabling participation never
  perturbs the compressor/VR/downlink draws);
* unbiased masked aggregation: the server direction rescales the participant
  sum (``n/|S_t|`` sampled, ``1/(n q)`` expected) while ``h_server`` always
  advances with the UNRESCALED ``sum/n`` (the invariant ``h = mean_i h_i``);
* frozen memory: a non-participant's ``h_worker``/VR rows do not move; a
  churn re-join re-initialises its row to zero; a degraded step
  (``|S_t| < min_workers``) freezes EVERYTHING and returns ``ghat = 0``;
* acceptance: ``aggregate_shardmap == reference_step`` BITWISE on a real
  4-worker mesh under sampling + straggler dropout + churn, for all five
  registry operators, per-leaf and bucketed, VR on/off, downlink on/off;
* multi-step trajectories stay bitwise across 5 steps in exact arithmetic
  (grid gradients, dyadic alpha/scales — the same FMA-contraction discipline
  as the seed's tests, see ``kernels/ref.py::ref_apply_server``);
* convergence law: DIANA under q=0.5 sampling still reaches the exact
  optimum (the rescaled estimator is unbiased and the memory drift argument
  survives intermittent updates); memoryless QSGD under the same sampling
  stalls at its variance floor;
* fault harness: a corrupted wire payload is detected by the bucket
  checksum and excluded from the sum WITHOUT perturbing ``h_server`` — the
  step is bitwise the step in which that worker had left the cohort.
"""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressionConfig,
    ChurnEvent,
    FaultEvent,
    FaultPlan,
    PART_FOLD,
    ParticipationSpec,
    expected_rate,
    parse_faults,
    participation_mask,
    reference_init,
    reference_step,
    step_ctx,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(11)

from tests.test_downlink import OPERATORS, _grid  # noqa: E402  (shared fixtures)


def _fixture(n=4, key=KEY):
    params = {"w": _grid(jax.random.fold_in(key, 0), (12, 5)),
              "b": _grid(jax.random.fold_in(key, 1), (9,))}
    grads = {
        k: _grid(jax.random.fold_in(key, 10 + i), (n,) + v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    return params, grads


def _assert_trees_equal(a, b, msg=""):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=msg)


# ---------------------------------------------------------------------------
# Spec + mask unit contracts
# ---------------------------------------------------------------------------

def test_spec_validation_and_triviality():
    with pytest.raises(ValueError):
        ParticipationSpec(q=0.0)
    with pytest.raises(ValueError):
        ParticipationSpec(dropout=1.0)
    with pytest.raises(ValueError):
        ParticipationSpec(min_workers=0)
    assert ParticipationSpec().is_trivial
    assert not ParticipationSpec(q=0.5).is_trivial
    assert not ParticipationSpec(churn=(ChurnEvent(2, 1, "leave"),)).is_trivial
    # min_workers alone is vacuous: |S_t| = n every step
    assert ParticipationSpec(min_workers=3).is_trivial


def test_spec_json_round_trip():
    spec = ParticipationSpec(q=0.5, dropout=0.25, min_workers=2,
                             churn=(ChurnEvent(3, 1, "leave"),
                                    ChurnEvent(5, 1, "join")),
                             rescale="expected")
    assert ParticipationSpec.from_json_dict(spec.to_json_dict()) == spec


def test_mask_is_deterministic_and_stream_isolated():
    """Same part_key -> same mask; the PART_FOLD stream never collides with
    the worker-fold streams the compressors draw from."""
    spec = ParticipationSpec(q=0.5, dropout=0.2)
    pk = jax.random.fold_in(KEY, PART_FOLD)
    m1 = participation_mask(spec, pk, 8)
    m2 = participation_mask(spec, pk, 8)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    assert m1.shape == (8,) and m1.dtype == jnp.bool_
    # a different step key gives a different draw (not a constant mask)
    masks = [participation_mask(
        spec, jax.random.fold_in(jax.random.fold_in(KEY, t), PART_FOLD), 8)
        for t in range(32)]
    assert len({tuple(np.asarray(m).tolist()) for m in masks}) > 1


def test_churn_schedule_presence_and_reinit():
    spec = ParticipationSpec(churn=(ChurnEvent(2, 3, "leave"),
                                    ChurnEvent(5, 3, "join")))
    pk = jax.random.fold_in(KEY, PART_FOLD)
    for t, present in [(0, True), (1, True), (2, False), (4, False), (5, True)]:
        ctx = step_ctx(spec, pk, 4, t)
        assert bool(ctx.mask[3]) == present, t
        assert bool(ctx.reinit[3]) == (t == 5), t


def test_direction_scale_rules():
    pk = jax.random.fold_in(KEY, PART_FOLD)
    # sampled: n-independent 1/|S_t| on the sum = n/|S_t| on the mean
    spec = ParticipationSpec(churn=(ChurnEvent(0, 0, "leave"),))
    ctx = step_ctx(spec, pk, 4, 0)
    assert float(ctx.dir_scale) == pytest.approx(1.0 / 3.0)
    # expected: 1/(n * rate), mask-independent
    spec_e = ParticipationSpec(q=0.5, dropout=0.2, rescale="expected")
    assert expected_rate(spec_e) == pytest.approx(0.4)
    ctx_e = step_ctx(spec_e, pk, 4, 0)
    assert float(ctx_e.dir_scale) == pytest.approx(1.0 / (4 * 0.4))
    # degraded: scale exactly 0, ok False
    spec_d = ParticipationSpec(min_workers=4,
                               churn=(ChurnEvent(0, 0, "leave"),))
    ctx_d = step_ctx(spec_d, pk, 4, 0)
    assert not bool(ctx_d.ok) and float(ctx_d.dir_scale) == 0.0


def test_parse_faults_cli_syntax():
    assert parse_faults(None) is None
    assert parse_faults("checksum") == FaultPlan()
    plan = parse_faults("corrupt:step=3,worker=1,byte=7;drop:step=5,worker=2")
    assert plan.events[0] == FaultEvent(step=3, worker=1, kind="corrupt", byte=7)
    assert plan.events[1] == FaultEvent(step=5, worker=2, kind="drop")


# ---------------------------------------------------------------------------
# Reference-path semantics: unbiasedness, freezing, reinit, degraded steps
# ---------------------------------------------------------------------------

def _cfg(bucketed=False, **kw):
    return CompressionConfig(method="diana", p=math.inf, block_size=16,
                             bucketed=bucketed, **kw)


@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
def test_full_participation_active_spec_is_bitwise_baseline(bucketed):
    """A NON-trivial spec whose mask happens to be all-true (a churn event
    far in the future) takes the masked code path with ``|S_t| = n`` — and
    must reproduce the pre-elastic path bit for bit (n=4 makes 1/|S| and
    1/n the same dyadic scale)."""
    params, grads = _fixture()
    base = _cfg(bucketed)
    active = _cfg(bucketed,
                  participation=ParticipationSpec(
                      churn=(ChurnEvent(1000, 0, "leave"),)))
    assert active.participation is not None and not active.participation.is_trivial
    v0, s0 = reference_step(grads, reference_init(params, base, 4), KEY, base)
    v1, s1 = reference_step(grads, reference_init(params, active, 4), KEY,
                            active, step=0)
    _assert_trees_equal(v0, v1, "ghat")
    _assert_trees_equal(s0.h_worker, s1.h_worker, "h_worker")
    _assert_trees_equal(s0.h_server, s1.h_server, "h_server")


@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
def test_nonparticipant_memory_frozen_and_h_server_unrescaled(bucketed):
    """Worker 3 leaves at step 0: its h row never moves, the other rows
    advance exactly as in a run where worker 3's gradient is zeroed AND the
    direction is rescaled by n/|S| — while h_server advances with the
    UNRESCALED participant sum / n."""
    params, grads = _fixture()
    cfg = _cfg(bucketed, alpha=0.5,
               participation=ParticipationSpec(
                   churn=(ChurnEvent(0, 3, "leave"),)))
    state = reference_init(params, cfg, 4)
    leaves = jax.tree_util.tree_leaves
    h3_before = [np.asarray(l[3]) for l in leaves(state.h_worker)]
    v, ns = reference_step(grads, state, KEY, cfg, step=0)
    for l, before in zip(leaves(ns.h_worker), h3_before):
        np.testing.assert_array_equal(np.asarray(l[3]), before,
                                      err_msg="row 3 moved")
    # participants' rows DID move (alpha=0.5, non-zero grid grads)
    assert any(float(jnp.abs(l[w]).max()) > 0
               for l in leaves(ns.h_worker) for w in range(3))
    # h_server == mean of worker rows (the memory invariant, mask or not)
    for hs, hw in zip(leaves(ns.h_server), leaves(ns.h_worker)):
        np.testing.assert_allclose(np.asarray(hs),
                                   np.asarray(jnp.mean(hw, axis=0)),
                                   rtol=0, atol=1e-7)
    # worker 3's gradient never contributes: perturbing it changes nothing
    grads_pert = dict(grads, w=grads["w"].at[3].add(1000.0))
    v_pert, ns_pert = reference_step(grads_pert, reference_init(params, cfg, 4),
                                     KEY, cfg, step=0)
    _assert_trees_equal(v, v_pert, "non-participant gradient leaked into ghat")
    _assert_trees_equal(ns.h_server, ns_pert.h_server,
                        "non-participant gradient leaked into h_server")


@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
def test_rejoin_reinitialises_memory_row(bucketed):
    """Worker 2 leaves at step 1 and re-joins at step 3: at step 3 its
    ``h_worker`` row restarts FROM ZERO (the server has no record of a
    returning worker's stale memory), then advances like any participant."""
    params, grads = _fixture()
    cfg = _cfg(bucketed, alpha=0.5,
               participation=ParticipationSpec(
                   churn=(ChurnEvent(1, 2, "leave"), ChurnEvent(3, 2, "join"))))
    state = reference_init(params, cfg, 4)
    leaves = jax.tree_util.tree_leaves
    rows2 = []
    for t in range(4):
        v, state = reference_step(grads, state,
                                  jax.random.fold_in(KEY, t), cfg, step=t)
        rows2.append([np.asarray(h[2]) for h in leaves(state.h_worker)])
    # step 0: moved; steps 1-2: frozen at the step-0 value
    assert any(np.abs(r).max() > 0 for r in rows2[0])
    for r0, r1, r2 in zip(rows2[0], rows2[1], rows2[2]):
        np.testing.assert_array_equal(r1, r0)
        np.testing.assert_array_equal(r2, r0)
    # step 3: re-initialised to zero, then one fresh alpha*Q(g-0) update —
    # hand-zero row 2 of the pre-step-3 state and replay the step: the
    # reinit select must land on exactly that trajectory
    state_pre = reference_init(params, cfg, 4)
    for t in range(3):
        _, state_pre = reference_step(grads, state_pre,
                                      jax.random.fold_in(KEY, t), cfg, step=t)
    zeroed = state_pre._replace(h_worker=jax.tree_util.tree_map(
        lambda h: h.at[2].set(0.0), state_pre.h_worker))
    _, state_z = reference_step(grads, zeroed, jax.random.fold_in(KEY, 3),
                                cfg, step=3)
    for r3, hz in zip(rows2[3], leaves(state_z.h_worker)):
        np.testing.assert_array_equal(r3, np.asarray(hz[2]))


@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
def test_degraded_step_freezes_everything(bucketed):
    """With 3 of 4 workers gone and ``min_workers=2`` the step degrades:
    ghat == 0 exactly and EVERY state leaf is bitwise-unchanged."""
    params, grads = _fixture()
    cfg = _cfg(bucketed, down_method="diana",
               participation=ParticipationSpec(
                   min_workers=2,
                   churn=(ChurnEvent(0, 1, "leave"), ChurnEvent(0, 2, "leave"),
                          ChurnEvent(0, 3, "leave"))))
    state = reference_init(params, cfg, 4)
    # advance one healthy-looking step first so the state is non-zero...
    # (churn at step 0 applies from step 0 — instead seed non-zero memory
    # by hand so the freeze is meaningful)
    bump = lambda t, d: jax.tree_util.tree_map(lambda h: h + d, t)
    state = state._replace(
        h_worker=bump(state.h_worker, 0.25),
        h_server=bump(state.h_server, 0.25),
        h_down=bump(state.h_down, 0.125) if state.h_down is not None else None)
    v, ns = reference_step(grads, state, KEY, cfg, step=0)
    for leaf in jax.tree_util.tree_leaves(v):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))
    _assert_trees_equal(ns.h_worker, state.h_worker, "h_worker moved")
    _assert_trees_equal(ns.h_server, state.h_server, "h_server moved")
    _assert_trees_equal(ns.h_down, state.h_down, "h_down moved")


# ---------------------------------------------------------------------------
# Fault harness: checksum detection == cohort exclusion
# ---------------------------------------------------------------------------

def test_corrupt_payload_excluded_bitwise_like_churn_leave():
    """A corrupt fault on worker 1 produces EXACTLY the step produced by a
    churn schedule in which worker 1 had left: same ghat, same h_server,
    same surviving h rows — the checksum-excluded payload touches nothing.
    (VR off: the local snapshot coin is gated on the SCHEDULED mask only,
    which legitimately differs between the two runs.)"""
    params, grads = _fixture()
    cfg = _cfg(bucketed=True)
    plan = FaultPlan(events=(FaultEvent(step=0, worker=1, kind="corrupt"),))
    v_f, ns_f = reference_step(grads, reference_init(params, cfg, 4), KEY, cfg,
                               step=0, faults=plan)
    cfg_churn = _cfg(bucketed=True,
                     participation=ParticipationSpec(
                         churn=(ChurnEvent(0, 1, "leave"),)))
    v_c, ns_c = reference_step(grads, reference_init(params, cfg_churn, 4),
                               KEY, cfg_churn, step=0)
    _assert_trees_equal(v_f, v_c, "ghat")
    _assert_trees_equal(ns_f.h_server, ns_c.h_server, "h_server")
    for hf, hc in zip(jax.tree_util.tree_leaves(ns_f.h_worker),
                      jax.tree_util.tree_leaves(ns_c.h_worker)):
        for w in (0, 2, 3):
            np.testing.assert_array_equal(np.asarray(hf[w]), np.asarray(hc[w]))


def test_empty_fault_plan_checksum_is_bitwise_noop():
    """Arming the checksum with no injected faults (--faults checksum) must
    not change a single bit of the round."""
    params, grads = _fixture()
    cfg = _cfg(bucketed=True)
    v0, s0 = reference_step(grads, reference_init(params, cfg, 4), KEY, cfg)
    v1, s1 = reference_step(grads, reference_init(params, cfg, 4), KEY, cfg,
                            step=0, faults=FaultPlan())
    _assert_trees_equal(v0, v1, "ghat")
    _assert_trees_equal(s0.h_worker, s1.h_worker, "h_worker")
    _assert_trees_equal(s0.h_server, s1.h_server, "h_server")


def test_drop_and_delay_faults_exclude_for_scheduled_steps():
    """delay=2 kills the victim's wire for two consecutive steps: perturbing
    its gradient ONLY inside that window (its local h is frozen too, on both
    sides of the comparison) must leave the entire 4-step trajectory —
    including the post-fault step — bitwise unchanged."""
    params, grads = _fixture()
    cfg = _cfg(bucketed=True)
    grads_pert = dict(grads, w=grads["w"].at[2].add(1000.0))
    plan = FaultPlan(events=(FaultEvent(step=1, worker=2, kind="delay",
                                        delay=2),))
    sa = reference_init(params, cfg, 4)
    sb = reference_init(params, cfg, 4)
    for t in range(4):
        ga, gb = grads, (grads_pert if t in (1, 2) else grads)
        va, sa = reference_step(ga, sa, jax.random.fold_in(KEY, t), cfg,
                                step=t, faults=plan)
        vb, sb = reference_step(gb, sb, jax.random.fold_in(KEY, t),
                                cfg, step=t, faults=plan)
        _assert_trees_equal(va, vb, f"ghat leaked at step {t}")
        _assert_trees_equal(sa.h_worker, sb.h_worker, f"h_worker at step {t}")
        _assert_trees_equal(sa.h_server, sb.h_server, f"h_server at step {t}")


def test_checksum_catches_single_bit_flip():
    from repro.core.bucket import add_checksum, verify_checksum

    buf = jnp.arange(64, dtype=jnp.uint8)
    wire = add_checksum(buf)
    _, ok = verify_checksum(wire[None])
    assert bool(ok[0])
    for byte, bits in [(0, 0x01), (13, 0x80), (63, 0xFF)]:
        bad = wire.at[byte].set(wire[byte] ^ bits)
        _, ok = verify_checksum(bad[None])
        assert not bool(ok[0]), (byte, bits)
    # swapping two unequal bytes changes position-weighted sum, not the sum
    sw = wire.at[0].set(wire[1]).at[1].set(wire[0])
    _, ok = verify_checksum(sw[None])
    assert not bool(ok[0])


# ---------------------------------------------------------------------------
# Convergence law: unbiased sampling converges, memoryless degrades
# ---------------------------------------------------------------------------

def test_sampled_diana_converges_memoryless_qsgd_stalls():
    from tests.test_downlink import _run_quadratic

    spec = ParticipationSpec(q=0.5)
    diana = _run_quadratic(CompressionConfig(
        method="diana", p=math.inf, block_size=16, participation=spec),
        steps=1200)
    qsgd = _run_quadratic(CompressionConfig(
        method="qsgd", block_size=16, participation=spec), steps=1200)
    assert diana < 1e-3, f"sampled DIANA should reach the optimum, got {diana}"
    assert qsgd > 10 * diana, (
        f"memoryless QSGD under sampling should stall: qsgd={qsgd:.2e} "
        f"diana={diana:.2e}")


# ---------------------------------------------------------------------------
# Acceptance: distributed == reference bitwise, 4-worker mesh
# ---------------------------------------------------------------------------

def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.parametrize("vr,down", [(False, False), (True, False),
                                     (False, True), (True, True)],
                         ids=["plain", "vr", "down", "vr+down"])
def test_elastic_distributed_bitwise_all_operators(vr, down):
    """Acceptance: under client sampling + straggler dropout + a churn
    leave, ``aggregate_shardmap`` over a real 4-worker mesh equals
    ``reference_step`` BITWISE — ghat and every state leaf — for all five
    registry operators, per-leaf and bucketed, one step from h=0 (exact at
    h=0; multi-step exactness is covered by the trajectory test below)."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np, json, math
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import (ChurnEvent, CompressionConfig, DianaState,
                        ParticipationSpec, VRState, aggregate_shardmap,
                        init_state)
from repro.core.diana import DOWN_FOLD, PART_FOLD, reference_init, reference_step
from repro.launch.mesh import make_mesh
from tests.test_downlink import OPERATORS
from tests.test_convergence_laws import _vr_fixture

VR, DOWN = {vr!r}, {down!r}
mesh = make_mesh((4, 1), ("data", "model"))
n = 4
key = jax.random.PRNGKey(11)
tmap, leaves = jax.tree_util.tree_map, jax.tree_util.tree_leaves
params, grads, snap, mu, g_snap, mu_cand = _vr_fixture(n, key)
spec = ParticipationSpec(q=0.5, dropout=0.2,
                         churn=(ChurnEvent(0, 3, "leave"),))

report = {{}}
for method, kw in OPERATORS:
    for bucketed in (False, True):
        cfg = CompressionConfig(
            method=method, p=math.inf, bucketed=bucketed,
            participation=spec,
            down_method=method if DOWN else None,
            down_k=kw.get("k") if DOWN else None,
            vr=VR, vr_p=0.5 if VR else None,
            **{{k: v for k, v in kw.items() if k != "k"}}, k=kw.get("k", 64))

        ref_state = reference_init(params, cfg, n)
        st = init_state(params, cfg, n)
        vr_kwargs = {{}}
        if VR:
            ref_state = ref_state._replace(
                vr=ref_state.vr._replace(snapshot=snap, mu=mu))
            st = st._replace(vr=st.vr._replace(snapshot=snap, mu=mu))
            vr_kwargs = dict(vr_aux=(g_snap, mu_cand), params=params)
        v_ref, ref_new = reference_step(grads, ref_state, key, cfg,
                                        step=0, **vr_kwargs)

        def body(g_st, snap_st, mu_st, gsnap_st, mucand_st, h_w, h_s, h_d, k):
            own = lambda t: tmap(lambda x: x[0], t)
            vr_st = VRState(snapshot=snap_st, mu=mu_st) if VR else None
            stl = DianaState(h_w, h_s, vr_st, h_d if DOWN else None)
            widx = jax.lax.axis_index("data")
            wkey = jax.random.fold_in(k, widx)
            kw2 = dict(vr_aux=(own(gsnap_st), own(mucand_st)),
                       params_local=params) if VR else {{}}
            if DOWN:
                kw2["down_key"] = jax.random.fold_in(k, DOWN_FOLD)
            ghat, ns = aggregate_shardmap(
                own(g_st), stl, wkey, cfg, axis_names=("data",), n_workers=n,
                part_key=jax.random.fold_in(k, PART_FOLD), step=0,
                worker_index=widx, **kw2)
            nsnap = ns.vr.snapshot if VR else snap_st
            nmu = ns.vr.mu if VR else mu_st
            nhd = ns.h_down if DOWN else h_d
            return ghat, ns.h_worker, ns.h_server, nhd, nsnap, nmu

        sh = lambda t: tmap(lambda _: P("data"), t)
        rep = lambda t: tmap(lambda _: P(), t)
        hd = st.h_down if DOWN else jnp.zeros((1,))
        hd_spec = tmap(lambda _: P(), hd)
        fn = shard_map(body, mesh=mesh,
            in_specs=(sh(grads), sh(snap), sh(mu), sh(g_snap), sh(mu_cand),
                      tmap(lambda _: P("data"), st.h_worker),
                      rep(st.h_server), hd_spec, P()),
            out_specs=(rep(params), tmap(lambda _: P("data"), st.h_worker),
                       rep(st.h_server), hd_spec, sh(snap), sh(mu)),
            axis_names={{"data"}}, check_vma=False)
        ghat, h_w, h_s, h_d, nsnap, nmu = jax.jit(fn)(
            grads, snap, mu, g_snap, mu_cand,
            st.h_worker, st.h_server, hd, key)

        errs = {{
            "g": max(float(jnp.abs(a - b).max()) for a, b in
                     zip(leaves(ghat), leaves(v_ref))),
            "hw": max(float(jnp.abs(a - b).max()) for a, b in
                      zip(leaves(h_w), leaves(ref_new.h_worker))),
            "hs": max(float(jnp.abs(a - b).max()) for a, b in
                      zip(leaves(h_s), leaves(ref_new.h_server))),
        }}
        if DOWN:
            errs["hd"] = max(float(jnp.abs(a - b).max()) for a, b in
                             zip(leaves(h_d), leaves(ref_new.h_down)))
        if VR:
            errs["snap"] = max(float(jnp.abs(a - b).max()) for a, b in
                               zip(leaves(nsnap), leaves(ref_new.vr.snapshot)))
            errs["mu"] = max(float(jnp.abs(a - b).max()) for a, b in
                             zip(leaves(nmu), leaves(ref_new.vr.mu)))
        report[f"{{method}}/{{'bucketed' if bucketed else 'perleaf'}}"] = errs
print(json.dumps(report))
"""
    report = json.loads(run_py(code).strip().splitlines()[-1])
    assert len(report) == 2 * len(OPERATORS)
    for pairing, errs in report.items():
        assert all(v == 0.0 for v in errs.values()), (pairing, errs)


@pytest.mark.parametrize("spec_kind", ["churn-dyadic", "expected-rate"])
def test_elastic_multistep_trajectory_bitwise(spec_kind):
    """5-step distributed-vs-reference trajectories stay bitwise in EXACT
    arithmetic: grid gradients, ``alpha=0.5``, ``p=inf`` and a dyadic
    participation scale (power-of-2 participant counts under a churn-only
    spec, or the 5/8 'expected' rescale), so the seed's FMA-contraction
    caveat (``kernels/ref.py::ref_apply_server``) never manifests and every
    intermediate is exactly representable in both compile contexts."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np, json, math
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import (ChurnEvent, CompressionConfig, DianaState,
                        ParticipationSpec, aggregate_shardmap, init_state)
from repro.core.diana import PART_FOLD, reference_init, reference_step
from repro.launch.mesh import make_mesh
from tests.test_downlink import _grid

KIND = {spec_kind!r}
if KIND == "churn-dyadic":
    spec = ParticipationSpec(churn=(
        ChurnEvent(1, 2, "leave"), ChurnEvent(1, 3, "leave"),
        ChurnEvent(3, 2, "join"), ChurnEvent(3, 3, "join")))
else:
    spec = ParticipationSpec(q=0.5, dropout=0.2, rescale="expected")

mesh = make_mesh((4, 1), ("data", "model"))
n, steps = 4, 5
key0 = jax.random.PRNGKey(11)
tmap, leaves = jax.tree_util.tree_map, jax.tree_util.tree_leaves
params = {{"w": _grid(jax.random.fold_in(key0, 0), (12, 5)),
          "b": _grid(jax.random.fold_in(key0, 1), (9,))}}

report = {{}}
for bucketed in (False, True):
    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16,
                            alpha=0.5, bucketed=bucketed, participation=spec)
    ref_state = reference_init(params, cfg, n)
    st = init_state(params, cfg, n)

    def body(g_st, h_w, h_s, k, t):
        widx = jax.lax.axis_index("data")
        ghat, ns = aggregate_shardmap(
            tmap(lambda x: x[0], g_st), DianaState(h_w, h_s, None, None),
            jax.random.fold_in(k, widx), cfg,
            axis_names=("data",), n_workers=n,
            part_key=jax.random.fold_in(k, PART_FOLD), step=t,
            worker_index=widx)
        return ghat, ns.h_worker, ns.h_server

    sh = lambda t: tmap(lambda _: P("data"), t)
    rep = lambda t: tmap(lambda _: P(), t)
    fn = jax.jit(shard_map(body, mesh=mesh,
        in_specs=(sh(params), tmap(lambda _: P("data"), st.h_worker),
                  rep(st.h_server), P(), P()),
        out_specs=(rep(params), tmap(lambda _: P("data"), st.h_worker),
                   rep(st.h_server)),
        axis_names={{"data"}}, check_vma=False))

    drift = 0.0
    h_w, h_s = st.h_worker, st.h_server
    for t in range(steps):
        key = jax.random.fold_in(key0, t)
        grads = {{
            k2: _grid(jax.random.fold_in(key, 100 + i), (n,) + v.shape)
            for i, (k2, v) in enumerate(params.items())
        }}
        v_ref, ref_state = reference_step(grads, ref_state, key, cfg, step=t)
        ghat, h_w, h_s = fn(grads, h_w, h_s, key, jnp.int32(t))
        drift = max(drift, max(float(jnp.abs(a - b).max()) for a, b in
                               zip(leaves(ghat), leaves(v_ref))))
        drift = max(drift, max(float(jnp.abs(a - b).max()) for a, b in
                               zip(leaves(h_w), leaves(ref_state.h_worker))))
        drift = max(drift, max(float(jnp.abs(a - b).max()) for a, b in
                               zip(leaves(h_s), leaves(ref_state.h_server))))
    report["bucketed" if bucketed else "perleaf"] = drift
print(json.dumps(report))
"""
    report = json.loads(run_py(code).strip().splitlines()[-1])
    assert report == {"perleaf": 0.0, "bucketed": 0.0}, report
