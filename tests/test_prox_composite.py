"""Composite-objective (non-smooth R) path of DIANA — dedicated tier-1 suite.

The paper's iterate is ``x^{k+1} = prox_{gamma R}(x^k - gamma v^k)`` for an
arbitrary proper closed convex ``R`` (Algorithm 1 line 9) — the capability
QSGD/TernGrad lack.  `tests/test_prox.py` checks the closed-form operators in
isolation; this file checks the COMPOSITE path end to end:

* the optimizer-level wiring: ``DianaOptimizer.apply_direction`` actually
  applies ``prox_{lr R}`` after the inner update, with ``gamma = lr``;
* composite convergence: l1-regularized logistic regression under DIANA
  reaches the composite optimum ``f(x) + R(x)`` (not the smooth-only one) and
  produces genuinely sparse iterates;
* composite convergence survives a compressed downlink (the bidirectional
  iterate still supports prox — DESIGN.md §Bidirectional);
* indicator regularizers: the DIANA trajectory NEVER leaves the constraint
  set (the projection runs every step, not only at the end).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step
from repro.core.prox import box_indicator, l1
from repro.optim import DianaOptimizer, momentum
from repro.optim.diana_optimizer import DianaOptState

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# Optimizer-level wiring of the prox step
# ---------------------------------------------------------------------------

def test_apply_direction_applies_prox_with_gamma_eq_lr():
    """``apply_direction`` == inner update followed by ``prox_{lr R}`` — the
    paper's coupling of the prox scale to the stepsize, on the real
    optimizer path (not the hand-rolled benchmark loops)."""
    lam, lr = 0.3, 0.1
    reg = l1(lam)
    opt = DianaOptimizer(CompressionConfig(method="diana", block_size=16),
                         momentum(0.9), regularizer=reg, lr=lr)
    params = {"x": jnp.asarray([0.5, -0.02, 0.011, -2.0])}
    state = opt.init(params, n_workers=2)
    ghat = {"x": jnp.asarray([1.0, -0.5, 0.25, 0.125])}
    new_params, new_state = opt.apply_direction(params, ghat, state, state.diana)

    want = reg.tree_prox({"x": params["x"] - lr * ghat["x"]}, lr)
    np.testing.assert_allclose(np.asarray(new_params["x"]),
                               np.asarray(want["x"]), rtol=1e-6, atol=1e-7)
    assert int(new_state.step) == 1


def test_apply_direction_without_regularizer_is_plain_update():
    opt = DianaOptimizer(CompressionConfig(method="diana", block_size=16),
                         momentum(0.0), lr=0.5)
    params = {"x": jnp.asarray([1.0, -1.0])}
    state = opt.init(params, n_workers=2)
    ghat = {"x": jnp.asarray([0.5, 0.5])}
    new_params, _ = opt.apply_direction(params, ghat, state, state.diana)
    np.testing.assert_allclose(np.asarray(new_params["x"]), [0.75, -1.25],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Composite convergence: l1-regularized logistic regression under DIANA
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def composite_runs():
    """One shared sweep of the composite problem (module-scoped: the
    assertions are cross-run comparisons of the same trajectory family)."""
    from benchmarks.common import fstar_logreg, run_logreg, stoch_problem

    prob = stoch_problem()
    lam = 0.01
    fstar = fstar_logreg(prob, 800, l1=lam)
    runs = {
        "diana": run_logreg("diana", math.inf, steps=400, gamma=1.0, block=8,
                            l1=lam, problem=prob),
        "bidirectional": run_logreg("diana", math.inf, steps=400, gamma=1.0,
                                    block=8, l1=lam, problem=prob,
                                    down_method="diana"),
    }
    return fstar, lam, runs


def test_composite_gap_vanishes_under_diana(composite_runs):
    """DIANA + prox drives the COMPOSITE objective f + lam*||x||_1 to its
    optimum — the quantization noise of the differences vanishes, so the
    prox fixed point is exact (the claim QSGD's non-vanishing noise breaks)."""
    fstar, _, runs = composite_runs
    assert runs["diana"]["final_loss"] - fstar < 1e-4, (
        runs["diana"]["final_loss"], fstar)


def test_composite_iterates_are_sparse(composite_runs):
    """Soft-thresholding every step yields EXACT zeros in the iterate — the
    hallmark of a real prox path (plain subgradient steps only shrink)."""
    _, _, runs = composite_runs
    x = np.asarray(runs["diana"]["x"])
    assert (x == 0.0).sum() > 0, "l1 prox should zero out some coordinates"


def test_composite_survives_compressed_downlink(composite_runs):
    """Bidirectional DIANA (compressed broadcast with downlink memory) keeps
    the composite path intact: same optimum, within noise of uplink-only."""
    fstar, _, runs = composite_runs
    assert runs["bidirectional"]["final_loss"] - fstar < 1e-4, (
        runs["bidirectional"]["final_loss"], fstar)


def test_box_constraint_never_violated_along_trajectory():
    """Indicator-of-box R: every iterate of the DIANA trajectory stays inside
    [lo, hi]^d — the projection is part of the step, not a post-hoc clamp."""
    lo, hi = -0.25, 0.25
    reg = box_indicator(lo, hi)
    rng = np.random.default_rng(2)
    n, d = 4, 16
    A = jnp.asarray(rng.standard_normal((n, 24, d)))
    # unconstrained solution far outside the box, so the constraint binds
    x_true = jnp.asarray(rng.standard_normal(d) * 2.0)
    y = jnp.einsum("wij,j->wi", A, x_true)

    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16)
    params = {"x": jnp.zeros((d,))}
    state = reference_init(params, cfg, n)
    key, gamma = KEY, 0.05
    for t in range(80):
        key = jax.random.fold_in(key, t)
        resid = jnp.einsum("wij,j->wi", A, params["x"]) - y
        g = {"x": jnp.einsum("wij,wi->wj", A, resid) / A.shape[1]}
        v, state = reference_step(g, state, key, cfg)
        params = reg.tree_prox({"x": params["x"] - gamma * v["x"]}, gamma)
        x = np.asarray(params["x"])
        assert x.min() >= lo - 1e-7 and x.max() <= hi + 1e-7, (t, x.min(), x.max())
    # the constraint is active at the solution (the problem actually binds)
    assert np.isclose(np.abs(np.asarray(params["x"])).max(), hi, atol=1e-3)
