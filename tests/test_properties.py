"""Property-based tests (hypothesis) on the system's core invariants."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")

from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (
    CompressionConfig,
    alpha_p,
    dequantize_blocks,
    expected_sparsity,
    lp_norm,
    pack2bit,
    quantization_variance,
    quantize_blocks,
    unpack2bit,
)

FINITE = dict(allow_nan=False, allow_infinity=False, width=32)


@given(hnp.arrays(np.int8, hnp.array_shapes(min_dims=1, max_dims=3, min_side=4, max_side=64),
                  elements=st.integers(-1, 1)))
@settings(max_examples=100, deadline=None)
def test_pack_roundtrip(signs):
    last = signs.shape[-1]
    trim = last - (last % 4)
    if trim == 0:
        return
    s = jnp.asarray(signs[..., :trim])
    np.testing.assert_array_equal(np.asarray(unpack2bit(pack2bit(s))), np.asarray(s))


@given(hnp.arrays(np.float32, st.integers(1, 300),
                  elements=st.floats(-1e3, 1e3, **FINITE)))
@settings(max_examples=100, deadline=None)
def test_norm_ordering(x):
    """||x||_1 >= ||x||_2 >= ||x||_inf — the inequality DIANA's theory rests on."""
    xj = jnp.asarray(x)
    n1, n2, ni = (float(lp_norm(xj, p)) for p in (1, 2, math.inf))
    assert n1 >= n2 - 1e-3 * max(n1, 1)
    assert n2 >= ni - 1e-3 * max(n2, 1)


@given(hnp.arrays(np.float32, st.integers(2, 200),
                  elements=st.floats(-100, 100, **FINITE).filter(
                      lambda v: v == 0 or abs(v) > 1e-6)),
       st.sampled_from([1.0, 2.0, math.inf]))
@settings(max_examples=100, deadline=None)
def test_alpha_p_is_lower_bound(x, p):
    """alpha_p(d) <= ||x||_2^2 / (||x||_1 ||x||_p) for every nonzero x (eq. 12).

    Magnitudes bounded away from subnormals: x^2 underflowing to 0 in f32
    breaks the exact-arithmetic inequality, which is not what we test."""
    xj = jnp.asarray(x)
    n1, np_, n2sq = float(lp_norm(xj, 1)), float(lp_norm(xj, p)), float(jnp.sum(xj * xj))
    if n1 == 0 or np_ == 0 or n2sq == 0:
        return
    assert alpha_p(p, len(x)) <= n2sq / (n1 * np_) * (1 + 1e-4) + 1e-6


@given(st.integers(0, 2**31 - 1),
       st.sampled_from([2.0, math.inf]),
       st.sampled_from([16, 64, 256]))
@settings(max_examples=50, deadline=None)
def test_quantized_support(seed, p, block):
    """Every quantized coordinate is in {-scale_l, 0, +scale_l} of its block,
    and signs never flip (eq. 5)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (block * 3,)) * 10
    q = quantize_blocks(x, jax.random.fold_in(key, 1), p=p, block_size=block)
    signs = np.asarray(q.signs)
    assert set(np.unique(signs)) <= {-1, 0, 1}
    xb = np.asarray(x).reshape(3, block)
    agree = np.sign(xb) == signs
    assert np.all(agree | (signs == 0))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_variance_decreasing_in_p(seed):
    """Lemma 2: Psi is decreasing in p — p=inf has minimal variance."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    v1 = float(quantization_variance(x, 1.0, 64))
    v2 = float(quantization_variance(x, 2.0, 64))
    vi = float(quantization_variance(x, math.inf, 64))
    assert v1 >= v2 - 1e-4 and v2 >= vi - 1e-4


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_sparsity_increasing_in_p(seed):
    """Theorem 1: E||qhat||_0 = ||x||_1/||x||_p increases with p."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    s2 = float(expected_sparsity(x, 2.0, 256))
    si = float(expected_sparsity(x, math.inf, 256))
    assert si >= s2 - 1e-4


@given(st.lists(st.integers(1, 40), min_size=1, max_size=8),
       st.sampled_from([1, 4, 16, 64]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_bucket_layout_roundtrip(sizes, align, seed):
    """BucketLayout flatten/unflatten is the identity on arbitrary pytrees:
    offsets are aligned and disjoint, pads are zero, values survive exactly."""
    from repro.core import BucketLayout

    key = jax.random.PRNGKey(seed)
    tree = {f"leaf{i}": jax.random.normal(jax.random.fold_in(key, i), (s,))
            for i, s in enumerate(sizes)}
    lay = BucketLayout.for_tree(tree, align=align)
    flat = lay.flatten(tree)
    assert flat.shape == (lay.padded_size,) and lay.padded_size % align == 0
    assert lay.size == sum(sizes)
    back = lay.unflatten(flat)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    flat_np = np.asarray(flat)
    covered = np.zeros(lay.padded_size, bool)
    for off, size, ps in zip(lay.offsets, lay.sizes, lay.padded_sizes):
        assert off % align == 0 and not covered[off:off + ps].any()
        covered[off:off + ps] = True
        assert np.all(flat_np[off + size:off + ps] == 0.0)
    assert covered.all()


@given(st.sampled_from(["diana", "qsgd", "terngrad", "dqgd", "none"]),
       st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_compression_config_consistency(method, seed):
    cfg = CompressionConfig(method=method, block_size=64)
    a = cfg.effective_alpha()
    assert (a > 0) == (method == "diana")
    if method == "qsgd":
        assert cfg.effective_p() == 2.0
    if method == "terngrad":
        assert cfg.effective_p() == math.inf
    assert 0 < cfg.theory_alpha_p() <= 1.0


# ---------------------------------------------------------------------------
# Wire-format fusion: fuse/unfuse is exact for ARBITRARY payloads
# ---------------------------------------------------------------------------

_FIELD_DTYPES = {
    "packed": (np.uint8, np.int16),              # ternary nibbles / natural codes
    "scales": (np.float32,),
    "indices": (np.uint8, np.uint16, np.uint32), # narrowed sparse indices
    "values": (np.float32,),
}


@st.composite
def _payloads(draw):
    """Arbitrary multi-field payloads: any subset of fields populated, a
    shared leading dim, odd trailing shapes (0-2 extra dims), mixed dtypes."""
    from repro.core.compressors import Payload

    lead = draw(st.integers(1, 4))
    fields = {}
    for name, dts in _FIELD_DTYPES.items():
        if not draw(st.booleans()):
            continue
        dt = np.dtype(draw(st.sampled_from(dts)))
        shape = (lead, *draw(st.lists(st.integers(1, 5), max_size=2)))
        if dt.kind == "f":
            arr = draw(hnp.arrays(dt, shape,
                                  elements=st.floats(-1e3, 1e3, **FINITE)))
        else:
            arr = draw(hnp.arrays(dt, shape))
        fields[name] = jnp.asarray(arr)
    if not fields:
        fields["values"] = jnp.full((lead,), draw(st.floats(-1, 1, **FINITE)),
                                    jnp.float32)
    return Payload(**fields)


@given(_payloads())
@settings(max_examples=80, deadline=None)
def test_fuse_unfuse_roundtrip_arbitrary_payloads(pay):
    """fuse_payload/unfuse_payload is the bit-exact identity for every field
    combination, dtype and odd leaf shape (compared as raw bytes, so exotic
    float bit patterns cannot hide behind value comparison)."""
    from repro.core.bucket import fuse_payload, payload_recipe, unfuse_payload

    buf = fuse_payload(pay)
    assert buf.dtype == jnp.uint8 and buf.ndim == 2
    back = unfuse_payload(buf, payload_recipe(pay))
    for f, g in zip(pay, back):
        if f is None:
            assert g is None
            continue
        assert g.dtype == f.dtype and g.shape == f.shape
        assert np.asarray(f).tobytes() == np.asarray(g).tobytes()
    # gathered layout: an extra leading worker dim un-fuses row-wise
    stacked = jnp.stack([buf, buf])
    back2 = unfuse_payload(stacked, payload_recipe(pay))
    for f, g in zip(pay, back2):
        if f is not None:
            assert g.shape == (2,) + f.shape
            assert np.asarray(f).tobytes() == np.asarray(g[0]).tobytes()


# ---------------------------------------------------------------------------
# VR-composed encode: unbiasedness survives the control variate
# ---------------------------------------------------------------------------

def _vr_delta(key, d):
    """A control-variated gradient k = g - grad f_j(w) + mu (repro.core.vr):
    the exact input VR-DIANA feeds every compressor."""
    from repro.core import control_variate

    g, g_snap, mu = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(3))
    return control_variate({"x": g}, {"x": g_snap}, {"x": mu})["x"]


@given(st.sampled_from(["diana", "natural", "randk", "none"]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=16, deadline=None)
def test_vr_composed_encode_unbiased(method, seed):
    """E[decode(compress(k))] = k for every unbiased registry operator when
    the input is a VR control-variated gradient — Monte-Carlo over 2048
    independent keys, 6-sigma tolerance on the empirical mean."""
    d, n_draws = 16, 2048
    key = jax.random.PRNGKey(seed)
    delta = _vr_delta(key, d)
    cfg = CompressionConfig(method=method, p=math.inf, block_size=8, k=4)
    comp = cfg.make()
    keys = jax.random.split(jax.random.fold_in(key, 7), n_draws)
    dec = jax.vmap(lambda k: comp.decode(comp.compress(delta, k), d))(keys)
    mean = np.asarray(dec.mean(0))
    se = np.asarray(dec.std(0)) / math.sqrt(n_draws)
    np.testing.assert_array_less(np.abs(mean - np.asarray(delta)),
                                 6.0 * se + 1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_vr_composed_topk_ef_residual_is_exact(seed):
    """The biased operator's contract under VR: decode + residual == input
    EXACTLY (disjoint supports), so error feedback loses nothing."""
    d = 16
    key = jax.random.PRNGKey(seed)
    delta = _vr_delta(key, d)
    comp = CompressionConfig(method="topk_ef", k=4).make()
    pay = comp.compress(delta, key)
    dec = comp.decode(pay, d)
    resid = comp.next_memory(jnp.zeros((d,)), dec, delta)
    np.testing.assert_array_equal(np.asarray(dec + resid), np.asarray(delta))
    assert int((dec != 0).sum()) <= 4
