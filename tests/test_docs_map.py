"""Tier-1 enforcement of the documentation map (tools/check_docs.py).

Runs the same two checks the CI step runs, in-process, so a PR that renames a
symbol cited by docs/paper_map.md or deletes a DESIGN.md section that module
docstrings cite fails locally too — the reproduction's claim-by-claim audit
trail can never silently rot.
"""

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


def test_design_citations_resolve():
    errors = check_docs.check_design_citations(REPO)
    assert not errors, "\n".join(errors)


def test_paper_map_references_resolve():
    errors = check_docs.check_paper_map(REPO)
    assert not errors, "\n".join(errors)


def test_paper_map_covers_required_claims():
    """The acceptance surface: Lemmas 1-2, Theorem 1 and Algorithm 1 are all
    mapped (by name) in docs/paper_map.md."""
    with open(os.path.join(REPO, "docs", "paper_map.md")) as f:
        text = f.read()
    for claim in ("Lemma 1", "Lemma 2", "Theorem 1", "Algorithm 1", "VR-DIANA"):
        assert claim in text, f"paper_map.md must cover {claim!r}"


def test_linter_catches_bad_reference(tmp_path):
    """The linter is not vacuous: a fabricated bad citation and a bad symbol
    reference are both flagged."""
    repo = tmp_path
    (repo / "DESIGN.md").write_text("## §1 Real\n")
    (repo / "docs").mkdir()
    (repo / "docs" / "paper_map.md").write_text(
        "see `src/repro/core/quantization.py::no_such_symbol`\n"
        "and `missing/dir/`\n")
    # built at runtime so the real-repo scan never sees this bad citation
    bad_cite = '"""cites ' + "DESIGN" + ".md §7" + '."""\n'
    (repo / "mod.py").write_text(bad_cite)
    errs = check_docs.check_design_citations(str(repo))
    assert len(errs) == 1 and "§7" in errs[0]
    # the src/ import check runs against the real repo's sys.path
    (repo / "src").mkdir()
    import shutil

    shutil.copytree(os.path.join(REPO, "src", "repro"),
                    repo / "src" / "repro",
                    ignore=shutil.ignore_patterns("__pycache__"))
    errs = check_docs.check_paper_map(str(repo))
    assert any("no_such_symbol" in e for e in errs)
    assert any("missing/dir/" in e for e in errs)
