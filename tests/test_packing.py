"""2-bit packing roundtrip tests."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PACK_FACTOR, pack2bit, packed_nbytes, unpack2bit


def test_roundtrip_exhaustive_byte():
    """All 3^4 = 81 valid sign nibbles roundtrip through one byte."""
    combos = np.array(list(itertools.product([-1, 0, 1], repeat=4)), dtype=np.int8)
    packed = pack2bit(jnp.asarray(combos))
    assert packed.shape == (81, 1) and packed.dtype == jnp.uint8
    back = unpack2bit(packed)
    np.testing.assert_array_equal(np.asarray(back), combos)


@pytest.mark.parametrize("shape", [(8,), (3, 16), (2, 5, 64), (1, 128)])
def test_roundtrip_random(shape):
    rng = np.random.default_rng(0)
    signs = rng.integers(-1, 2, size=shape).astype(np.int8)
    back = unpack2bit(pack2bit(jnp.asarray(signs)))
    np.testing.assert_array_equal(np.asarray(back), signs)


def test_compression_ratio():
    assert packed_nbytes(1024) == 256
    assert packed_nbytes(1) == 1
    assert PACK_FACTOR == 4


def test_rejects_unaligned():
    with pytest.raises(ValueError):
        pack2bit(jnp.zeros((7,), jnp.int8))


def test_unpack_trim():
    signs = jnp.asarray(np.tile([1, -1, 0, 1], 4).astype(np.int8))
    packed = pack2bit(signs)
    assert unpack2bit(packed, n=10).shape == (10,)
