"""Data pipeline tests: determinism, heterogeneity, spec conformance."""

import numpy as np

from repro.configs import get_config, get_shape, input_specs, reduced
from repro.configs.base import ShapeConfig
from repro.configs.diana_paper import LogRegProblem
from repro.data import LMStream, logistic_loss_and_grad, logreg_data, make_lm_batch


def test_lm_stream_deterministic():
    a = LMStream(vocab=50, seq_len=12, batch=3, seed=7).batch_at(5)
    b = LMStream(vocab=50, seq_len=12, batch=3, seed=7).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = LMStream(vocab=50, seq_len=12, batch=3, seed=8).batch_at(5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_stream_has_structure():
    """The affine grammar makes next-token largely predictable (learnable)."""
    b = LMStream(vocab=97, seq_len=256, batch=8, seed=0, noise=0.0, n_workers=1).batch_at(0)
    t = b["tokens"]
    pred = (t[:, :-1] * 3 + 7) % 97
    agreement = (pred == t[:, 1:]).mean()
    assert agreement > 0.95


def test_make_lm_batch_matches_specs():
    cfg = reduced(get_config("internvl2-2b"))
    shape = ShapeConfig("t", seq_len=64, global_batch=4, kind="train")
    batch = make_lm_batch(cfg, shape, step=0)
    specs = input_specs(cfg, shape)
    assert set(batch) == set(specs)
    for k in specs:
        assert tuple(batch[k].shape) == tuple(specs[k].shape), k


def test_logreg_heterogeneous_workers():
    X, y = logreg_data(LogRegProblem(n_samples=200, dim=16, n_workers=4, seed=3))
    assert X.shape == (4, 50, 16) and set(np.unique(y)) == {-1.0, 1.0}
    # distributions differ across workers (the paper's "loc. data")
    means = X.mean(axis=(1,))
    assert np.linalg.norm(means[0] - means[-1]) > 1e-3


def test_logistic_grad_matches_finite_diff():
    X, y = logreg_data(LogRegProblem(n_samples=64, dim=8, n_workers=1))
    w = np.random.default_rng(0).standard_normal(8) * 0.1
    loss, grad = logistic_loss_and_grad(w, X[0], y[0], l2=0.01)
    eps = 1e-5
    for j in range(8):
        wp, wm = w.copy(), w.copy()
        wp[j] += eps; wm[j] -= eps
        fd = (logistic_loss_and_grad(wp, X[0], y[0], 0.01)[0]
              - logistic_loss_and_grad(wm, X[0], y[0], 0.01)[0]) / (2 * eps)
        assert abs(fd - grad[j]) < 1e-4


def test_decode_specs_are_one_token():
    cfg = get_config("llama3.2-1b")
    specs = input_specs(cfg, get_shape("decode_32k"))
    assert specs["tokens"].shape == (128, 1)
    specs = input_specs(cfg, get_shape("long_500k"))
    assert specs["tokens"].shape == (1, 1)
