"""Inner-optimizer and schedule tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.core.prox import l1
from repro.optim import (
    DianaOptimizer,
    adamw,
    constant_schedule,
    diana_decreasing_schedule,
    momentum,
    sgd,
    warmup_cosine_schedule,
)

KEY = jax.random.PRNGKey(0)


def _quadratic_min(opt, steps=300, lr=0.1):
    """min 0.5||x - t||^2 — every optimizer must solve this."""
    t = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    for k in range(steps):
        g = {"x": params["x"] - t}
        upd, state = opt.update(g, state, params, jnp.asarray(lr))
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
    return float(jnp.linalg.norm(params["x"] - t))


@pytest.mark.parametrize("make,lr", [(sgd, 0.3), (lambda: momentum(0.9), 0.05),
                                     (lambda: adamw(), 0.05)])
def test_optimizers_converge(make, lr):
    assert _quadratic_min(make(), lr=lr) < 1e-2


def test_momentum_matches_paper_recursion():
    """v^k = beta v^{k-1} + g; update = -lr v^k."""
    opt = momentum(0.5)
    params = {"x": jnp.zeros(2)}
    state = opt.init(params)
    g = {"x": jnp.ones(2)}
    upd1, state = opt.update(g, state, params, 1.0)
    upd2, state = opt.update(g, state, params, 1.0)
    np.testing.assert_allclose(np.asarray(upd1["x"]), -1.0)
    np.testing.assert_allclose(np.asarray(upd2["x"]), -1.5)


def test_adamw_weight_decay():
    opt = adamw(weight_decay=0.1)
    params = {"x": jnp.full((2,), 10.0)}
    state = opt.init(params)
    upd, _ = opt.update({"x": jnp.zeros(2)}, state, params, 0.1)
    assert float(upd["x"][0]) < 0  # decay pulls toward 0 even with zero grad


def test_schedules():
    assert float(constant_schedule(0.1)(jnp.asarray(7))) == pytest.approx(0.1)
    sch = diana_decreasing_schedule(mu=1.0, theta=4.0)
    assert float(sch(jnp.asarray(0))) == pytest.approx(0.5)      # 2/(0+4)
    assert float(sch(jnp.asarray(4))) == pytest.approx(0.25)     # 2/(4+4)
    wc = warmup_cosine_schedule(1.0, warmup=10, total=110)
    assert float(wc(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-5)
    assert float(wc(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-5)


def test_diana_optimizer_prox_application():
    """apply_direction runs inner update then prox_{lr R}."""
    comp = CompressionConfig(block_size=4)
    opt = DianaOptimizer(comp, sgd(), regularizer=l1(1.0), lr=0.5)
    params = {"x": jnp.asarray([2.0, 0.1, -3.0])}
    state = opt.init(params, n_workers=2)
    ghat = {"x": jnp.zeros(3)}
    new_params, new_state = opt.apply_direction(params, ghat, state, state.diana)
    # prox_{0.5 * l1}: soft-threshold by 0.5
    np.testing.assert_allclose(np.asarray(new_params["x"]), [1.5, 0.0, -2.5])
    assert int(new_state.step) == 1


def test_diana_state_is_flat_and_sized():
    comp = CompressionConfig(block_size=4)
    opt = DianaOptimizer(comp, momentum(0.9), lr=0.1)
    params = {"w": jnp.zeros((4, 6)), "b": jnp.zeros((3,))}
    state = opt.init(params, n_workers=5)
    assert state.diana.h_worker["w"].shape == (5, 24)
    assert state.diana.h_server["b"].shape == (3,)


def test_diana_optimizer_vr_knob_and_refresh_snapshot():
    """The vr= knob grows the L-SVRG slot and refresh_snapshot (epoch-mode /
    warm-start) installs params + per-worker mu on every worker at once."""
    comp = CompressionConfig(block_size=4)
    opt = DianaOptimizer(comp, momentum(0.9), lr=0.1, vr=True, vr_p=0.25)
    assert opt.variance_reduced and opt.compression.vr_p == 0.25
    params = {"w": jnp.full((4, 6), 2.0), "b": jnp.zeros((3,))}
    state = opt.init(params, n_workers=3)
    assert state.diana.vr is not None
    assert state.diana.vr.snapshot["w"].shape == (3, 4, 6)
    np.testing.assert_array_equal(np.asarray(state.diana.vr.mu["w"]), 0.0)

    mu = {"w": jnp.arange(3 * 24, dtype=jnp.float32).reshape(3, 4, 6),
          "b": jnp.ones((3, 3))}
    new_x = {"w": jnp.full((4, 6), 5.0), "b": jnp.full((3,), -1.0)}
    state = opt.refresh_snapshot(state, new_x, mu)
    np.testing.assert_array_equal(np.asarray(state.diana.vr.snapshot["w"]), 5.0)
    np.testing.assert_array_equal(np.asarray(state.diana.vr.snapshot["b"]), -1.0)
    np.testing.assert_array_equal(np.asarray(state.diana.vr.mu["w"]),
                                  np.arange(3 * 24, dtype=np.float32).reshape(3, 4, 6))

    # vr off: no slot, refresh_snapshot refuses
    plain = DianaOptimizer(comp, momentum(0.9), lr=0.1)
    pstate = plain.init(params, n_workers=3)
    assert pstate.diana.vr is None
    with pytest.raises(AssertionError):
        plain.refresh_snapshot(pstate, new_x, mu)
