"""Proximal operator tests: closed forms vs numerical argmin, nonexpansiveness
(paper eq. 9), and tree mapping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prox

KEY = jax.random.PRNGKey(0)


def _numeric_prox(value_fn, u, gamma, lo=-10, hi=10, n=400_001):
    """Brute-force argmin_v gamma*R(v) + 0.5 (v-u)^2 on a grid (scalar)."""
    v = np.linspace(lo, hi, n)
    obj = gamma * value_fn(v) + 0.5 * (v - u) ** 2
    return v[np.argmin(obj)]


@pytest.mark.parametrize("u", [-3.0, -0.1, 0.0, 0.4, 2.5])
def test_l1_matches_numeric(u):
    lam, gamma = 0.7, 0.5
    r = prox.l1(lam)
    got = float(r.prox(jnp.asarray(u), gamma))
    want = _numeric_prox(lambda v: lam * np.abs(v), u, gamma)
    assert got == pytest.approx(want, abs=1e-4)


@pytest.mark.parametrize("u", [-2.0, 0.3, 5.0])
def test_l2_matches_numeric(u):
    lam, gamma = 1.3, 0.25
    r = prox.l2(lam)
    got = float(r.prox(jnp.asarray(u), gamma))
    want = _numeric_prox(lambda v: 0.5 * lam * v * v, u, gamma)
    assert got == pytest.approx(want, abs=1e-4)


def test_elastic_net_reduces():
    en = prox.elastic_net(0.5, 0.0)
    l1 = prox.l1(0.5)
    u = jax.random.normal(KEY, (64,))
    np.testing.assert_allclose(np.asarray(en.prox(u, 0.3)), np.asarray(l1.prox(u, 0.3)))


def test_box_projection():
    r = prox.box_indicator(-1.0, 1.0)
    u = jnp.asarray([-5.0, -0.5, 0.0, 0.9, 3.0])
    np.testing.assert_allclose(np.asarray(r.prox(u, 17.0)), [-1, -0.5, 0, 0.9, 1])


def test_nonneg():
    r = prox.nonneg_indicator()
    u = jnp.asarray([-2.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(r.prox(u, 1.0)), [0, 0, 3])


@pytest.mark.parametrize("make", [
    lambda: prox.l1(0.7), lambda: prox.l2(2.0),
    lambda: prox.elastic_net(0.3, 0.4), lambda: prox.box_indicator(-2, 2),
])
def test_nonexpansive(make):
    """||prox(u) - prox(v)|| <= ||u - v|| (paper eq. 9)."""
    r = make()
    k1, k2 = jax.random.split(KEY)
    u = jax.random.normal(k1, (128,)) * 3
    v = jax.random.normal(k2, (128,)) * 3
    d_out = float(jnp.linalg.norm(r.prox(u, 0.7) - r.prox(v, 0.7)))
    d_in = float(jnp.linalg.norm(u - v))
    assert d_out <= d_in + 1e-6


def test_tree_prox_and_value():
    r = prox.l1(1.0)
    tree = {"a": jnp.asarray([3.0, -0.2]), "b": jnp.asarray([[0.5]])}
    out = r.tree_prox(tree, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), [2.0, 0.0])
    assert float(r.tree_value(tree)) == pytest.approx(3.7)
