"""Unit tests for the p-quantization operators against the paper's theory:
unbiasedness + variance (Lemma 2), expected sparsity (Theorem 1), alpha_p
closed forms (Lemma 1)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    alpha_p,
    dequantize_blocks,
    expected_sparsity,
    lp_norm,
    quantization_variance,
    quantize_blocks,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("p", [1.0, 2.0, math.inf])
@pytest.mark.parametrize("block", [64, 128, 1000])
def test_unbiased_and_moments(p, block):
    d = 1000
    x = jax.random.normal(KEY, (d,))
    n_samples = 3000
    ks = jax.random.split(jax.random.PRNGKey(1), n_samples)
    f = jax.jit(jax.vmap(
        lambda k: dequantize_blocks(quantize_blocks(x, k, p=p, block_size=block), shape=(d,))
    ))
    samp = np.asarray(f(ks))
    xv = np.asarray(x)

    # unbiasedness: per-coordinate CLT bound using the THEORETICAL variance
    # |x_j| (scale_l - |x_j|) from Lemma 2 (sample variance is 0 for coords
    # whose Bernoulli never fires, which breaks an empirical z-test).
    from repro.core.quantization import pad_to_blocks

    blocks = np.asarray(pad_to_blocks(x, block))
    scales = np.asarray(lp_norm(jnp.asarray(blocks), p, axis=-1))
    theo_var = (np.abs(blocks) * (scales[:, None] - np.abs(blocks))).reshape(-1)[:d]
    # floor the variance: near-deterministic coords (prob ~ 0 or ~ 1) break
    # the CLT normal approximation at this sample size
    z = np.abs(samp.mean(0) - xv) / np.sqrt(np.maximum(theo_var, 1e-3) / n_samples)
    assert np.max(z) < 6.0, f"bias z-score {np.max(z)}"

    # total variance matches Psi (Lemma 2, second claim) within 5%
    emp = float(((samp - xv) ** 2).sum(-1).mean())
    theo = float(quantization_variance(x, p, block))
    assert abs(emp - theo) / theo < 0.05

    # expected sparsity matches Theorem 1 within 5%
    emp_nnz = float((samp != 0).sum(-1).mean())
    theo_nnz = float(expected_sparsity(x, p, block))
    assert abs(emp_nnz - theo_nnz) / theo_nnz < 0.05


def test_sparsity_bound_thm1():
    """E||qhat||_0 = ||x||_1/||x||_p <= d^{1-1/p} (Thm 1, eq. 7)."""
    d = 512
    x = jax.random.normal(KEY, (d,))
    for p, bound in [(1.0, 1.0), (2.0, math.sqrt(d)), (math.inf, d)]:
        assert float(expected_sparsity(x, p, d)) <= bound + 1e-3


def test_values_are_ternary_times_scale():
    x = jax.random.normal(KEY, (256,))
    q = quantize_blocks(x, KEY, p=math.inf, block_size=64)
    assert q.signs.dtype == jnp.int8
    assert set(np.unique(np.asarray(q.signs))) <= {-1, 0, 1}
    dense = np.asarray(dequantize_blocks(q, shape=(256,)))
    scales = np.repeat(np.asarray(q.scales), 64)
    mask = dense != 0
    np.testing.assert_allclose(np.abs(dense[mask]), scales[mask], rtol=1e-6)


def test_zero_vector():
    q = quantize_blocks(jnp.zeros(128), KEY, p=2, block_size=64)
    assert float(jnp.abs(dequantize_blocks(q, shape=(128,))).max()) == 0.0


def test_infty_prob_is_valid():
    """p=inf: |x_j|/||x||_inf <= 1 always — all-equal blocks fire every coord."""
    x = jnp.ones(64)
    q = quantize_blocks(x, KEY, p=math.inf, block_size=64)
    assert int((q.signs != 0).sum()) == 64  # prob exactly 1 everywhere


def test_alpha_p_closed_forms():
    """Lemma 1: alpha_1 = 1/d, alpha_2 = 1/sqrt(d), alpha_inf = 2/(1+sqrt(d))."""
    for d in (2, 16, 100, 4096):
        assert alpha_p(1, d) == pytest.approx(1 / d)
        assert alpha_p(2, d) == pytest.approx(1 / math.sqrt(d))
        assert alpha_p(math.inf, d) == pytest.approx(2 / (1 + math.sqrt(d)))
        # monotone in p (Lemma 1)
        assert alpha_p(1, d) <= alpha_p(2, d) <= alpha_p(math.inf, d)
    # decreasing in d
    assert alpha_p(2, 10) > alpha_p(2, 100)
    assert alpha_p(math.inf, 10) > alpha_p(math.inf, 100)


def test_alpha_inf_is_tight():
    """The minimiser x = (1, a*, ..., a*) with a* = 1/(1+sqrt(d)) attains
    alpha_inf(d) (see the paper's Lemma 1 proof)."""
    d = 37
    a = 1.0 / (1.0 + math.sqrt(d))
    x = jnp.concatenate([jnp.ones(1), jnp.full((d - 1,), a)])
    ratio = float(jnp.sum(x * x) / (lp_norm(x, 1) * lp_norm(x, math.inf)))
    assert ratio == pytest.approx(alpha_p(math.inf, d), rel=1e-6)


def test_block_padding_roundtrip():
    """Non-multiple lengths zero-pad: dequant returns the original shape."""
    x = jax.random.normal(KEY, (7, 13))
    q = quantize_blocks(x, KEY, p=2, block_size=32)
    y = dequantize_blocks(q, shape=(7, 13))
    assert y.shape == (7, 13)
