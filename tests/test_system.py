"""End-to-end behaviour tests for the full system (single device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.data import make_lm_batch
from repro.launch.mesh import make_mesh
from repro.launch.train import build_train_step, init_train_state, make_optimizer

SHAPE = ShapeConfig("sys", seq_len=32, global_batch=4, kind="train")


def _train(cfg, steps=8, lr=0.02):
    mesh = make_mesh((1, 1), ("data", "model"))
    opt = make_optimizer(cfg, lr=lr)
    key = jax.random.PRNGKey(0)
    params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
    step_fn = build_train_step(cfg, opt, mesh, SHAPE)
    losses = []
    for step in range(steps):
        batch = jax.tree_util.tree_map(jnp.asarray, make_lm_batch(cfg, SHAPE, step))
        params, opt_state, m = step_fn(params, opt_state, batch, jax.random.fold_in(key, step))
        losses.append(float(m["loss"]))
    return losses, params, opt_state


def test_end_to_end_training_loss_decreases():
    cfg = reduced(get_config("llama3.2-1b"))
    losses, _, _ = _train(cfg, steps=10)
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses))


def test_compression_none_vs_diana_comparable():
    """DIANA training must track uncompressed training (same order of loss)."""
    from dataclasses import replace

    cfg = reduced(get_config("llama3.2-1b"))
    l_diana, _, _ = _train(cfg, steps=10)
    l_none, _, _ = _train(replace(cfg, compression="none"), steps=10)
    assert l_diana[-1] < l_diana[0]
    assert l_none[-1] < l_none[0]
    assert abs(l_diana[-1] - l_none[-1]) < 1.0, (l_diana[-1], l_none[-1])


def test_h_memory_accumulates_and_is_flat():
    cfg = reduced(get_config("mamba2-130m"))
    _, _, opt_state = _train(cfg, steps=4)
    h = opt_state.diana.h_worker
    leaves = jax.tree_util.tree_leaves(h)
    assert all(l.ndim == 2 for l in leaves)  # (n_workers, d_leaf)
    assert sum(float(jnp.abs(l).sum()) for l in leaves) > 0


def test_checkpoint_resume_bitwise():
    """save -> restore -> continue == continue directly."""
    import tempfile

    from repro.checkpoint import restore_checkpoint, save_checkpoint

    cfg = reduced(get_config("llama3.2-1b"))
    mesh = make_mesh((1, 1), ("data", "model"))
    opt = make_optimizer(cfg, lr=0.02)
    key = jax.random.PRNGKey(0)
    params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
    step_fn = build_train_step(cfg, opt, mesh, SHAPE)

    for step in range(3):
        batch = jax.tree_util.tree_map(jnp.asarray, make_lm_batch(cfg, SHAPE, step))
        params, opt_state, _ = step_fn(params, opt_state, batch, jax.random.fold_in(key, step))

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"params": params, "opt": opt_state})
        restored, _ = restore_checkpoint(d, {"params": params, "opt": opt_state})

    batch = jax.tree_util.tree_map(jnp.asarray, make_lm_batch(cfg, SHAPE, 3))
    k = jax.random.fold_in(key, 3)
    p_a, _, m_a = step_fn(params, opt_state, batch, k)
    p_b, _, m_b = step_fn(restored["params"], restored["opt"], batch, k)
    assert float(m_a["loss"]) == float(m_b["loss"])
    for a, b in zip(jax.tree_util.tree_leaves(p_a), jax.tree_util.tree_leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_qsgd_and_terngrad_train():
    from dataclasses import replace

    cfg = reduced(get_config("llama3.2-1b"))
    for method in ("qsgd", "terngrad"):
        losses, _, _ = _train(replace(cfg, compression=method), steps=6, lr=0.01)
        assert all(np.isfinite(losses)), (method, losses)
