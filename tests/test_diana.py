"""Algorithm-level tests: DIANA's headline claims on controlled convex problems.

* Noiseless strongly convex: DIANA converges LINEARLY to the EXACT optimum;
  QSGD/TernGrad with the same constant step stall at a quantization-noise
  floor (the paper's core superiority claim, Thm 2 vs Thm 10).
* The memory h_i converges to grad f_i(x*) (the mechanism behind the rate).
* p=inf converges at least as fast as p=2 (optimal norm power).
* Prox/l1 compatibility: DIANA + soft-thresholding finds sparse solutions.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step
from repro.core.prox import l1

KEY = jax.random.PRNGKey(0)


def quadratic_problem(n_workers=4, d=64, seed=0):
    """f_i(x) = 0.5||A_i x - b_i||^2 — heterogeneous strongly convex pieces."""
    rng = np.random.default_rng(seed)
    As = rng.standard_normal((n_workers, d, d)) / math.sqrt(d)
    As += np.eye(d) * 0.8                      # well-conditioned
    bs = rng.standard_normal((n_workers, d))
    A_all = np.concatenate(As, 0)
    b_all = np.concatenate(bs, 0)
    x_star = np.linalg.lstsq(A_all, b_all, rcond=None)[0]
    As, bs = jnp.asarray(As), jnp.asarray(bs)

    def grads(x):
        return jnp.einsum("wij,wjk->wik", jnp.swapaxes(As, 1, 2),
                          (jnp.einsum("wij,j->wi", As, x) - bs)[..., None])[..., 0]

    return grads, jnp.asarray(x_star), As, bs


def run_method(method, p, steps, gamma, *, beta=0.0, block=16, alpha=None, d=64):
    grads_fn, x_star, As, bs = quadratic_problem(d=d)
    cfg = CompressionConfig(method=method, p=p, block_size=block, alpha=alpha)
    params = {"x": jnp.zeros((d,))}
    state = reference_init(params, cfg, 4)
    dists = []
    key = KEY
    for k in range(steps):
        key = jax.random.fold_in(key, k)
        g = {"x": grads_fn(params["x"])}
        v, state = reference_step(g, state, key, cfg, beta=beta)
        params = {"x": params["x"] - gamma * v["x"]}
        dists.append(float(jnp.linalg.norm(params["x"] - x_star)))
    return np.array(dists), state, x_star


def test_diana_linear_convergence_to_exact_optimum():
    dists, _, _ = run_method("diana", math.inf, steps=600, gamma=0.3)
    assert dists[-1] < 1e-4, f"DIANA should reach the exact optimum, got {dists[-1]}"
    # linear rate in the pre-float32-floor phase: an order of magnitude per
    # ~50 steps early on (the floor is hit long before step 600)
    assert dists[60] < dists[10] * 1e-1
    assert dists[120] < dists[60] * 1e-1 or dists[120] < 1e-5


def test_qsgd_stalls_at_noise_floor():
    """Algorithm 2 (alpha=0) with constant step cannot converge to the optimum
    — quantization noise of the gradient itself does not vanish."""
    d_diana, _, _ = run_method("diana", 2.0, steps=600, gamma=0.1)
    d_qsgd, _, _ = run_method("qsgd", 2.0, steps=600, gamma=0.1)
    assert d_diana[-1] < 1e-3
    assert d_qsgd[-1] > 10 * d_diana[-1], (
        f"QSGD should stall: qsgd={d_qsgd[-1]:.2e} diana={d_diana[-1]:.2e}")


def test_h_learns_local_gradients_at_optimum():
    """h_i -> grad f_i(x*) (Lemma 4's fixed point)."""
    dists, state, x_star = run_method("diana", math.inf, steps=800, gamma=0.3)
    grads_fn, x_star, As, bs = quadratic_problem()
    g_star = np.asarray(grads_fn(x_star))                    # (n, d)
    h = np.asarray(state.h_worker["x"])
    rel = np.linalg.norm(h - g_star) / max(np.linalg.norm(g_star), 1e-9)
    assert rel < 0.05, f"h_i should track grad f_i(x*), rel err {rel:.3f}"


def test_p_inf_no_worse_than_p2():
    """Optimal norm power (Cor. 1): p=inf iteration complexity <= p=2."""
    d_inf, _, _ = run_method("diana", math.inf, steps=400, gamma=0.25)
    d_2, _, _ = run_method("diana", 2.0, steps=400, gamma=0.25)
    assert d_inf[-1] <= d_2[-1] * 3.0  # allow noise, inf must not be much worse


def test_terngrad_is_qsgd_with_p_inf():
    """TernGrad == Algorithm 2 with p=inf (same code path, Sec. 3)."""
    cfg_t = CompressionConfig(method="terngrad", block_size=16)
    cfg_q = CompressionConfig(method="qsgd", block_size=16)
    assert cfg_t.effective_p() == math.inf and cfg_q.effective_p() == 2.0
    assert not cfg_t.uses_memory and not cfg_q.uses_memory


def test_momentum_version_converges():
    d_m, _, _ = run_method("diana", math.inf, steps=600, gamma=0.05, beta=0.9)
    assert d_m[-1] < 1e-3


def test_diana_with_l1_prox_finds_sparse_solution():
    """Non-smooth R support: lasso via DIANA + prox — QSGD can't do this."""
    rng = np.random.default_rng(1)
    d, n_workers = 32, 4
    x_true = np.zeros(d); x_true[:4] = (1.0, -2.0, 3.0, 1.5)
    A = rng.standard_normal((n_workers, 40, d))
    y = jnp.asarray(A @ x_true)
    A = jnp.asarray(A)
    lam = 0.05
    reg = l1(lam)
    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16)
    params = {"x": jnp.zeros((d,))}
    state = reference_init(params, cfg, n_workers)
    gamma = 0.02
    key = KEY
    for k in range(1500):
        key = jax.random.fold_in(key, k)
        resid = jnp.einsum("wij,j->wi", A, params["x"]) - y
        g = {"x": jnp.einsum("wij,wi->wj", A, resid) / A.shape[1]}
        v, state = reference_step(g, state, key, cfg)
        params = reg.tree_prox({"x": params["x"] - gamma * v["x"]}, gamma)
    x = np.asarray(params["x"])
    assert np.abs(x[6:]).max() < 5e-2, "tail coords should be (near) zero"
    assert np.linalg.norm(x[:4] - x_true[:4]) < 0.5


def test_none_method_is_exact_mean():
    cfg = CompressionConfig(method="none")
    params = {"x": jnp.zeros((8,))}
    state = reference_init(params, cfg, 3)
    g = {"x": jnp.stack([jnp.full((8,), v) for v in (1.0, 2.0, 3.0)])}
    v, _ = reference_step(g, state, KEY, cfg)
    np.testing.assert_allclose(np.asarray(v["x"]), 2.0)
