"""Full kernel coverage (ISSUE 6): every registry operator's kernel route is
bitwise-equal to its lax fallback, end to end.

* oracle sweeps for the natural / sparse / dense kernels against
  ``repro.kernels.ref`` (the ternary family's sweeps live in
  ``tests/test_kernels.py``);
* operator-level kernel == fallback through ``reference_step`` — 5 operators
  x per-leaf/bucketed x f32/bf16 gradient dtypes;
* jaxpr counting: the fused ``decode_sum_apply`` server tail is ONE pallas
  launch per operator (per group — the grouped path runs one such tail per
  policy group, counted on the distributed round in ``tests/test_bucket.py``);
* the ``tools/check_kernels.py`` linter runs clean on the repo and catches
  seeded capability/oracle rot (mirroring ``tests/test_policy.py``'s
  treatment of ``check_policy``).
"""

import os
import sys
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step
from repro.kernels import ops as kops
from repro.kernels import ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(0)

# (registry method, config kwargs) — one row per operator family; the
# ternary block is 128 because the quantize kernels are VPU-lane shaped
# (kernels/quantize_pack.py rejects narrower blocks)
OPERATORS = [
    ("diana", dict(block_size=128)),
    ("natural", {}),
    ("randk", dict(k=9)),
    ("topk_ef", dict(k=9)),
    ("none", {}),
]


def _normal(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# Oracle sweeps: natural / sparse / dense kernels vs repro.kernels.ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [16, 100, 257])
def test_nat_pack_matches_ref(d):
    x = _normal(KEY, (d,)) * jnp.exp2(_normal(jax.random.fold_in(KEY, 1), (d,)) * 8)
    x = x.at[0].set(0.0)
    bits = jax.random.bits(jax.random.fold_in(KEY, 2), (d,), dtype=jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(kops.nat_pack_op(x, bits)),
        np.asarray(ref.ref_nat_pack(x, bits)))


@pytest.mark.parametrize("n,d", [(1, 16), (4, 100), (7, 257)])
def test_nat_decode_sum_matches_ref(n, d):
    codes = jax.random.randint(KEY, (n, d), -40, 40, jnp.int16)
    codes = jnp.where(codes == 0, jnp.int16(0), codes + jnp.int16(np.sign(np.asarray(codes)) * ref.NAT_BIAS))
    s = ref.ref_nat_decode_sum(codes)
    np.testing.assert_array_equal(np.asarray(kops.nat_decode_sum_op(codes)), np.asarray(s))
    np.testing.assert_array_equal(
        np.asarray(kops.nat_decode_sum_mean_op(codes)),
        np.asarray(jax.jit(lambda s: s / jnp.float32(n))(s)))


@pytest.mark.parametrize("n,k,d", [(1, 4, 32), (4, 9, 100), (6, 16, 257)])
def test_sparse_decode_sum_matches_ref(n, k, d):
    idx = jnp.stack([
        jax.lax.top_k(jax.random.bits(jax.random.fold_in(KEY, i), (d,), dtype=jnp.uint32), k)[1]
        for i in range(n)
    ])
    values = _normal(jax.random.fold_in(KEY, 99), (n, k))
    scale = jnp.full((k,), jnp.float32(d / k))
    want = ref.ref_sparse_decode_sum(idx, values, scale, d)
    np.testing.assert_array_equal(
        np.asarray(kops.sparse_decode_sum_op(idx, values, scale, d=d)),
        np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(kops.sparse_decode_sum_mean_op(idx, values, scale, d=d)),
        np.asarray(jax.jit(lambda s: s / jnp.float32(n))(want)))


def test_sparse_gather_matches_ref():
    d, k = 127, 17
    x = _normal(KEY, (d,))
    idx = jax.lax.top_k(jax.random.bits(jax.random.fold_in(KEY, 1), (d,), dtype=jnp.uint32), k)[1]
    np.testing.assert_array_equal(
        np.asarray(kops.sparse_gather_op(x, idx)),
        np.asarray(ref.ref_sparse_gather(x, idx)))


@pytest.mark.parametrize("n,d", [(1, 16), (5, 213)])
def test_dense_decode_sum_matches_ref(n, d):
    values = _normal(KEY, (n, d))
    want = ref.ref_dense_decode_sum(values)
    np.testing.assert_array_equal(np.asarray(kops.dense_decode_sum_op(values)), np.asarray(want))
    np.testing.assert_array_equal(
        np.asarray(kops.dense_decode_sum_mean_op(values)),
        np.asarray(jax.jit(lambda s: s / jnp.float32(n))(want)))
    np.testing.assert_array_equal(np.asarray(kops.dense_copy_op(values[0])), np.asarray(values[0]))


# ---------------------------------------------------------------------------
# Operator level: kernel route == fallback route through reference_step
# ---------------------------------------------------------------------------

PARAMS = {"a": jnp.zeros((13, 5)), "b": jnp.zeros((70,)), "c": jnp.zeros((3, 3, 3))}
N = 4


def _grads(dtype):
    return {k: _normal(jax.random.fold_in(KEY, i), (N,) + v.shape).astype(dtype)
            for i, (k, v) in enumerate(PARAMS.items())}


def _run(cfg, grads):
    v, ns = reference_step(grads, reference_init(PARAMS, cfg, N), KEY, cfg, beta=0.9)
    return [v, ns.h_worker, ns.h_server]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
@pytest.mark.parametrize("bucketed", [False, True], ids=["perleaf", "bucketed"])
@pytest.mark.parametrize("method,kw", OPERATORS, ids=[m for m, _ in OPERATORS])
def test_kernel_route_bitwise_equals_fallback(method, kw, bucketed, dtype):
    """The ISSUE's core contract: with the same key, enabling the kernels
    changes NOTHING about a full reference round — momentum, worker memory
    and server memory all stay bitwise-identical on every operator, both
    layouts, and bf16 gradient inputs."""
    grads = _grads(dtype)
    base = CompressionConfig(method=method, bucketed=bucketed, **kw)
    out_fb = _run(replace(base, use_kernel=False), grads)
    out_kn = _run(replace(base, use_kernel=True), grads)
    for a, b in zip(jax.tree_util.tree_leaves(out_fb),
                    jax.tree_util.tree_leaves(out_kn)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# jaxpr: the fused server tail is ONE pallas launch per operator
# ---------------------------------------------------------------------------

def _count_pallas(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None:
                    n += _count_pallas(inner)
    return n


@pytest.mark.parametrize("method,kw", OPERATORS, ids=[m for m, _ in OPERATORS])
def test_decode_sum_apply_is_one_launch(method, kw):
    """Fused decode_sum + server update traces exactly ONE pallas launch per
    operator (so the grouped path pays one launch per group): the aggregated
    sum never round-trips HBM between decode and apply — either the epilogue
    runs in-kernel (ternary/natural) or the memory tail composes on the
    kernel's materialised accumulator (sparse/dense; kernels/sparse.py)."""
    d = 64
    cfg = CompressionConfig(method=method, use_kernel=True, **kw)
    comp = cfg.make()
    pay = comp.compress(_normal(KEY, (d,)), KEY)
    gathered = jax.tree_util.tree_map(lambda x: jnp.stack([x] * N), pay)
    h = jnp.zeros((d,), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda g, hh: comp.decode_sum_apply(g, N, d, hh))(gathered, h)
    assert _count_pallas(jaxpr.jaxpr) == 1, jaxpr


# ---------------------------------------------------------------------------
# tools/check_kernels.py linter
# ---------------------------------------------------------------------------

def test_check_kernels_repo_clean():
    """Every registry operator declares its capability, names a resolving
    oracle and keeps the fallback reachable — the CI step, run in-process."""
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_kernels
        assert check_kernels.main(["--no-trace"]) == 0
    finally:
        sys.path.pop(0)


def test_check_kernels_catches_rot(monkeypatch):
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_kernels

        class NoOracle:
            kernel_oracle = None
            use_kernel = True

        class BadOracle:
            kernel_oracle = "repro.kernels.ref::does_not_exist"
            use_kernel = True

        class Unresolved:
            kernel_oracle = "repro.kernels.ref::ref_nat_pack"
            use_kernel = None  # auto left unresolved

        for cls, checker in [(NoOracle, check_kernels.oracle_errors),
                             (BadOracle, check_kernels.oracle_errors),
                             (Unresolved, check_kernels.capability_errors)]:
            monkeypatch.setattr(check_kernels, "_make", lambda m, f, c=cls: c())
            assert checker("probe") != [], cls.__name__
    finally:
        sys.path.pop(0)
