"""Convergence-law regression suite — the papers' rate claims as tier-1 tests.

Turns the headline theory of the DIANA paper (Thm 1/2) and of VR-DIANA
(Horváth et al., arXiv:1904.05115, Thm 3.1) into seeded assertions on a small
strongly-convex logistic-regression fixture (`benchmarks.common.stoch_problem`),
instead of eyeball-only benchmark figures:

  (a) batch DIANA drives the objective gap to (numerical) zero — linear
      convergence to the exact optimum with full local gradients;
  (b) with single-sample stochastic gradients, plain DIANA stalls at a
      variance floor while VR-DIANA's L-SVRG control variates restore linear
      convergence: >= 10x below DIANA's gap at an equal step budget;
  (c) memoryless QSGD stalls at/above that floor.

Plus the VR bitwise contract: the VR-composed round produces IDENTICAL bits
on the distributed bucketed path (`aggregate_shardmap` over a 4-worker mesh,
subprocess like tests/test_distributed.py), the per-leaf reference and the
bucketed reference, for all five registry operators — and enabling VR never
perturbs the compressor's PRNG draws.

The fixture is sized so the whole module runs in well under 30 s (the
stochastic loops are jitted; f* is solved once and lru-cached).
"""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(5)  # chosen so the vr_p=0.5 coins mix refresh/keep

# the five canonical registry operators (every alias resolves to one of these)
OPERATORS = [
    ("diana", dict(block_size=16)),      # ternary, alpha-memory
    ("natural", {}),
    ("randk", dict(k=8)),
    ("topk_ef", dict(k=8)),
    ("none", {}),                        # identity
]

GAP_FLOOR = 1e-7   # f32 resolution of the fixture's objective (~0.66)


def _gap(loss, fstar):
    return max(loss - fstar, GAP_FLOOR)


@pytest.fixture(scope="module")
def fixture_gaps():
    """One shared run of every regime on the seeded fixture (module-scoped:
    the laws are cross-method comparisons of the same trajectory family)."""
    from benchmarks.common import (
        fstar_logreg, run_logreg, run_logreg_stochastic, stoch_problem)

    prob = stoch_problem()
    fstar = fstar_logreg(prob, 400)
    batch = run_logreg("diana", math.inf, steps=200, gamma=1.0, block=8,
                       problem=prob)
    stoch = {
        name: run_logreg_stochastic(
            method, p, steps=300, gamma=0.5, block=8, problem=prob, **kw)
        for name, method, p, kw in [
            ("diana", "diana", math.inf, {}),
            ("vr", "diana", math.inf, dict(vr=True)),
            ("qsgd", "qsgd", 2.0, {}),
        ]
    }
    return {
        "batch_diana": _gap(batch["final_loss"], fstar),
        **{k: _gap(r["final_loss"], fstar) for k, r in stoch.items()},
    }


def test_batch_diana_gap_vanishes(fixture_gaps):
    """(a) Thm 2: batch-mode DIANA converges to the exact optimum — the gap
    lands at the numerical floor, far below any variance ball."""
    assert fixture_gaps["batch_diana"] < 1e-5, fixture_gaps


def test_vr_diana_beats_stochastic_variance_floor(fixture_gaps):
    """(b) arXiv:1904.05115 Thm 3.1: with stochastic finite-sum gradients,
    L-SVRG control variates restore linear convergence — >= 10x below plain
    DIANA's variance floor at an equal step budget (measured: ~1e4x)."""
    assert fixture_gaps["diana"] > 1e-3, (
        f"stochastic DIANA should stall at a variance floor: {fixture_gaps}")
    assert fixture_gaps["diana"] >= 10.0 * fixture_gaps["vr"], fixture_gaps
    assert fixture_gaps["vr"] < 1e-4, fixture_gaps


def test_qsgd_stalls_above_floor(fixture_gaps):
    """(c) memoryless QSGD keeps both the sampling and the full-gradient
    quantization noise: it stalls at/above DIANA's floor, orders of magnitude
    above VR-DIANA."""
    assert fixture_gaps["qsgd"] > 1e-3, fixture_gaps
    assert fixture_gaps["qsgd"] >= 0.5 * fixture_gaps["diana"], fixture_gaps
    assert fixture_gaps["qsgd"] >= 10.0 * fixture_gaps["vr"], fixture_gaps


@pytest.mark.slow
@pytest.mark.parametrize("method,p,kw", [
    ("natural", 2.0, {}),
    ("randk", 2.0, dict(k=8)),
], ids=["natural", "randk"])
def test_vr_linear_convergence_other_operators(method, p, kw):
    """Long parametrization: the VR composition is operator-agnostic — the
    other unbiased registry operators also reach the exact optimum in the
    stochastic regime (their omega only rescales the rate)."""
    from benchmarks.common import fstar_logreg, run_logreg_stochastic, stoch_problem

    prob = stoch_problem()
    fstar = fstar_logreg(prob, 400)
    r = run_logreg_stochastic(method, p, steps=500, gamma=0.4, block=8,
                              vr=True, problem=prob, **kw)
    assert _gap(r["final_loss"], fstar) < 1e-4, r["final_loss"] - fstar


# ---------------------------------------------------------------------------
# VR bitwise contracts
# ---------------------------------------------------------------------------

def _grid(key, shape, scale=64):
    """Values on the 1/64 grid: every partial sum of a few of them is exact
    in f32, so even the identity operator's pmean-vs-sequential-sum paths
    cannot diverge and bitwise equality is meaningful for ALL operators."""
    return jnp.round(jax.random.normal(key, shape) * scale) / scale


def _vr_fixture(n=4, key=KEY):
    params = {"w": _grid(jax.random.fold_in(key, 0), (12, 5)),
              "b": _grid(jax.random.fold_in(key, 1), (9,))}
    stacked = lambda tag: {
        k: _grid(jax.random.fold_in(key, tag * 10 + i), (n,) + v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    return params, stacked(2), stacked(3), stacked(4), stacked(5), stacked(6)


def _run_reference_vr(cfg, n=4, key=KEY):
    params, grads, snap, mu, g_snap, mu_cand = _vr_fixture(n, key)
    state = reference_init(params, cfg, n)
    state = state._replace(vr=state.vr._replace(snapshot=snap, mu=mu))
    v, ns = reference_step(grads, state, key, cfg,
                           vr_aux=(g_snap, mu_cand), params=params)
    return v, ns


@pytest.mark.parametrize("method,kw", OPERATORS, ids=[m for m, _ in OPERATORS])
def test_vr_reference_bucketed_bitwise_equals_perleaf(method, kw):
    """The VR composition happens before any layout decision, so the bucketed
    and per-leaf reference paths stay bitwise-equal under VR for every
    operator — including the (snapshot, mu) rows after mixed coins."""
    from dataclasses import replace

    from repro.core.diana import bucket_layout

    cfg = CompressionConfig(method=method, p=math.inf, vr=True, vr_p=0.5, **kw)
    v_pl, ns_pl = _run_reference_vr(cfg)
    v_bk, ns_bk = _run_reference_vr(replace(cfg, bucketed=True))
    for a, b in zip(jax.tree_util.tree_leaves(v_pl), jax.tree_util.tree_leaves(v_bk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ns_pl.vr),
                    jax.tree_util.tree_leaves(ns_bk.vr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-leaf h rows live inside the bucketed buffer at the layout offsets
    lay = bucket_layout(cfg, {k: v[0] for k, v in _vr_fixture()[1].items()})
    for i, (off, size) in enumerate(zip(lay.offsets, lay.sizes)):
        np.testing.assert_array_equal(
            np.asarray(ns_bk.h_worker[:, off:off + size]),
            np.asarray(jax.tree_util.tree_leaves(ns_pl.h_worker)[i]))


def test_vr_does_not_perturb_compression_draws():
    """PRNG schedule contract: the VR coin stream (VR_FOLD) is disjoint from
    the compressor's — a VR run whose control variate is algebraically the
    identity (g_snap=0, mu=0) produces the SAME h updates, bitwise, as the
    plain DIANA run on the same gradients."""
    n = 4
    params, grads, _, _, _, mu_cand = _vr_fixture(n)
    zeros = jax.tree_util.tree_map(lambda g: jnp.zeros_like(g), grads)

    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16)
    v0, ns0 = reference_step(grads, reference_init(params, cfg, n), KEY, cfg)

    from dataclasses import replace

    cfg_vr = replace(cfg, vr=True, vr_p=0.5)
    state = reference_init(params, cfg_vr, n)
    state = state._replace(vr=state.vr._replace(mu=zeros))
    v1, ns1 = reference_step(grads, state, KEY, cfg_vr,
                             vr_aux=(zeros, mu_cand), params=params)

    for a, b in zip(jax.tree_util.tree_leaves(v0), jax.tree_util.tree_leaves(v1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ns0.h_worker),
                    jax.tree_util.tree_leaves(ns1.h_worker)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_variance_reducer_facade_matches_free_functions():
    """The `VarianceReducer` facade is the same algebra as the free functions
    the aggregation paths use: identical coins (the PRNG schedule contract),
    identical control variates and refreshes, and the paper's 1/m default."""
    from repro.core import VarianceReducer, control_variate
    from repro.core.vr import reference_coins, refresh, vr_coin

    vr = VarianceReducer.for_finite_sum(32)
    assert vr.p == pytest.approx(1 / 32)
    with pytest.raises(ValueError):
        VarianceReducer(0.0)

    vr = VarianceReducer(0.5)
    np.testing.assert_array_equal(np.asarray(vr.coins(KEY, 4)),
                                  np.asarray(reference_coins(KEY, 0.5, 4)))
    wkey = jax.random.fold_in(KEY, 2)
    assert bool(vr.coin(wkey)) == bool(vr_coin(wkey, 0.5))

    params, grads, snap, mu, g_snap, mu_cand = _vr_fixture()
    np.testing.assert_array_equal(
        np.asarray(vr.control_variate(grads, g_snap, mu)["w"]),
        np.asarray(control_variate(grads, g_snap, mu)["w"]))
    state = vr.init(params, 4, mu=mu)
    coins = vr.coins(KEY, 4)
    np.testing.assert_array_equal(
        np.asarray(vr.refresh(state, coins, params, mu_cand).mu["w"]),
        np.asarray(refresh(state, coins, params, mu_cand).mu["w"]))


def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_vr_bucketed_distributed_bitwise_all_operators():
    """Acceptance: VR-bucketed `aggregate_shardmap` over a real 4-worker mesh
    equals the VR `reference_step` BITWISE — ghat, h state and the refreshed
    (snapshot, mu) rows — for all five registry operators (one subprocess,
    all operators; grid-valued inputs make even identity's pmean exact)."""
    code = """
import jax, jax.numpy as jnp, numpy as np, json, math
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import CompressionConfig, DianaState, VRState, aggregate_shardmap, init_state
from repro.core.diana import reference_init, reference_step
from repro.launch.mesh import make_mesh
from tests.test_convergence_laws import OPERATORS, _vr_fixture

mesh = make_mesh((4, 1), ("data", "model"))
n = 4
key = jax.random.PRNGKey(5)
params, grads, snap, mu, g_snap, mu_cand = _vr_fixture(n, key)
tmap, leaves = jax.tree_util.tree_map, jax.tree_util.tree_leaves

report = {}
for method, kw in OPERATORS:
    cfg = CompressionConfig(method=method, p=math.inf, bucketed=True,
                            vr=True, vr_p=0.5, **kw)

    ref_state = reference_init(params, cfg, n)
    ref_state = ref_state._replace(vr=ref_state.vr._replace(snapshot=snap, mu=mu))
    v_ref, ref_new = reference_step(grads, ref_state, key, cfg,
                                    vr_aux=(g_snap, mu_cand), params=params)

    state = init_state(params, cfg, n)
    state = state._replace(vr=state.vr._replace(snapshot=snap, mu=mu))

    def body(g_st, snap_st, mu_st, gsnap_st, mucand_st, h_w, h_s, k):
        own = lambda t: tmap(lambda x: x[0], t)
        st = DianaState(h_w, h_s, VRState(snapshot=snap_st, mu=mu_st))
        wkey = jax.random.fold_in(k, jax.lax.axis_index("data"))
        ghat, ns = aggregate_shardmap(
            own(g_st), st, wkey, cfg, axis_names=("data",), n_workers=n,
            vr_aux=(own(gsnap_st), own(mucand_st)), params_local=params)
        return ghat, ns.h_worker, ns.h_server, ns.vr.snapshot, ns.vr.mu

    sh = lambda t: tmap(lambda _: P("data"), t)
    rep = lambda t: tmap(lambda _: P(), t)
    fn = shard_map(body, mesh=mesh,
        in_specs=(sh(grads), sh(snap), sh(mu), sh(g_snap), sh(mu_cand),
                  P("data"), P(), P()),
        out_specs=(rep(params), P("data"), P(), sh(snap), sh(mu)),
        axis_names={"data"}, check_vma=False)
    ghat, h_w, h_s, nsnap, nmu = jax.jit(fn)(
        grads, snap, mu, g_snap, mu_cand, state.h_worker, state.h_server, key)

    errs = {
        "g": max(float(jnp.abs(a - b).max()) for a, b in
                 zip(leaves(ghat), leaves(v_ref))),
        "hw": float(jnp.abs(h_w - ref_new.h_worker).max()),
        "hs": float(jnp.abs(h_s - ref_new.h_server).max()),
        "snap": max(float(jnp.abs(a - b).max()) for a, b in
                    zip(leaves(nsnap), leaves(ref_new.vr.snapshot))),
        "mu": max(float(jnp.abs(a - b).max()) for a, b in
                  zip(leaves(nmu), leaves(ref_new.vr.mu))),
    }
    report[method] = errs
print(json.dumps(report))
"""
    report = json.loads(run_py(code).strip().splitlines()[-1])
    assert set(report) == {m for m, _ in OPERATORS}
    for method, errs in report.items():
        assert all(v == 0.0 for v in errs.values()), (method, errs)
