"""Compressor registry, Payload wire format, and per-operator behaviour.

Covers the refactor's contract surface:

* registry resolution (canonical names + legacy aliases);
* ``Payload`` as a jax pytree (flatten/unflatten, jit/vmap safe);
* ``pack2bit``/``unpack2bit`` roundtrip over ALL 3-value codes;
* ``payload_bits_per_dim`` agreement with each operator's ``bits_per_dim``;
* ternary ``decode_sum``: kernel (``unpack_reduce``, interpret=True) bitwise
  EQUAL to the pure-jnp fallback loop;
* unbiasedness of ternary / natural / rand-k / identity;
* the paper's headline claim on the logreg example: every operator runs
  through ``reference_step``, and the unbiased ones converge to within 1e-3
  of the uncompressed optimum in batch mode.
"""

import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step
from repro.core.compression import payload_bits_per_dim
from repro.core.compressors import (
    Payload,
    TernaryCompressor,
    available_methods,
    make_compressor,
)
from repro.core.compressors.registry import canonical_name
from repro.core.packing import pack2bit, unpack2bit

KEY = jax.random.PRNGKey(0)

ALL_METHODS = ("diana", "natural", "randk", "topk_ef", "none")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_aliases_resolve():
    assert canonical_name("diana") == "ternary"
    assert canonical_name("qsgd") == "ternary"
    assert canonical_name("terngrad") == "ternary"
    assert canonical_name("dqgd") == "ternary"
    assert canonical_name("none") == "identity"
    for m in ("ternary", "natural", "randk", "topk_ef", "identity"):
        assert canonical_name(m) == m
        assert m in available_methods()


def test_registry_alias_semantics():
    qsgd = CompressionConfig(method="qsgd").make()
    assert isinstance(qsgd, TernaryCompressor)
    assert qsgd.p == 2.0 and not qsgd.carries_state
    tern = CompressionConfig(method="terngrad").make()
    assert tern.p == math.inf and not tern.carries_state
    diana = CompressionConfig(method="diana").make()
    assert diana.carries_state and diana.memory_alpha() > 0


def test_unknown_method_rejected():
    with pytest.raises((KeyError, ValueError)):
        CompressionConfig(method="zstd")


# ---------------------------------------------------------------------------
# Payload wire format
# ---------------------------------------------------------------------------

def test_payload_is_pytree_roundtrip():
    pay = Payload(packed=jnp.arange(8, dtype=jnp.uint8), scales=jnp.ones((2,)))
    leaves, treedef = jax.tree_util.tree_flatten(pay)
    assert len(leaves) == 2  # None fields flatten away
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, Payload)
    np.testing.assert_array_equal(np.asarray(back.packed), np.asarray(pay.packed))
    assert back.indices is None and back.values is None


def test_payload_jit_and_vmap_safe():
    @jax.jit
    def double(p: Payload) -> Payload:
        return Payload(values=p.values * 2)

    out = double(Payload(values=jnp.arange(4.0)))
    np.testing.assert_allclose(np.asarray(out.values), [0, 2, 4, 6])

    stacked = Payload(values=jnp.arange(12.0).reshape(3, 4))
    summed = jax.vmap(lambda p: p.values.sum())(stacked)
    assert summed.shape == (3,)


def test_pack2bit_roundtrip_all_codes():
    """Every 3^4 = 81 sign nibble and longer random code streams roundtrip."""
    combos = np.array(list(itertools.product([-1, 0, 1], repeat=4)), dtype=np.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack2bit(pack2bit(jnp.asarray(combos)))), combos
    )
    rng = np.random.default_rng(0)
    signs = rng.integers(-1, 2, size=(7, 64)).astype(np.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack2bit(pack2bit(jnp.asarray(signs)))), signs
    )


@pytest.mark.parametrize("method", ("diana", "qsgd", "natural", "randk", "topk_ef", "none"))
def test_bits_per_dim_agreement(method):
    """payload_bits_per_dim(cfg, d) is exactly the operator's bits_per_dim(d)."""
    d = 640
    cfg = CompressionConfig(method=method, block_size=64, k=32)
    comp = cfg.make()
    assert payload_bits_per_dim(cfg, d) == comp.bits_per_dim(d)
    # and the actual payload container is consistent with the accounting
    pay = comp.compress(jax.random.normal(KEY, (d,)), KEY)
    if method in ("randk", "topk_ef"):
        assert pay.indices.shape == pay.values.shape == (32,)
        # d = 640 -> uint16 indices: (32 + 16) bits per kept coordinate
        assert pay.indices.dtype == jnp.uint16
        assert comp.bits_per_dim(d) == pytest.approx((32 + 16) * 32 / d)
    if method in ("diana", "qsgd"):
        assert pay.packed.shape == (d // 64, 16)  # 2 bits/dim packed
        assert comp.bits_per_dim(d) == pytest.approx(2.0 + 32.0 / 64)


# ---------------------------------------------------------------------------
# Decode correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,kw", [
    ("diana", {}), ("natural", {}), ("randk", {"k": 64}), ("none", {}),
])
def test_unbiasedness(method, kw):
    """E[decode(compress(x))] == x for the unbiased operators."""
    d = 128
    cfg = CompressionConfig(method=method, block_size=32, **kw)
    comp = cfg.make()
    assert comp.unbiased
    x = jax.random.normal(KEY, (d,))
    n = 3000

    def one(k):
        return comp.decode(comp.compress(x, k), d)

    samp = jax.jit(jax.vmap(one))(jax.random.split(jax.random.PRNGKey(7), n))
    err = float(jnp.abs(samp.mean(0) - x).max())
    assert err < 0.15, f"{method}: bias {err}"


def test_topk_ef_is_biased_but_exact_on_support():
    cfg = CompressionConfig(method="topk_ef", k=4)
    comp = cfg.make()
    assert not comp.unbiased and comp.carries_state
    x = jnp.asarray([5.0, -4.0, 3.0, -2.0, 1.0, 0.5, -0.25, 0.125])
    dec = comp.decode(comp.compress(x, KEY), 8)
    np.testing.assert_allclose(np.asarray(dec), [5.0, -4.0, 3.0, -2.0, 0, 0, 0, 0])


def test_decode_sum_matches_stacked_decodes():
    """Default decode_sum == sum of per-worker decodes, for every operator."""
    d, n = 200, 5
    for method, kw in [("diana", {}), ("natural", {}), ("randk", {"k": 16}),
                       ("topk_ef", {"k": 16}), ("none", {})]:
        comp = CompressionConfig(method=method, block_size=64, **kw).make()
        pays = [
            comp.compress(jax.random.normal(jax.random.PRNGKey(i), (d,)),
                          jax.random.PRNGKey(100 + i))
            for i in range(n)
        ]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pays)
        total = comp.decode_sum(stacked, n, d)
        manual = sum(comp.decode(p, d) for p in pays)
        np.testing.assert_allclose(np.asarray(total), np.asarray(manual),
                                   rtol=1e-6, atol=1e-6, err_msg=method)


def test_ternary_kernel_decode_sum_bitwise_equals_fallback():
    """The Pallas unpack_reduce decode (interpret=True on CPU) is bitwise
    identical to the pure-jnp fallback loop — the acceptance criterion for
    putting the kernel on the hot decode path."""
    d, n = 5000, 4  # pads 5000 -> 3 blocks of 2048, and m=3 pads to tile_m
    fallback = TernaryCompressor(p=math.inf, block_size=2048, use_kernel=False)
    kernel = TernaryCompressor(p=math.inf, block_size=2048, use_kernel=True)
    pays = [
        fallback.compress(
            jax.random.normal(jax.random.PRNGKey(i), (d,)) * (10.0 ** (i - 2)),
            jax.random.PRNGKey(50 + i),
        )
        for i in range(n)
    ]
    gathered = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pays)
    out_fb = fallback.decode_sum(gathered, n, d)
    out_k = kernel.decode_sum(gathered, n, d)
    assert out_fb.shape == out_k.shape == (d,)
    np.testing.assert_array_equal(np.asarray(out_fb), np.asarray(out_k))


def test_ternary_kernel_compress_format_matches():
    """Kernel-quantized payloads use the same wire format (independent PRNG
    stream, so values agree in distribution; the packed container and the
    scales must agree exactly in shape/dtype)."""
    kernel = TernaryCompressor(p=2.0, block_size=128, use_kernel=True)
    fallback = TernaryCompressor(p=2.0, block_size=128, use_kernel=False)
    x = jax.random.normal(KEY, (1000,))
    pk, pf = kernel.compress(x, KEY), fallback.compress(x, KEY)
    assert pk.packed.shape == pf.packed.shape and pk.packed.dtype == pf.packed.dtype
    assert pk.scales.shape == pf.scales.shape
    np.testing.assert_allclose(np.asarray(pk.scales), np.asarray(pf.scales), rtol=1e-6)


# ---------------------------------------------------------------------------
# Convergence on the logreg example (the paper's headline claim)
# ---------------------------------------------------------------------------

def _logreg_problem(n_workers=4, dim=48, samples=96, seed=3):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_workers, samples, dim)) / math.sqrt(dim)
    w_true = rng.standard_normal(dim)
    y = np.sign(X.reshape(-1, dim) @ w_true + 0.1 * rng.standard_normal(n_workers * samples))
    y = y.reshape(n_workers, samples)
    l2 = 1e-3
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def grads(w):
        z = yj * jnp.einsum("wij,j->wi", Xj, w)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wij,wi->wj", Xj, yj * sig) / samples + l2 * w

    def loss(w):
        z = yj * jnp.einsum("wij,j->wi", Xj, w)
        return float(jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * l2 * w @ w)

    return grads, loss, dim


def _run(method, grads, dim, *, steps, gamma, n_workers=4, **kw):
    cfg = CompressionConfig(method=method, block_size=16, **kw)
    params = {"x": jnp.zeros((dim,))}
    state = reference_init(params, cfg, n_workers)
    key = jax.random.PRNGKey(0)
    step = jax.jit(lambda g, s, k: reference_step(g, s, k, cfg))
    for k in range(steps):
        key = jax.random.fold_in(key, k)
        v, state = step({"x": grads(params["x"])}, state, key)
        params = {"x": params["x"] - gamma * v["x"]}
    return params["x"]


def test_all_five_compressors_run_and_unbiased_ones_reach_optimum():
    """Acceptance: every registered operator runs through reference_step on
    the logreg problem; the unbiased ones (DIANA-ternary, natural, rand-k,
    identity) reach the uncompressed optimum to within 1e-3 in batch mode."""
    grads, loss, dim = _logreg_problem()
    x_none = _run("none", grads, dim, steps=800, gamma=2.0)
    fstar = loss(x_none)

    gaps = {}
    for method, kw in [
        ("diana", {}),
        ("natural", {}),
        ("randk", {"k": 8}),
        ("topk_ef", {"k": 8}),
    ]:
        x = _run(method, grads, dim, steps=800, gamma=2.0, **kw)
        gaps[method] = loss(x) - fstar

    for method in ("diana", "natural", "randk"):
        assert abs(gaps[method]) < 1e-3, (method, gaps)
    # top-k EF is biased: no 1e-3 guarantee, but error feedback must keep it
    # in the optimum's neighbourhood rather than diverging
    assert abs(gaps["topk_ef"]) < 5e-2, gaps


def test_memory_carries_residual_for_topk():
    """EF residual e_i = delta_i - dhat_i is exactly what top-k dropped."""
    cfg = CompressionConfig(method="topk_ef", k=2)
    comp = cfg.make()
    g = jnp.asarray([[3.0, -2.0, 1.0, 0.5]])   # one worker
    params = {"x": jnp.zeros((4,))}
    state = reference_init(params, cfg, 1)
    _, new_state = reference_step({"x": g}, state, KEY, cfg)
    np.testing.assert_allclose(
        np.asarray(new_state.h_worker["x"][0]), [0.0, 0.0, 1.0, 0.5]
    )
