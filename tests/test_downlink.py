"""Bidirectional DIANA — the compressed server broadcast (DESIGN.md
§Bidirectional).

Contracts under test:

* layout invariance: the downlink round is bitwise-identical across the
  per-leaf and bucketed downlink layouts, for every registry operator,
  including the mixed (uplink layout != downlink layout) pairings;
* the identity downlink is an exact no-op (an uplink-only run with an inert
  ``h_down`` slot);
* disabled downlink keeps the state tree free of ``h_down`` leaves — states
  and checkpoints are byte-identical to uplink-only DIANA;
* the downlink PRNG fold never perturbs the uplink draws;
* convergence law: the downlink MEMORY is what makes broadcast compression
  safe — bidirectional DIANA still reaches the exact optimum, while a
  memoryless downlink quantizer stalls at its broadcast-noise floor;
* acceptance: ``aggregate_shardmap == reference_step`` BITWISE on a real
  4-worker mesh for all five registry operators (paired uplink x downlink),
  in per-leaf and bucketed layouts, VR on and off (subprocess, like
  tests/test_distributed.py).
"""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, reference_init, reference_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(7)

# the five canonical registry operators (every alias resolves to one of these)
OPERATORS = [
    ("diana", dict(block_size=16)),
    ("natural", {}),
    ("randk", dict(k=8)),
    ("topk_ef", dict(k=8)),
    ("none", {}),
]


def _grid(key, shape, scale=64):
    """1/64-grid values: small partial sums are exact in f32, so bitwise
    equality is meaningful even through identity's pmean path."""
    return jnp.round(jax.random.normal(key, shape) * scale) / scale


def _fixture(n=4, key=KEY):
    params = {"w": _grid(jax.random.fold_in(key, 0), (12, 5)),
              "b": _grid(jax.random.fold_in(key, 1), (9,))}
    grads = {
        k: _grid(jax.random.fold_in(key, 10 + i), (n,) + v.shape)
        for i, (k, v) in enumerate(params.items())
    }
    return params, grads


def _run(cfg, n=4, key=KEY, steps=1):
    params, grads = _fixture(n, key)
    state = reference_init(params, cfg, n)
    v = None
    for t in range(steps):
        v, state = reference_step(grads, state, jax.random.fold_in(key, t), cfg)
    return v, state


# ---------------------------------------------------------------------------
# Layout invariance of the downlink round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("down,kw", OPERATORS, ids=[m for m, _ in OPERATORS])
def test_downlink_bucketed_bitwise_equals_perleaf(down, kw):
    """Per-leaf and bucketed DOWNLINK layouts agree bitwise for every
    operator (the downlink re-derives the per-leaf key schedule exactly as
    the uplink bucketed hooks do), including the h_down memory rows."""
    from dataclasses import replace

    from repro.core.diana import bucket_layout

    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16, k=8,
                            down_method=down, down_k=kw.get("k"))
    v_pl, ns_pl = _run(cfg, steps=2)
    v_bk, ns_bk = _run(replace(cfg, bucketed=True), steps=2)
    for a, b in zip(jax.tree_util.tree_leaves(v_pl), jax.tree_util.tree_leaves(v_bk)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-leaf h_down rows live inside the bucketed h_down at the downlink
    # layout's offsets
    params, _ = _fixture()
    lay = bucket_layout(replace(cfg.down_config(), bucketed=True), params)
    pl_leaves = jax.tree_util.tree_leaves(ns_pl.h_down)
    for i, (off, size) in enumerate(zip(lay.offsets, lay.sizes)):
        np.testing.assert_array_equal(
            np.asarray(ns_bk.h_down[off:off + size]), np.asarray(pl_leaves[i]))


@pytest.mark.parametrize("up_bucketed,down_bucketed", [(True, False), (False, True)],
                         ids=["bucketed-up/perleaf-down", "perleaf-up/bucketed-down"])
def test_mixed_layout_pairings_bitwise(up_bucketed, down_bucketed):
    """The downlink makes its OWN layout decision (``down_bucketed``): mixed
    uplink/downlink layout pairings produce the same bits as the pure ones."""
    base = CompressionConfig(method="diana", p=math.inf, block_size=16,
                             down_method="diana")
    from dataclasses import replace

    v_ref, ns_ref = _run(base, steps=2)  # pure per-leaf
    mixed = replace(base, bucketed=up_bucketed, down_bucketed=down_bucketed)
    v_mx, ns_mx = _run(mixed, steps=2)
    for a, b in zip(jax.tree_util.tree_leaves(v_ref), jax.tree_util.tree_leaves(v_mx)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_identity_downlink_is_exact_noop():
    """``down_method='none'`` adds an inert h_down slot but cannot change a
    single bit of the trajectory (f32 round-trips exactly)."""
    from dataclasses import replace

    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16)
    v0, ns0 = _run(cfg, steps=3)
    v1, ns1 = _run(replace(cfg, down_method="none"), steps=3)
    for a, b in zip(jax.tree_util.tree_leaves(v0), jax.tree_util.tree_leaves(v1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ns0.h_down is None
    assert all(float(jnp.abs(l).max()) == 0.0
               for l in jax.tree_util.tree_leaves(ns1.h_down))


def test_downlink_fold_does_not_perturb_uplink_draws():
    """PRNG schedule contract: enabling a downlink changes ghat (it is
    compressed now) but the UPLINK h memories — a pure function of the
    uplink draws — stay bitwise-identical, so DOWN_FOLD is disjoint from the
    compression schedule."""
    from dataclasses import replace

    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16)
    _, ns0 = _run(cfg)
    _, ns1 = _run(replace(cfg, down_method="diana"))
    for a, b in zip(jax.tree_util.tree_leaves(ns0.h_worker),
                    jax.tree_util.tree_leaves(ns1.h_worker)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ns0.h_server),
                    jax.tree_util.tree_leaves(ns1.h_server)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_disabled_downlink_state_has_no_h_down_leaves():
    """``down_method=None`` flattens the slot away: the state pytree carries
    exactly the uplink-only leaves (pre-PR byte-identity; the checkpoint-key
    twin of this test lives in tests/test_checkpoint.py)."""
    from repro.core import init_state

    params, _ = _fixture()
    cfg = CompressionConfig(method="diana", block_size=16)
    st = init_state(params, cfg, 4)
    assert st.h_down is None
    paths = ["/".join(str(getattr(p, "name", getattr(p, "key", p))) for p in kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(st)[0]]
    assert not any("h_down" in p for p in paths)
    assert not any("vr" in p.split("/") for p in paths)


def test_bf16_gradients_downlink_matches_f32_reference_bitwise():
    """The downlink compresses the f32 server direction — NOT a ghat already
    rounded to the gradient dtype — so a bf16-gradient distributed run stays
    bitwise-aligned with the f32 reference fed the exact same values (the
    gradient-dtype cast happens once, after the downlink).  Regression test:
    an earlier ordering cast ghat to bf16 before the downlink encode, which
    silently forked h_down between the paths."""
    from repro.core import DianaState, aggregate_shardmap, init_state
    from repro.core.diana import DOWN_FOLD

    key = KEY
    # 1/8-grid values with small magnitude: exactly representable in bf16,
    # so the bf16 local gradients upcast to the identical f32 values the
    # reference consumes.
    g16 = {
        "w": (_grid(jax.random.fold_in(key, 0), (12, 5), scale=8) / 4).astype(jnp.bfloat16),
        "b": (_grid(jax.random.fold_in(key, 1), (9,), scale=8) / 4).astype(jnp.bfloat16),
    }
    params = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32), g16)
    g32 = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32)[None], g16  # stacked, n=1
    )
    cfg = CompressionConfig(method="diana", p=math.inf, block_size=16,
                            down_method="diana")
    v_ref, ref_new = reference_step(g32, reference_init(params, cfg, 1), key, cfg)

    st = init_state(params, cfg, 1)
    ghat, ns = aggregate_shardmap(
        g16, st, jax.random.fold_in(key, 0), cfg,
        axis_names=(), n_workers=1,
        down_key=jax.random.fold_in(key, DOWN_FOLD))

    for a, b in zip(jax.tree_util.tree_leaves(ns.h_down),
                    jax.tree_util.tree_leaves(ref_new.h_down)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(ghat),
                    jax.tree_util.tree_leaves(v_ref)):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b.astype(jnp.bfloat16), np.float32))


# ---------------------------------------------------------------------------
# Convergence law: downlink memory vs memoryless downlink
# ---------------------------------------------------------------------------

def _quadratic(n_workers=4, d=64, seed=0):
    rng = np.random.default_rng(seed)
    As = rng.standard_normal((n_workers, d, d)) / math.sqrt(d)
    As += np.eye(d) * 0.8
    bs = rng.standard_normal((n_workers, d))
    x_star = np.linalg.lstsq(np.concatenate(As, 0), np.concatenate(bs, 0),
                             rcond=None)[0]
    As, bs = jnp.asarray(As), jnp.asarray(bs)

    def grads(x):
        return jnp.einsum("wij,wjk->wik", jnp.swapaxes(As, 1, 2),
                          (jnp.einsum("wij,j->wi", As, x) - bs)[..., None])[..., 0]

    return grads, jnp.asarray(x_star)


def _run_quadratic(cfg, steps=500, gamma=0.3, d=64):
    grads_fn, x_star = _quadratic(d=d)

    @jax.jit
    def step(params, state, key):
        v, state = reference_step({"x": grads_fn(params["x"])}, state, key, cfg)
        return {"x": params["x"] - gamma * v["x"]}, state

    params = {"x": jnp.zeros((d,))}
    state = reference_init(params, cfg, 4)
    key = KEY
    for t in range(steps):
        key = jax.random.fold_in(key, t)
        params, state = step(params, state, key)
    return float(jnp.linalg.norm(params["x"] - x_star))


def test_bidirectional_diana_reaches_exact_optimum():
    """The downlink memory makes broadcast compression noise VANISH near the
    optimum (the same gradient-difference argument as uplink DIANA), so
    bidirectional DIANA still converges to the exact optimum; a memoryless
    downlink quantizer (``down_method='qsgd'``) keeps re-injecting broadcast
    noise and stalls, exactly like memoryless uplink QSGD does."""
    bi = _run_quadratic(CompressionConfig(
        method="diana", p=math.inf, block_size=16, down_method="diana"))
    memoryless = _run_quadratic(CompressionConfig(
        method="diana", p=math.inf, block_size=16, down_method="qsgd"))
    assert bi < 1e-3, f"bidirectional DIANA should reach the optimum, got {bi}"
    assert memoryless > 10 * bi, (
        f"memoryless downlink should stall: down-qsgd={memoryless:.2e} "
        f"down-diana={bi:.2e}")


def test_downlink_ef_converges():
    """Error feedback is safe on the deterministic server direction: top-k EF
    downlink (with its residual in h_down) also reaches the exact optimum."""
    dist = _run_quadratic(CompressionConfig(
        method="diana", p=math.inf, block_size=16,
        down_method="topk_ef", down_k=16), steps=800, gamma=0.2)
    assert dist < 1e-2, f"EF downlink should converge, got {dist}"


# ---------------------------------------------------------------------------
# Acceptance: distributed == reference bitwise, 4-worker mesh
# ---------------------------------------------------------------------------

def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + REPO
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.parametrize("vr", [False, True], ids=["plain", "vr"])
def test_downlink_distributed_bitwise_all_operators(vr):
    """Acceptance: with a downlink compressor enabled, ``aggregate_shardmap``
    over a real 4-worker mesh equals ``reference_step`` BITWISE — ghat, the
    uplink h state and the downlink h_down — for all five registry operators
    (paired as uplink AND downlink), in the per-leaf and bucketed layouts,
    with VR off and on (one subprocess per VR mode)."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np, json, math
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import (CompressionConfig, DianaState, VRState,
                        aggregate_shardmap, init_state)
from repro.core.diana import DOWN_FOLD, reference_init, reference_step
from repro.launch.mesh import make_mesh
from tests.test_downlink import OPERATORS
from tests.test_convergence_laws import _vr_fixture

VR = {vr!r}
mesh = make_mesh((4, 1), ("data", "model"))
n = 4
key = jax.random.PRNGKey(7)
tmap, leaves = jax.tree_util.tree_map, jax.tree_util.tree_leaves
params, grads, snap, mu, g_snap, mu_cand = _vr_fixture(n, key)

report = {{}}
for method, kw in OPERATORS:
    for bucketed in (False, True):
        cfg = CompressionConfig(
            method=method, p=math.inf, bucketed=bucketed,
            down_method=method, down_k=kw.get("k"),
            vr=VR, vr_p=0.5 if VR else None,
            **{{k: v for k, v in kw.items() if k != "k"}}, k=kw.get("k", 64))

        ref_state = reference_init(params, cfg, n)
        st = init_state(params, cfg, n)
        vr_kwargs = {{}}
        if VR:
            ref_state = ref_state._replace(
                vr=ref_state.vr._replace(snapshot=snap, mu=mu))
            st = st._replace(vr=st.vr._replace(snapshot=snap, mu=mu))
            vr_kwargs = dict(vr_aux=(g_snap, mu_cand), params=params)
        v_ref, ref_new = reference_step(grads, ref_state, key, cfg, **vr_kwargs)

        def body(g_st, snap_st, mu_st, gsnap_st, mucand_st, h_w, h_s, h_d, k):
            own = lambda t: tmap(lambda x: x[0], t)
            vr_st = VRState(snapshot=snap_st, mu=mu_st) if VR else None
            stl = DianaState(h_w, h_s, vr_st, h_d)
            wkey = jax.random.fold_in(k, jax.lax.axis_index("data"))
            kw2 = dict(vr_aux=(own(gsnap_st), own(mucand_st)),
                       params_local=params) if VR else {{}}
            ghat, ns = aggregate_shardmap(
                own(g_st), stl, wkey, cfg, axis_names=("data",), n_workers=n,
                down_key=jax.random.fold_in(k, DOWN_FOLD), **kw2)
            nsnap = ns.vr.snapshot if VR else snap_st
            nmu = ns.vr.mu if VR else mu_st
            return ghat, ns.h_worker, ns.h_server, ns.h_down, nsnap, nmu

        sh = lambda t: tmap(lambda _: P("data"), t)
        rep = lambda t: tmap(lambda _: P(), t)
        hd_spec = tmap(lambda _: P(), st.h_down)
        fn = shard_map(body, mesh=mesh,
            in_specs=(sh(grads), sh(snap), sh(mu), sh(g_snap), sh(mu_cand),
                      tmap(lambda _: P("data"), st.h_worker),
                      rep(st.h_server), hd_spec, P()),
            out_specs=(rep(params), tmap(lambda _: P("data"), st.h_worker),
                       rep(st.h_server), hd_spec, sh(snap), sh(mu)),
            axis_names={{"data"}}, check_vma=False)
        ghat, h_w, h_s, h_d, nsnap, nmu = jax.jit(fn)(
            grads, snap, mu, g_snap, mu_cand,
            st.h_worker, st.h_server, st.h_down, key)

        errs = {{
            "g": max(float(jnp.abs(a - b).max()) for a, b in
                     zip(leaves(ghat), leaves(v_ref))),
            "hw": max(float(jnp.abs(a - b).max()) for a, b in
                      zip(leaves(h_w), leaves(ref_new.h_worker))),
            "hs": max(float(jnp.abs(a - b).max()) for a, b in
                      zip(leaves(h_s), leaves(ref_new.h_server))),
            "hd": max(float(jnp.abs(a - b).max()) for a, b in
                      zip(leaves(h_d), leaves(ref_new.h_down))),
        }}
        if VR:
            errs["snap"] = max(float(jnp.abs(a - b).max()) for a, b in
                               zip(leaves(nsnap), leaves(ref_new.vr.snapshot)))
            errs["mu"] = max(float(jnp.abs(a - b).max()) for a, b in
                             zip(leaves(nmu), leaves(ref_new.vr.mu)))
        report[f"{{method}}/{{'bucketed' if bucketed else 'perleaf'}}"] = errs
print(json.dumps(report))
"""
    report = json.loads(run_py(code).strip().splitlines()[-1])
    assert len(report) == 2 * len(OPERATORS)
    for pairing, errs in report.items():
        assert all(v == 0.0 for v in errs.values()), (pairing, errs)
