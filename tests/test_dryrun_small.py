"""Exercise the dry-run machinery end-to-end on a small 8-device mesh in a
subprocess (the production 512-device run happens out-of-band via
``python -m repro.launch.dryrun --all --multi-pod both``)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [
    ("mamba2-130m", "decode_32k"),
    ("llama3.2-1b", "long_500k"),
])
def test_dryrun_small_mesh(arch, shape, tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # dryrun sets its own XLA_FLAGS (512 devices) internally; --devices shrinks
    # only the mesh, which is exactly what we want to exercise here.
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape,
         "--devices", "8", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    path = tmp_path / "singlepod" / f"{arch}_{shape}.json"
    res = json.loads(path.read_text())
    assert res["status"] == "ok", res
    assert res["per_device"]["hlo_flops"] > 0
    assert set(res["roofline"]) >= {"compute_s", "memory_s", "collective_s", "dominant"}
