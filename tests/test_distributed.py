"""Multi-device distributed tests, run in subprocesses so the 8-device
XLA_FLAGS never leaks into the main pytest process (smoke tests must see the
real single-device CPU)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np, json
from dataclasses import replace
from jax.sharding import NamedSharding
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh, resolve_train_mesh
from repro.launch.train import build_train_step, init_train_state, make_optimizer
from repro.launch.sharding_rules import batch_specs
from repro.data import make_lm_batch
"""


@pytest.mark.parametrize("waxes", ["pod,data", "pod"])
def test_train_step_runs_and_loss_decreases(waxes):
    code = COMMON + f"""
cfg = replace(reduced(get_config("llama3.2-1b")), comp_worker_axes=tuple("{waxes}".split(",")))
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((2,2,2), ("pod","data","model"))
opt = make_optimizer(cfg, lr=0.02)
key = jax.random.PRNGKey(0)
params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
step_fn = build_train_step(cfg, opt, mesh, shape)
smesh, _ = resolve_train_mesh(mesh, opt.compression.worker_axes)
losses = []
for step in range(6):
    hb = make_lm_batch(cfg, shape, step)
    bs = batch_specs(hb, smesh)
    batch = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, NamedSharding(smesh, s)), hb, bs)
    params, opt_state, m = step_fn(params, opt_state, batch, jax.random.fold_in(key, step))
    losses.append(float(m["loss"]))
h_sum = float(sum(jnp.abs(l).sum() for l in jax.tree_util.tree_leaves(opt_state.diana.h_worker)))
print(json.dumps({{"losses": losses, "h_sum": h_sum}}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["losses"][-1] < out["losses"][0], out
    assert out["h_sum"] > 0


def test_vr_train_step_runs_and_loss_decreases():
    """End-to-end VR-DIANA trainer: the L-SVRG slot threads through
    init_train_state / shardings / the shard_map step on a real worker mesh,
    the loss decreases, and the snapshot state actually moves off x^0 (the
    step-0 forced refresh + later coins at vr_p=0.5)."""
    code = COMMON + """
cfg = replace(reduced(get_config("llama3.2-1b")), vr=True, vr_p=0.5)
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = make_mesh((4, 1), ("data", "model"))
opt = make_optimizer(cfg, lr=0.02)
key = jax.random.PRNGKey(0)
params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
params0 = jax.device_get(params)  # host copy: params is donated into step_fn
step_fn = build_train_step(cfg, opt, mesh, shape)
smesh, _ = resolve_train_mesh(mesh, opt.compression.worker_axes)
losses = []
for step in range(6):
    hb = make_lm_batch(cfg, shape, step)
    bs = batch_specs(hb, smesh)
    batch = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, NamedSharding(smesh, s)), hb, bs)
    params, opt_state, m = step_fn(params, opt_state, batch, jax.random.fold_in(key, step))
    losses.append(float(m["loss"]))
vr = opt_state.diana.vr
mu_sum = float(sum(jnp.abs(l).sum() for l in jax.tree_util.tree_leaves(vr.mu)))
snap_moved = float(max(jnp.abs(np.asarray(s) - np.asarray(p)[None]).max()
                       for s, p in zip(jax.tree_util.tree_leaves(vr.snapshot),
                                       jax.tree_util.tree_leaves(params0))))
print(json.dumps({"losses": losses, "mu_sum": mu_sum, "snap_moved": snap_moved}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["losses"][-1] < out["losses"][0], out
    assert out["mu_sum"] > 0, out
    assert out["snap_moved"] > 0, out


def test_distributed_matches_reference_bitwise():
    """aggregate_shardmap over a 4-worker mesh == reference_step, exactly."""
    code = """
import jax, jax.numpy as jnp, numpy as np, json, math
from functools import partial
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core import CompressionConfig, DianaState, aggregate_shardmap, init_state
from repro.core.diana import reference_init, reference_step
from repro.launch.mesh import make_mesh

# pure-data mesh: this test validates Algorithm-1 semantics (distributed ==
# reference, bitwise), not model parallelism — and XLA's partitioner is
# fragile around the aggregation ops when an auto 'model' axis coexists with
# manual subgroups (DESIGN.md §6)
mesh = make_mesh((4, 1), ("data", "model"))
cfg = CompressionConfig(method="diana", p=math.inf, block_size=64)
n = 4
params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((24,))}
key = jax.random.PRNGKey(42)
grads = {"w": jax.random.normal(key, (n, 32, 16)), "b": jax.random.normal(key, (n, 24))}

# --- reference (single process)
ref_state = reference_init(params, cfg, n)
v_ref, ref_new = reference_step(grads, ref_state, key, cfg)

# --- distributed
state = init_state(params, cfg, n)
def body(grads_stacked, h_worker, h_server, key):
    g_local = jax.tree_util.tree_map(lambda g: g[0], grads_stacked)
    widx = jax.lax.axis_index("data")
    wkey = jax.random.fold_in(key, widx)
    ghat, new_state = aggregate_shardmap(
        g_local, DianaState(h_worker, h_server), wkey, cfg,
        axis_names=("data",), n_workers=n)
    return ghat, new_state.h_worker, new_state.h_server

fn = shard_map(body, mesh=mesh,
    in_specs=(jax.tree_util.tree_map(lambda _: P("data"), grads),
              jax.tree_util.tree_map(lambda _: P("data"), state.h_worker),
              jax.tree_util.tree_map(lambda _: P(), state.h_server), P()),
    out_specs=(jax.tree_util.tree_map(lambda _: P(), params),
               jax.tree_util.tree_map(lambda _: P("data"), state.h_worker),
               jax.tree_util.tree_map(lambda _: P(), state.h_server)),
    axis_names={"data"}, check_vma=False)
ghat, h_w, h_s = jax.jit(fn)(grads, state.h_worker, state.h_server, key)

err_g = max(float(jnp.abs(a - b).max()) for a, b in zip(
    jax.tree_util.tree_leaves(ghat), jax.tree_util.tree_leaves(v_ref)))
err_hw = max(float(jnp.abs(a - b).max()) for a, b in zip(
    jax.tree_util.tree_leaves(h_w), jax.tree_util.tree_leaves(ref_new.h_worker)))
err_hs = max(float(jnp.abs(a - b).max()) for a, b in zip(
    jax.tree_util.tree_leaves(h_s), jax.tree_util.tree_leaves(ref_new.h_server)))
print(json.dumps({"err_g": err_g, "err_hw": err_hw, "err_hs": err_hs}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["err_g"] == 0.0, out
    assert out["err_hw"] == 0.0, out
    assert out["err_hs"] == 0.0, out


def test_compression_methods_all_lower():
    """Every compression policy builds a runnable distributed step."""
    code = COMMON + """
results = {}
for method in ("diana", "qsgd", "terngrad", "none"):
    cfg = replace(reduced(get_config("llama3.2-1b")), compression=method)
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    mesh = make_mesh((4,2), ("data","model"))
    opt = make_optimizer(cfg, lr=0.02)
    key = jax.random.PRNGKey(0)
    params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
    step_fn = build_train_step(cfg, opt, mesh, shape)
    smesh, _ = resolve_train_mesh(mesh, opt.compression.worker_axes)
    hb = make_lm_batch(cfg, shape, 0)
    bs = batch_specs(hb, smesh)
    batch = jax.tree_util.tree_map(lambda a, s: jax.device_put(a, NamedSharding(smesh, s)), hb, bs)
    params, opt_state, m = step_fn(params, opt_state, batch, key)
    results[method] = float(m["loss"])
print(json.dumps(results))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert all(v == v for v in out.values()), out  # no NaN


def test_serve_step_multi_device():
    code = """
import jax, jax.numpy as jnp, json
from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.serve import build_serve_step, serve_cache_shardings
from repro.models import init_model, init_caches
mesh = make_mesh((4, 2), ("data", "model"))
cfg = reduced(get_config("jamba-v0.1-52b"))
shape = ShapeConfig("d", seq_len=64, global_batch=8, kind="decode")
params = init_model(cfg, jax.random.PRNGKey(0))
caches = init_caches(cfg, shape.global_batch, shape.seq_len)
step = build_serve_step(cfg, mesh, shape)
tok = jnp.zeros((8, 1), jnp.int32)
logits, caches = step(params, caches, tok)
logits, caches = step(params, caches, tok)
print(json.dumps({"shape": list(logits.shape), "finite": bool(jnp.isfinite(logits).all())}))
"""
    out = json.loads(run_py(code).strip().splitlines()[-1])
    assert out["finite"], out
