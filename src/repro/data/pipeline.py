"""Data pipelines: synthetic LM token streams + the paper's convex problems.

Offline CI has no dataset downloads, so the LM stream is a deterministic
synthetic language with learnable structure (an order-1 affine-mod grammar
plus noise) — losses genuinely decrease during the end-to-end example, which
is what the substrate needs to prove.  Worker heterogeneity (the paper's
"loc. data": no similarity assumed between D_i) is modelled by giving each
worker its own grammar coefficients.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.diana_paper import LogRegProblem

__all__ = ["LMStream", "make_lm_batch", "logreg_data", "logistic_loss_and_grad"]


# ---------------------------------------------------------------------------
# Synthetic LM stream
# ---------------------------------------------------------------------------

@dataclass
class LMStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    noise: float = 0.1
    n_workers: int = 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        b, s, v = self.batch, self.seq_len, self.vocab
        # per-sequence worker assignment -> heterogeneous grammars
        worker = rng.integers(0, self.n_workers, size=(b, 1))
        a = 3 + 2 * worker                      # odd multiplier per worker
        c = 7 + 11 * worker
        toks = np.empty((b, s), dtype=np.int64)
        toks[:, 0] = rng.integers(0, v, size=b)
        noise_mask = rng.random((b, s)) < self.noise
        noise_tok = rng.integers(0, v, size=(b, s))
        for t in range(1, s):
            nxt = (toks[:, t - 1] * a[:, 0] + c[:, 0]) % v
            toks[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"tokens": toks.astype(np.int32)}


def make_lm_batch(cfg, shape, step: int, seed: int = 0, n_workers: int = 1) -> Dict[str, np.ndarray]:
    """One batch matching ``input_specs(cfg, shape)`` (labels + frontends)."""
    from repro.configs.shapes import input_specs

    specs = input_specs(cfg, shape)
    rng = np.random.default_rng(seed * 999_983 + step)
    out: Dict[str, np.ndarray] = {}
    if "tokens" in specs:
        b, s = specs["tokens"].shape
        stream = LMStream(vocab=cfg.vocab, seq_len=s, batch=b, seed=seed + step, n_workers=n_workers)
        out["tokens"] = stream.batch_at(step)["tokens"]
    if "labels" in specs:
        out["labels"] = np.roll(out["tokens"], -1, axis=1)
    for k in ("vision_embeds", "audio_embeds"):
        if k in specs:
            out[k] = rng.standard_normal(specs[k].shape).astype(np.float32)
    return out


# ---------------------------------------------------------------------------
# Convex problems (paper Sec. 6 / M.2)
# ---------------------------------------------------------------------------

def logreg_data(problem: LogRegProblem):
    """Synthetic binary classification split across heterogeneous workers.

    Each worker's feature distribution is shifted/scaled differently (no
    similarity between D_i — the paper's setting).  Returns
    (features (n_workers, m, dim), labels (n_workers, m) in {-1, +1}, x_star-ish init).
    """
    rng = np.random.default_rng(problem.seed)
    n, d, w = problem.n_samples, problem.dim, problem.n_workers
    m = n // w
    true_w = rng.standard_normal(d) / math.sqrt(d)
    feats, labels = [], []
    for i in range(w):
        shift = 0.5 * rng.standard_normal(d) * (i / max(w - 1, 1))
        scale = 1.0 + 0.5 * (i / max(w - 1, 1))
        X = rng.standard_normal((m, d)) * scale + shift
        X /= np.linalg.norm(X, axis=1, keepdims=True).clip(1e-8)   # row-normalised
        logits = X @ true_w + 0.1 * rng.standard_normal(m)
        y = np.where(logits > 0, 1.0, -1.0)
        feats.append(X)
        labels.append(y)
    return np.stack(feats).astype(np.float32), np.stack(labels).astype(np.float32)


def logistic_loss_and_grad(w, X, y, l2: float):
    """Per-worker regularised logistic loss/grad (numpy reference for tests).

    loss = mean log(1 + exp(-y x·w)) + l2/2 ||w||^2.
    """
    z = y * (X @ w)
    loss = np.mean(np.log1p(np.exp(-z))) + 0.5 * l2 * float(w @ w)
    sig = 1.0 / (1.0 + np.exp(z))
    grad = -(X * (y * sig)[:, None]).mean(0) + l2 * w
    return loss, grad
