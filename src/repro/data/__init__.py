from .pipeline import LMStream, make_lm_batch, logreg_data, logistic_loss_and_grad

__all__ = ["LMStream", "make_lm_batch", "logreg_data", "logistic_loss_and_grad"]
