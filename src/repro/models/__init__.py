"""Model zoo: one decoder-only family covering all assigned architectures."""

from . import layers, mamba2, moe, sharding, transformer
from .transformer import (
    init_model,
    forward,
    train_loss,
    init_caches,
    decode_step,
    count_params,
    count_active_params,
    model_flops_per_token,
)
from .sharding import shard, sharding_policy, GSPMDPolicy

__all__ = [
    "layers", "mamba2", "moe", "sharding", "transformer",
    "init_model", "forward", "train_loss", "init_caches", "decode_step",
    "count_params", "count_active_params", "model_flops_per_token",
    "shard", "sharding_policy", "GSPMDPolicy",
]
