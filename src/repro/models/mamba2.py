"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
math *within* chunks (MXU-friendly einsums) and a linear recurrence *across*
chunks carried by ``lax.scan`` — the TPU-native formulation of the paper's
block-decomposition.  Decode keeps the O(1) recurrent state
``(B, H, P, N)`` plus a depthwise-conv ring of width-1 inputs.

Sequence length must divide ``chunk_size`` (all assigned shapes do).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .sharding import shard

__all__ = ["init_mamba", "mamba_layer", "MambaCache", "init_mamba_cache"]


class MambaCache(NamedTuple):
    conv: jax.Array    # (B, W-1, conv_channels) — last inputs for the causal conv
    ssm: jax.Array     # (B, H, P, N) — recurrent state
    pos: jax.Array


def _dims(cfg):
    sc = cfg.ssm
    d_in = sc.d_inner(cfg.d_model)
    h = sc.n_heads(cfg.d_model)
    return sc, d_in, h, sc.head_dim, sc.d_state, sc.n_groups


def init_mamba(key, cfg, dtype) -> dict:
    sc, d_in, h, p, n, g = _dims(cfg)
    conv_ch = d_in + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(cfg.d_model)
    d_proj = 2 * d_in + 2 * g * n + h       # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(k1, (cfg.d_model, d_proj)) * s).astype(dtype),
        "conv_w": (jax.random.normal(k2, (sc.conv_width, conv_ch)) / math.sqrt(sc.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": (jax.random.normal(k4, (d_in, cfg.d_model)) * (1.0 / math.sqrt(d_in)) / math.sqrt(2 * cfg.n_layers)).astype(dtype),
    }


def init_mamba_cache(cfg, batch: int, dtype) -> MambaCache:
    sc, d_in, h, p, n, g = _dims(cfg)
    conv_ch = d_in + 2 * g * n
    return MambaCache(
        conv=jnp.zeros((batch, sc.conv_width - 1, conv_ch), dtype),
        ssm=jnp.zeros((batch, h, p, n), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def _segsum(at):
    """Stable segment-sum: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums."""
    q = at.shape[-1]
    cs = jnp.cumsum(at, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(q)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def _ssd_chunked(xt, at, b_, c_, chunk: int, unroll: bool = False):
    """Chunked SSD scan.

    xt: (B, L, H, P) — dt-discretised inputs (x * dt)
    at: (B, L, H)    — dt-discretised log-decays (A * dt, negative)
    b_, c_: (B, L, H, N) — input/output projections (already group-broadcast)
    Returns y: (B, L, H, P).
    """
    bsz, l, h, p = xt.shape
    n = b_.shape[-1]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    c = l // chunk

    def r(t):  # (B, L, ...) -> (B, C, Q, ...)
        return t.reshape(bsz, c, chunk, *t.shape[2:])

    xt, at, b_, c_ = r(xt), r(at), r(b_), r(c_)
    at = at.astype(jnp.float32)

    # --- intra-chunk (quadratic, MXU): Y_diag = (C B^T ∘ L) X
    lmat = jnp.exp(_segsum(jnp.moveaxis(at, -1, 2)))            # (B,C,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", c_.astype(jnp.float32), b_.astype(jnp.float32))
    y_diag = jnp.einsum("bchqk,bchqk,bckhp->bcqhp", scores, lmat, xt.astype(jnp.float32))

    # --- chunk states: what each chunk contributes to the running state
    a_cum = jnp.cumsum(at, axis=2)                               # (B,C,Q,H)
    a_tot = a_cum[:, :, -1]                                      # (B,C,H)
    decay_states = jnp.exp(a_tot[:, :, None] - a_cum)            # (B,C,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", b_.astype(jnp.float32), decay_states, xt.astype(jnp.float32))

    # --- inter-chunk recurrence (linear scan over chunks)
    def step(carry, inp):
        st, a_t = inp                                            # (B,H,P,N), (B,H)
        new = carry * jnp.exp(a_t)[:, :, None, None] + st
        return new, carry                                        # emit state BEFORE this chunk

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0)),
        unroll=c if unroll else 1,
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)                # (B,C,H,P,N)

    # --- inter-chunk output: Y_off = C · (decay_in * prev_state)
    decay_out = jnp.exp(a_cum)                                   # (B,C,Q,H)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", c_.astype(jnp.float32), decay_out, prev_states)

    return (y_diag + y_off).reshape(bsz, l, h, p)


def _split_proj(proj, cfg):
    sc, d_in, h, p, n, g = _dims(cfg)
    z, x, b_, c_, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + g * n, 2 * d_in + 2 * g * n], axis=-1
    )
    return z, x, b_, c_, dt


def _conv_full(params, u, cfg):
    """Causal depthwise conv over (B, L, CH) with width W."""
    w = params["conv_w"].astype(jnp.float32)                     # (W, CH)
    width = w.shape[0]
    up = jnp.pad(u.astype(jnp.float32), ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(up[:, i : i + u.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + params["conv_b"].astype(jnp.float32)).astype(cfg.compute_dtype)


def mamba_layer(
    params, x, cfg, cache: Optional[MambaCache] = None
) -> Tuple[jax.Array, Optional[MambaCache]]:
    """x: (B, S, D) -> (out, new_cache).  cache=None: chunked SSD (train/prefill);
    else single-token recurrent decode."""
    sc, d_in, h, p, n, g = _dims(cfg)
    bsz, s, _ = x.shape
    rep = h // g

    proj = x @ params["in_proj"].astype(cfg.compute_dtype)       # (B,S,dproj)
    z, xr, braw, craw, dt_raw = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xr, braw, craw], axis=-1)

    if cache is None:
        conv_out = _conv_full(params, conv_in, cfg)
        new_cache = None
    else:
        assert s == 1
        hist = jnp.concatenate([cache.conv.astype(cfg.compute_dtype), conv_in], axis=1)
        w = params["conv_w"].astype(jnp.float32)
        out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), w)
        conv_out = jax.nn.silu(out + params["conv_b"].astype(jnp.float32))[:, None].astype(cfg.compute_dtype)
        new_conv = hist[:, 1:]
    xr, braw, craw = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)

    xt = xr.reshape(bsz, s, h, p)
    xt = shard(xt, "batch", None, "model", None)
    bmat = braw.reshape(bsz, s, g, n)
    cmat = craw.reshape(bsz, s, g, n)
    bh = jnp.repeat(bmat, rep, axis=2)                           # (B,S,H,N)
    ch = jnp.repeat(cmat, rep, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])     # (B,S,H)
    a = -jnp.exp(params["A_log"])                                # (H,)

    if cache is None:
        y = _ssd_chunked(
            xt.astype(jnp.float32) * dt[..., None],
            a * dt,
            bh,
            ch,
            min(sc.chunk_size, s),
            unroll=getattr(cfg, "scan_unroll", False),
        )
    else:
        dt0 = dt[:, 0]                                           # (B,H)
        decay = jnp.exp(a * dt0)                                 # (B,H)
        xin = xt[:, 0].astype(jnp.float32) * dt0[..., None]      # (B,H,P)
        new_ssm = (
            cache.ssm * decay[:, :, None, None]
            + xin[..., None] * bh[:, 0, :, None, :].astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bhn->bhp", new_ssm, ch[:, 0].astype(jnp.float32))[:, None]
        new_cache = MambaCache(conv=new_conv, ssm=new_ssm, pos=cache.pos + 1)

    y = y + params["D"][:, None] * xt.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(gated * gated, axis=-1, keepdims=True)
    yn = gated * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"].astype(jnp.float32)

    out = yn.astype(cfg.compute_dtype) @ params["out_proj"].astype(cfg.compute_dtype)
    return shard(out, "batch", None, None), new_cache
