"""Sharding-policy context: decouples model code from the runtime mode.

Model code annotates activations/params with *logical* axes via
``shard(x, "batch", None, "model")``; the active policy translates that into a
``with_sharding_constraint`` (or a no-op on a single device / in unit tests).

Three policies:
* ``NoopPolicy``       — default (CPU tests, examples).
* ``GSPMDPolicy``      — full-auto jit (serve_step, dryrun): every logical axis
                         maps to mesh axes present in the mesh.
* ``GSPMDPolicy(manual=...)`` — inside a ``shard_map`` whose manual axes are the
                         DIANA worker axes: logical axes that resolve to manual
                         mesh axes are dropped (the dimension is already local).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["shard", "shard_spec", "sharding_policy", "GSPMDPolicy", "current_policy", "LOGICAL_RULES"]

# Logical axis -> mesh axes. 'batch' spans the data axes — including the
# optional 'node' axis of the hierarchical aggregation topology (a worker
# axis like 'pod'/'data', marking the intra-node boundary; DESIGN.md
# §Topology); tensors sharded over 'model' use the logical name 'model';
# 'seq' is used by long-context decode caches (sequence parallelism);
# 'expert' by expert-parallel MoE.
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "node", "data"),
    "model": ("model",),
    "expert": ("model",),
    "seq": ("pod", "node", "data"),
    "fsdp": ("data",),
}


class _Policy:
    def apply(self, x, *logical):
        return x

    def spec(self, *logical) -> Optional[P]:
        return None


class NoopPolicy(_Policy):
    pass


class GSPMDPolicy(_Policy):
    def __init__(self, mesh, manual: Sequence[str] = (), rules: Dict[str, Tuple[str, ...]] = None):
        self.mesh = mesh
        self.manual = frozenset(manual)
        self.rules = dict(LOGICAL_RULES, **(rules or {}))

    def _resolve(self, logical):
        """Logical names -> PartitionSpec over available, non-manual mesh axes."""
        axis_names = set(self.mesh.axis_names)
        out = []
        for name in logical:
            if name is None:
                out.append(None)
                continue
            axes = tuple(
                a for a in self.rules.get(name, ())
                if a in axis_names and a not in self.manual
            )
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        # trim trailing Nones (cosmetic)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def spec(self, *logical):
        return self._resolve(logical)

    def apply(self, x, *logical):
        spec = self._resolve(logical)
        if all(s is None for s in spec):
            return x
        # Inside a shard_map body the constraint must reference the tracing
        # context's ABSTRACT mesh (whose manual axes carry Manual axis types);
        # the concrete mesh is only valid at the jit boundary.
        mesh = self.mesh
        try:
            amesh = jax.sharding.get_abstract_mesh()
            if amesh is not None and not amesh.empty:
                mesh = amesh
        except Exception:
            pass
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


_tls = threading.local()


def current_policy() -> _Policy:
    return getattr(_tls, "policy", None) or NoopPolicy()


@contextlib.contextmanager
def sharding_policy(policy: _Policy):
    prev = getattr(_tls, "policy", None)
    _tls.policy = policy
    try:
        yield
    finally:
        _tls.policy = prev


def shard(x, *logical):
    """Annotate array ``x`` with logical axes (no-op without a policy)."""
    return current_policy().apply(x, *logical)


def shard_forced(x, *logical):
    """Like :func:`shard` but ALWAYS applies the constraint, including
    explicit replication for None dims.  Used where XLA's sharding
    propagation makes partitioner-crashing choices (MoE dispatch under
    manual subgroups) — every intermediate is pinned."""
    policy = current_policy()
    if not isinstance(policy, GSPMDPolicy):
        return x
    spec = policy.spec(*logical)
    full = P(*(tuple(spec) + (None,) * (x.ndim - len(spec))))
    mesh = policy.mesh
    try:
        amesh = jax.sharding.get_abstract_mesh()
        if amesh is not None and not amesh.empty:
            mesh = amesh
    except Exception:
        pass
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, full))


def shard_replicated(x):
    """FORCE replication (an explicit P() constraint, unlike shard(x, None...)
    which is a no-op).  Used on small per-layer vectors (norm scales etc.)
    whose scan-sliced stacked form the propagation otherwise mis-shards,
    tripping the SPMD partitioner under multiple manual axes."""
    policy = current_policy()
    if not isinstance(policy, GSPMDPolicy):
        return x
    mesh = policy.mesh
    try:
        amesh = jax.sharding.get_abstract_mesh()
        if amesh is not None and not amesh.empty:
            mesh = amesh
    except Exception:
        pass
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*((None,) * x.ndim)))
    )


def shard_spec(*logical) -> Optional[P]:
    return current_policy().spec(*logical)
