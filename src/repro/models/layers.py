"""Transformer building blocks: RMSNorm, RoPE, GQA attention (train/prefill,
cached decode, sliding-window), dense MLP variants.

Functional style: ``init_*`` returns a param pytree, ``apply`` functions are
pure.  Sharding is annotated through :mod:`repro.models.sharding` logical axes
so the same code runs on one CPU device, under full GSPMD, or inside the
DIANA shard_map.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .sharding import shard, shard_replicated

__all__ = [
    "rms_norm", "init_rms_norm",
    "rope_freqs", "apply_rope",
    "init_attention", "attention", "AttnCache", "init_attn_cache",
    "init_mlp", "mlp",
]


# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------

def init_rms_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params, x, eps: float = 1e-5):
    scale = shard_replicated(params["scale"])
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) int32.

    Rotate-half (contiguous-halves) convention.  NOTE: the interleaved
    convention's strided slices ``x[..., 0::2]`` lower to HLO gathers whose
    SPMD partitioning crashes XLA under manual subgroups (CHECK failure in
    spmd_partitioner_util) — contiguous half-slices lower to plain slices and
    partition cleanly.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                                  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (B, S, Dh/2)
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., : dh // 2], xf[..., dh // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, cached decode)
# ---------------------------------------------------------------------------

class AttnCache(NamedTuple):
    """KV cache. The sliding window size is NOT stored here (it must stay a
    static Python value — caches get stacked/scanned); pass ``window=`` to
    :func:`attention` consistently with how the cache was initialised.

    bf16 caches are STORED as bit-equal uint16: XLA's CPU backend promotes
    bf16 dynamic-update-slice to f32, which would triple decode memory in the
    dry-run (and the integer view is harmless on TPU).  ``_cache_view`` /
    ``_cache_store`` do the bitcasts."""

    k: jax.Array          # (B, S_cache, Hkv, Dh) — S_cache = seq or window
    v: jax.Array
    pos: jax.Array        # () int32 — absolute position of next token


def _storage_dtype(dtype):
    return jnp.uint16 if dtype == jnp.bfloat16 else dtype


def _cache_view(buf, dtype):
    """storage -> compute view (bit-equal)."""
    if buf.dtype == jnp.uint16 and dtype == jnp.bfloat16:
        return jax.lax.bitcast_convert_type(buf, jnp.bfloat16)
    return buf


def _cache_store(x, storage_dtype):
    if storage_dtype == jnp.uint16 and x.dtype != jnp.uint16:
        return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)
    return x.astype(storage_dtype)


def init_attention(key, cfg, dtype) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": (jax.random.normal(k1, (d, h * dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv * dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv * dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (h * dh, d)) * s / math.sqrt(2 * cfg.n_layers)).astype(dtype),
    }


def init_attn_cache(cfg, batch: int, max_len: int, dtype, window: Optional[int] = None) -> AttnCache:
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    w = int(window or 0)
    s_cache = min(max_len, w) if w else max_len
    sdt = _storage_dtype(dtype)
    return AttnCache(
        k=jnp.zeros((batch, s_cache, hkv, dh), sdt),
        v=jnp.zeros((batch, s_cache, hkv, dh), sdt),
        pos=jnp.zeros((), jnp.int32),
    )


def _sdpa(q, k, v, mask, cfg):
    """q: (B,Sq,H,Dh), k/v: (B,Sk,Hkv,Dh), mask: (B,1,Sq,Sk) bool.

    H-major score layout with the KV heads repeated to H: the (g, r)-grouped
    layout leaves the S x S score tensor unshardable over 'model' (propagation
    replicates 10s of GiB at train_4k scale); in H-major the scores pin to
    P(_, 'model', _, _) whenever H divides the axis.  k/v stay in their
    storage dtype with f32 MXU accumulation (``preferred_element_type``)."""
    from .sharding import GSPMDPolicy, current_policy

    h, hkv = q.shape[2], k.shape[2]
    rep = h // hkv
    dh = q.shape[-1]
    qs = (q.astype(jnp.float32) / math.sqrt(dh)).astype(k.dtype)
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k         # broadcast, no gather
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v

    hs = "model"
    pol = current_policy()
    if isinstance(pol, GSPMDPolicy):
        ms = pol.mesh.shape.get("model", 1)
        if h % ms:
            hs = None                                          # uneven heads: replicate

    scores = jnp.einsum("bqhd,bkhd->bhqk", qs, kr,
                        preferred_element_type=jnp.float32)
    scores = shard(scores, "batch", hs, None, None)
    scores = jnp.where(mask, scores, -1e30)                    # mask (B,1,Sq,Sk)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = shard(probs, "batch", hs, None, None)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vr.dtype), vr,
                     preferred_element_type=jnp.float32)
    return out.astype(cfg.compute_dtype)


def _sdpa_qchunked(q, k, v, cfg, *, window, chunk: int):
    """Query-chunked causal attention: O(S^2) compute, O(chunk * S) score
    memory — the S x S score tensor at prefill_32k would be 16 GiB/device.

    Scans over query chunks (full keys per chunk, masked softmax); with
    ``cfg.scan_unroll`` the scan is statically unrolled (no dynamic-slice —
    required under multiple manual mesh axes, see train.py)."""
    b, s, h, dh = q.shape
    nq = s // chunk
    qb = jnp.moveaxis(q.reshape(b, nq, chunk, h, dh), 1, 0)     # (nq, B, cq, H, Dh)
    idx_k = jnp.arange(s)

    def one(qi, qc):
        q_pos = qi * chunk + jnp.arange(chunk)
        m = q_pos[:, None] >= idx_k[None, :]
        if window:
            m &= q_pos[:, None] - idx_k[None, :] < window
        return _sdpa(qc, k, v, m[None, None], cfg)              # (B, cq, H, Dh)

    # remat each chunk: without it the map/backward keeps every chunk's
    # (B, H, cq, S) score tensor live simultaneously
    one = jax.checkpoint(one)

    if getattr(cfg, "scan_unroll", False):
        outs = [one(i, qb[i]) for i in range(nq)]
        return jnp.concatenate(outs, axis=1)
    stacked = jax.lax.map(lambda args: one(*args), (jnp.arange(nq), qb))
    return jnp.moveaxis(stacked, 0, 1).reshape(b, s, h, dh)


DECODE_KV_CHUNK = 4096


def _decode_attention(q, k_cache, v_cache, valid, cfg):
    """Flash-decoding: one query token against a long cache, KV-chunked with
    online (max, num, den) combination.

    Exact softmax attention; the chunking bounds the working set — the CPU
    dry-run backend otherwise materialises an f32 convert of the ENTIRE cache
    for the score dot (8 GiB/device at decode_32k), and on TPU the chunk loop
    is where sequence-parallel partial results combine (two small
    all-reduces when the cache seq dim is sharded).
    """
    b, _, h, dh = q.shape
    s_cache, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    ck = min(DECODE_KV_CHUNK, s_cache)
    if s_cache % ck:
        ck = s_cache  # fall back to single chunk for odd cache lengths
    nk = s_cache // ck

    compute_kv = cfg.compute_dtype
    qg = (q.astype(jnp.float32) / math.sqrt(dh)).astype(compute_kv)
    qg = qg.reshape(b, hkv, rep, dh)                       # Sq == 1 squeezed

    kb = jnp.moveaxis(k_cache.reshape(b, nk, ck, hkv, dh), 1, 0)
    vb = jnp.moveaxis(v_cache.reshape(b, nk, ck, hkv, dh), 1, 0)
    validb = valid.reshape(nk, ck)

    def chunk_fn(args):
        kc, vc, vm = args                                  # (B,ck,Hkv,Dh), (ck,)
        kc = _cache_view(kc, compute_kv)                   # u16 storage -> bf16
        vc = _cache_view(vc, compute_kv)
        s = jnp.einsum("bgrd,bkgd->bgrk", qg, kc, preferred_element_type=jnp.float32)
        s = jnp.where(vm[None, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)             # (B,g,r,1)
        e = jnp.exp(s - m)
        num = jnp.einsum("bgrk,bkgd->bgrd", e.astype(vc.dtype), vc,
                         preferred_element_type=jnp.float32)
        den = jnp.sum(e, axis=-1, keepdims=True)
        return m[..., 0], num, den[..., 0]

    ms, nums, dens = jax.lax.map(chunk_fn, (kb, vb, validb))
    m_all = jnp.max(ms, axis=0, keepdims=True)             # (1,B,g,r)
    scale = jnp.exp(ms - m_all)                            # (nk,B,g,r)
    num = jnp.sum(nums * scale[..., None], axis=0)         # (B,g,r,Dh)
    den = jnp.sum(dens * scale, axis=0)                    # (B,g,r)
    out = num / jnp.maximum(den[..., None], 1e-30)
    return out.reshape(b, 1, h, dh).astype(cfg.compute_dtype)


def attention(
    params,
    x,
    cfg,
    positions,
    cache: Optional[AttnCache] = None,
    window: Optional[int] = None,
):
    """Returns (out, new_cache).

    * cache is None: full (or sliding-window-masked) causal self-attention over
      ``x`` — the train / prefill path.
    * cache is not None: ``x`` is one new token per sequence (S=1); the KV cache
      is updated (ring buffer when ``cache.window > 0``) — the decode path.
    """
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    q = (x @ params["wq"].astype(cfg.compute_dtype)).reshape(b, s, h, dh)
    k = (x @ params["wk"].astype(cfg.compute_dtype)).reshape(b, s, hkv, dh)
    v = (x @ params["wv"].astype(cfg.compute_dtype)).reshape(b, s, hkv, dh)
    # q heads shard over 'model' (policy drops the axis when not divisible);
    # kv heads are replicated over 'model' when n_kv_heads < model axis (GQA).
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, None, None)
    v = shard(v, "batch", None, None, None)

    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        cq = max(int(getattr(cfg, "attn_q_chunk", 0) or 0), 0)
        if cq and s > cq and s % cq == 0:
            out = _sdpa_qchunked(q, k, v, cfg, window=window, chunk=cq)
        else:
            idx = jnp.arange(s)
            causal = idx[:, None] >= idx[None, :]                      # (Sq, Sk)
            if window:
                causal &= idx[:, None] - idx[None, :] < window
            out = _sdpa(q, k, v, causal[None, None], cfg)              # broadcast over (B,1)
        new_cache = None
    else:
        assert s == 1, "decode path expects one new token"
        w = int(window or 0)
        slot = cache.pos % w if w else cache.pos
        k_cache = _update_cache(cache.k, _cache_store(k, cache.k.dtype), slot)
        v_cache = _update_cache(cache.v, _cache_store(v, cache.v.dtype), slot)
        k_cache = shard(k_cache, "batch" if b > 1 else None, "seq" if b == 1 else None, None, None)
        v_cache = shard(v_cache, "batch" if b > 1 else None, "seq" if b == 1 else None, None, None)

        s_cache = k_cache.shape[1]
        cache_idx = jnp.arange(s_cache)
        if w:
            # ring buffer: slot j holds absolute position pos - ((slot - j) mod w);
            # valid iff that position has been written (>= 0).
            age = (slot - cache_idx) % w
            abs_pos = cache.pos - age
            valid = abs_pos >= 0
        else:
            valid = cache_idx <= cache.pos
        out = _decode_attention(q, k_cache, v_cache, valid, cfg)
        new_cache = AttnCache(k=k_cache, v=v_cache, pos=cache.pos + 1)

    out = out.reshape(b, s, h * dh)
    out = out @ params["wo"].astype(cfg.compute_dtype)
    return shard(out, "batch", None, None), new_cache


def _update_cache(buf, new, slot):
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), (0, slot, 0, 0))


# ---------------------------------------------------------------------------
# Dense MLP (swiglu / gelu / squared-relu)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    p = {
        "w_in": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (f, d)) * s_out).astype(dtype),
    }
    if cfg.act == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * s_in).astype(dtype)
    return p


def mlp(params, x, cfg):
    h = x @ params["w_in"].astype(cfg.compute_dtype)
    h = shard(h, "batch", None, "model")
    if cfg.act == "swiglu":
        g = x @ params["w_gate"].astype(cfg.compute_dtype)
        g = shard(g, "batch", None, "model")
        h = jax.nn.silu(g) * h
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.act == "relu2":  # Nemotron-4 squared ReLU
        r = jax.nn.relu(h)
        h = r * r
    else:
        raise ValueError(f"unknown activation {cfg.act}")
    out = h @ params["w_out"].astype(cfg.compute_dtype)
    return shard(out, "batch", None, None)
