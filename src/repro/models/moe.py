"""Token-choice top-k Mixture-of-Experts with capacity buckets.

Dispatch is the sort-free "scatter into per-expert capacity buckets" pattern:
tokens are replicated ``top_k`` times, bucketed into an ``(E, capacity, D)``
buffer (overflow dropped), processed by a batched per-expert SwiGLU, and
combined back with a gather-free slot->token segment-sum.

Two execution paths, one math:

* **Pure GSPMD** (serving, single-device tests): expert dim sharded over
  'model' (expert parallelism) when divisible, else per-expert d_ff
  (tensor-parallel experts — granite-moe's 40 experts on a 16 axis).

* **Nested manual shard_map over 'model'** whenever the caller is already
  inside a manual (DIANA-worker) shard_map.  XLA's SPMD partitioner crashes
  non-deterministically when it must place the data-dependent dispatch
  scatters next to model-sharded einsums inside a manual subgroup
  (spmd_partitioner.cc:552 IsManualSubgroup CHECK — see DESIGN.md §6), so
  under manual axes the WHOLE layer runs fully manual: the (cheap) routing
  math is replicated per model shard, the expert FFN uses hand-written
  collectives (EP all-gather / Megatron psum), and the partitioner never
  sees the region.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import GSPMDPolicy, NoopPolicy, current_policy, shard, shard_forced, sharding_policy

__all__ = ["init_moe", "moe_layer"]


def init_moe(key, cfg, dtype) -> dict:
    mc = cfg.moe
    d, f, e = cfg.d_model, mc.d_ff, mc.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    return {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(k2, (e, d, f)) * s_in).astype(dtype),
        "w_gate": (jax.random.normal(k3, (e, d, f)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k4, (e, f, d)) * s_out).astype(dtype),
    }


def _expert_spec(cfg):
    """Logical axes of the expert weight tensors, per partition mode."""
    if cfg.moe.partition == "expert":
        return ("expert", None, None), ("expert", None, None)
    return (None, None, "model"), (None, "model", None)  # ffn-partitioned


def _dispatch(router, xf, cfg):
    """Routing + capacity bucketing (gather-free). xf: (T, D)."""
    mc = cfg.moe
    t, d = xf.shape
    e, k = mc.n_experts, mc.top_k
    cap = max(1, int(mc.capacity_factor * t * k / e))

    logits = (xf.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                                    # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Switch-style load-balance loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce) * mc.aux_loss_weight

    flat_e = top_e.reshape(-1)                                                # (T*k,)
    flat_w = top_p.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    eo = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(eo, axis=0) * eo, axis=-1) - 1                   # pos within expert
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)

    xf_rep = jnp.repeat(xf.astype(cfg.compute_dtype), k, axis=0)              # == xf[tok_idx]
    buf = jnp.zeros((e * cap + 1, d), cfg.compute_dtype).at[slot].set(xf_rep)
    buf = buf[: e * cap].reshape(e, cap, d)

    w_eff = jnp.where(keep, flat_w, 0.0).astype(cfg.compute_dtype)
    tok_slot = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(tok_idx)
    w_slot = jnp.zeros((e * cap + 1,), cfg.compute_dtype).at[slot].set(w_eff)
    return buf, tok_slot, w_slot, cap, aux


def _combine(y, tok_slot, w_slot, t, d, cfg, *, expert_pin: bool = False):
    """Gather-free combine: empty slots contribute exactly 0 (bias-free
    SwiGLU(0) == 0 and their scattered weight is 0).

    ``expert_pin`` keeps the padded slot buffer expert-sharded so the
    segment-sum partitions into per-shard partial sums + an all-reduce of the
    (tokens, d) result — top_k*cf x fewer bytes than all-gathering the
    (E*cap, d) slots (§Perf, same linearity trick as the manual path)."""
    e_cap = y.shape[0] * y.shape[1]
    y_pad = jnp.concatenate(
        [y.reshape(e_cap, d), jnp.zeros((1, d), y.dtype)], axis=0
    )
    if expert_pin:
        y_pad = shard_forced(y_pad, "expert", None)
    combined = jax.ops.segment_sum(
        y_pad * w_slot[:, None], tok_slot, num_segments=t + 1
    )[:t]
    if expert_pin:
        combined = shard_forced(combined, None, None)
    return combined


def _swiglu(buf, w_in, w_gate, w_out):
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)


MOE_TOKEN_CHUNK = 16_384  # dispatch-buffer working set: chunk x d x top_k x cf


def _moe_chunked(xf, run_chunk, cfg):
    """Sequentially process token chunks — the dispatch buffers are
    O(chunk * top_k * capacity_factor * d) instead of O(T * ...): at 1M global
    tokens the unchunked buffers are 10s-100s of GiB/device.  Each chunk is
    rematerialised in the backward pass so only ONE chunk's dispatch
    intermediates are ever live (otherwise the map saves all of them).

    ``cfg.moe.token_chunk`` trades HBM weight-restreaming (every chunk streams
    all expert weights) against dispatch-buffer memory — a §Perf knob."""
    t, d = xf.shape
    chunk = getattr(cfg.moe, "token_chunk", 0) or MOE_TOKEN_CHUNK
    if t <= chunk or t % chunk:
        return run_chunk(xf)
    nc = t // chunk
    xb = xf.reshape(nc, chunk, d)
    run_chunk = jax.checkpoint(run_chunk)
    if getattr(cfg, "scan_unroll", False):
        outs, auxs = zip(*(run_chunk(xb[i]) for i in range(nc)))
        return jnp.concatenate(outs, axis=0), sum(auxs) / nc
    combined, auxs = jax.lax.map(run_chunk, xb)
    return combined.reshape(t, d), jnp.mean(auxs)


def moe_layer(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    mc = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    dtype = cfg.compute_dtype

    pol = current_policy()
    inner_axes = ()
    manual_ok = False
    if isinstance(pol, GSPMDPolicy) and pol.manual and "model" in pol.mesh.axis_names \
            and "model" not in pol.manual:
        msize = pol.mesh.shape["model"]
        expert_mode = mc.partition == "expert" and mc.n_experts % msize == 0
        ffn_mode = mc.partition == "ffn" and mc.d_ff % msize == 0
        if expert_mode or ffn_mode:
            manual_ok = True
            # go fully manual over EVERY non-worker axis: any remaining auto
            # axis would put the dispatch scatters back in partitioner hands
            inner_axes = tuple(a for a in pol.mesh.axis_names if a not in pol.manual)

    w_in = params["w_in"].astype(dtype)
    w_gate = params["w_gate"].astype(dtype)
    w_out = params["w_out"].astype(dtype)

    if not manual_ok:
        # ---- pure GSPMD path ----
        spec_in, spec_out = _expert_spec(cfg)
        ex = "expert" if mc.partition == "expert" else None
        wi = shard(w_in, *spec_in)
        wg = shard(w_gate, *spec_in)
        wo = shard(w_out, *spec_out)

        def run_chunk(xc):
            buf, tok_slot, w_slot, cap, aux = _dispatch(params["router"], xc, cfg)
            buf = shard_forced(buf, ex, None, None)
            y = _swiglu(buf, wi, wg, wo)
            y = shard_forced(y, ex, None, None)
            return _combine(y, tok_slot, w_slot, xc.shape[0], d, cfg,
                            expert_pin=ex is not None), aux

        combined, aux = _moe_chunked(xf, run_chunk, cfg)
        out = combined.reshape(b, s, d).astype(dtype)
        return shard(out, "batch", None, None), aux

    # ---- nested fully-manual path (inside a DIANA-worker shard_map) ----
    amesh = jax.sharding.get_abstract_mesh()
    x_spec = P("data") if "data" in inner_axes else P()

    if mc.partition == "expert":
        w_specs = (P("model"), P("model"), P("model"))

        def one_chunk(router, wi, wg, wo, xc):
            buf, tok_slot, w_slot, cap, aux = _dispatch(router, xc, cfg)
            e_loc = wi.shape[0]                     # experts on this shard
            eidx = jax.lax.axis_index("model") * e_loc
            buf_loc = jax.lax.dynamic_slice_in_dim(buf, eidx, e_loc, axis=0)
            y_loc = _swiglu(buf_loc, wi, wg, wo)
            y = jax.lax.all_gather(y_loc, "model", axis=0, tiled=True)
            return _combine(y, tok_slot, w_slot, xc.shape[0], d, cfg), aux
    else:
        w_specs = (P(None, None, "model"), P(None, None, "model"), P(None, "model", None))

        def one_chunk(router, wi, wg, wo, xc):
            buf, tok_slot, w_slot, cap, aux = _dispatch(router, xc, cfg)
            y_part = _swiglu(buf, wi, wg, wo)       # partial over local F slice
            # §Perf: combine BEFORE the psum — segment_sum is linear in y, so
            # psum(combine(y_part)) == combine(psum(y_part)) while moving
            # (tokens, d) instead of (E*cap, d) = top_k*cf x more bytes
            # (10x for granite-moe's top-8 @ cf 1.25).
            combined_part = _combine(y_part, tok_slot, w_slot, xc.shape[0], d, cfg)
            return jax.lax.psum(combined_part, "model"), aux

    def body(router, wi, wg, wo, xloc):
        with sharding_policy(NoopPolicy()):
            combined, aux = _moe_chunked(
                xloc, lambda xc: one_chunk(router, wi, wg, wo, xc), cfg
            )
            if "data" in inner_axes:
                aux = jax.lax.pmean(aux, "data")
            return combined, aux

    from repro.compat import shard_map as _shard_map

    combined, aux = _shard_map(
        body, mesh=amesh,
        in_specs=(P(),) + w_specs + (x_spec,),
        out_specs=(x_spec, P()),
        axis_names=set(inner_axes), check_vma=False,
    )(params["router"], w_in, w_gate, w_out, xf)
    out = combined.reshape(b, s, d).astype(dtype)
    return out, aux
