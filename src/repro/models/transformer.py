"""Decoder-only model family: embeddings + scanned block stack + LM head.

One code path serves all 10 assigned architectures through the config's
``pattern`` (a repeating tuple of LayerSpec), covering dense GQA transformers,
MoE variants, pure-SSM (mamba2), the Jamba hybrid interleave and the VLM /
audio stub-frontend models.

The layer stack lowers as ``lax.scan`` over ``n_blocks`` copies of the pattern
(stacked params) with configurable activation checkpointing — this keeps HLO
size O(pattern) instead of O(layers) so 52B-param graphs compile quickly in
the 512-device dry-run, and the remat policy is a §Perf knob.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba2 as M
from . import moe as MOE
from .sharding import shard

__all__ = [
    "init_model", "forward", "train_loss", "init_caches", "decode_step",
    "count_params", "model_flops_per_token", "FRONTEND_DIM",
]

FRONTEND_DIM = {"vision": 1024, "audio": 128}   # stub encoder output dims


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, spec, cfg, dtype) -> Dict[str, Any]:
    kmix, kmlp, kn1, kn2 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_rms_norm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = L.init_attention(kmix, cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = M.init_mamba(kmix, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp != "none":
        p["norm2"] = L.init_rms_norm(cfg.d_model, dtype)
        p["mlp"] = MOE.init_moe(kmlp, cfg, dtype) if spec.mlp == "moe" else L.init_mlp(kmlp, cfg, dtype)
    return p


def init_model(cfg, key) -> Dict[str, Any]:
    dtype = cfg.param_dtype
    keys = jax.random.split(key, 8)
    vpad, d = cfg.padded_vocab, cfg.d_model

    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (vpad, d)) * 0.02).astype(dtype),
        "final_norm": L.init_rms_norm(d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(keys[1], (d, vpad)) * 0.02).astype(dtype)
    if cfg.frontend != "none":
        fdim = FRONTEND_DIM[cfg.frontend]
        params["frontend_proj"] = {
            "w": (jax.random.normal(keys[2], (fdim, d)) / math.sqrt(fdim)).astype(dtype),
            "b": jnp.zeros((d,), dtype),
        }

    # stacked per-pattern-position params: leading dim n_blocks
    def init_block(bkey):
        lkeys = jax.random.split(bkey, len(cfg.pattern))
        return {
            f"layer{i}": _init_layer(lkeys[i], spec, cfg, dtype)
            for i, spec in enumerate(cfg.pattern)
        }

    bkeys = jax.random.split(keys[3], cfg.n_blocks)
    blocks = [init_block(k) for k in bkeys]
    params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int, *, window: Optional[int] = None):
    """Stacked (over n_blocks) tuple-of-pattern-position caches."""
    def one_block():
        caches = []
        for spec in cfg.pattern:
            if spec.mixer == "attn":
                caches.append(
                    L.init_attn_cache(cfg, batch, max_len, cfg.compute_dtype, window=window)
                )
            else:
                caches.append(M.init_mamba_cache(cfg, batch, cfg.compute_dtype))
        return tuple(caches)

    blocks = [one_block() for _ in range(cfg.n_blocks)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _block_apply(bparams, x, cfg, positions, bcaches, window):
    """Apply one pattern block. bcaches: tuple aligned with cfg.pattern or None."""
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, spec in enumerate(cfg.pattern):
        lp = bparams[f"layer{i}"]
        cache_i = bcaches[i] if bcaches is not None else None
        h = L.rms_norm(lp["norm1"], x, cfg.norm_eps)
        if spec.mixer == "attn":
            mix, nc = L.attention(lp["mixer"], h, cfg, positions, cache=cache_i, window=window)
        else:
            mix, nc = M.mamba_layer(lp["mixer"], h, cfg, cache=cache_i)
        x = x + mix
        if spec.mlp != "none":
            h2 = L.rms_norm(lp["norm2"], x, cfg.norm_eps)
            if spec.mlp == "moe":
                y, a = MOE.moe_layer(lp["mlp"], h2, cfg)
                aux = aux + a
            else:
                y = L.mlp(lp["mlp"], h2, cfg)
            x = x + y
        new_caches.append(nc)
    return x, aux, (tuple(new_caches) if bcaches is not None else None)


def _embed_inputs(params, batch, cfg):
    """Assemble the input embedding sequence (frontend stubs prepended)."""
    parts = []
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        fp = params["frontend_proj"]
        v = batch["vision_embeds"].astype(cfg.compute_dtype)
        parts.append(v @ fp["w"].astype(cfg.compute_dtype) + fp["b"].astype(cfg.compute_dtype))
    if cfg.frontend == "audio" and "audio_embeds" in batch:
        fp = params["frontend_proj"]
        a = batch["audio_embeds"].astype(cfg.compute_dtype)
        parts.append(a @ fp["w"].astype(cfg.compute_dtype) + fp["b"].astype(cfg.compute_dtype))
    if "tokens" in batch:
        emb = params["embed"].astype(cfg.compute_dtype)
        parts.append(emb[batch["tokens"]])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return shard(x, "batch", None, None)


def forward(
    params,
    batch: Dict[str, jax.Array],
    cfg,
    *,
    caches=None,
    window: Optional[int] = None,
    positions: Optional[jax.Array] = None,
    last_token_only: bool = False,
    return_hidden: bool = False,
):
    """Returns (logits (B, S, padded_vocab) f32, aux_loss, new_caches).

    ``last_token_only`` computes logits for the final position only — the
    serving prefill path, which avoids materialising the (B, S, V) tensor.
    ``return_hidden`` skips the LM head and returns the final hidden states
    (the chunked-CE training path computes logits per sequence chunk)."""
    x = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    body = partial(_block_apply, cfg=cfg, window=window)
    if cfg.remat == "full":
        body = jax.checkpoint(body, static_argnums=())
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    def scan_fn(carry, xs):
        x, aux = carry
        if caches is None:
            bparams = xs
            x, a, _ = body(bparams, x, positions=positions, bcaches=None)
            return (x, aux + a), None
        bparams, bcaches = xs
        x, a, ncaches = body(bparams, x, positions=positions, bcaches=bcaches)
        return (x, aux + a), ncaches

    xs = params["blocks"] if caches is None else (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(
        scan_fn,
        (x, jnp.zeros((), jnp.float32)),
        xs,
        unroll=cfg.n_blocks if getattr(cfg, "scan_unroll", False) else 1,
    )

    if last_token_only:
        x = x[:, -1:]
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux, new_caches
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cfg.compute_dtype)
    logits = (x @ head).astype(jnp.float32)
    logits = shard(logits, "batch", None, "model")
    return logits, aux, new_caches


# ---------------------------------------------------------------------------
# Training loss / decode step
# ---------------------------------------------------------------------------

CE_SEQ_CHUNK = 512


def train_loss(params, batch, cfg, *, window: Optional[int] = None):
    """Next-token CE (+ MoE aux). For frontend models the loss covers the token
    span only (frontend positions are context).

    The CE is computed per SEQUENCE CHUNK over the final hidden states so the
    (B, S, V) logits are never materialised — at nemotron's 256k vocab they
    are ~17 GiB/device even sharded.  Within a chunk, masked-sum CE replaces
    take_along_axis (a gather into the model-sharded vocab dim crashes XLA's
    SPMD partitioner under manual subgroups); the (B, cs, V) intermediates
    are constrained to keep the vocab dim sharded."""
    x, aux, _ = forward(params, batch, cfg, window=window, return_hidden=True)
    if "labels" in batch:
        labels = batch["labels"]
    else:
        labels = batch["tokens"][:, 1:]
        x = x[:, :-1]
    if cfg.frontend != "none" and "tokens" in batch and x.shape[1] != labels.shape[1]:
        x = x[:, -labels.shape[1]:]                      # drop frontend positions
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(cfg.compute_dtype)

    def ce_chunk(args):
        xc, lc = args                                    # (B, cs, D), (B, cs)
        logits = shard((xc @ head).astype(jnp.float32), "batch", None, "model")
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        vocab_iota = jnp.arange(logits.shape[-1], dtype=lc.dtype)
        mask = shard(lc[..., None] == vocab_iota, "batch", None, "model")
        picked = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
        return jnp.sum(logz - picked)

    b, s, d = x.shape
    cs = CE_SEQ_CHUNK
    if s > cs and s % cs == 0:
        nc = s // cs
        xb = jnp.moveaxis(x.reshape(b, nc, cs, d), 1, 0)
        lb = jnp.moveaxis(labels.reshape(b, nc, cs), 1, 0)
        fn = jax.checkpoint(ce_chunk)
        if getattr(cfg, "scan_unroll", False):
            total = sum(fn((xb[i], lb[i])) for i in range(nc))
        else:
            total = jnp.sum(jax.lax.map(fn, (xb, lb)))
    else:
        total = ce_chunk((x, labels))
    return total / labels.size + aux


def decode_step(params, tokens, caches, cfg, *, window: Optional[int] = None):
    """One decode step: tokens (B, 1) int32 -> (logits (B,1,V), new_caches)."""
    # position comes from a cache counter (all layers stay in sync)
    pos = _extract_pos(caches)
    b = tokens.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    logits, _, new_caches = forward(
        params, {"tokens": tokens}, cfg, caches=caches, window=window, positions=positions
    )
    return logits, new_caches


def _extract_pos(caches):
    """All per-layer caches carry a synchronized 'pos' scalar; grab one."""
    def first_cache(t):
        if isinstance(t, (L.AttnCache, M.MambaCache)):
            return t
        if isinstance(t, tuple):
            for e in t:
                c = first_cache(e)
                if c is not None:
                    return c
        return None

    c = first_cache(caches)
    # caches are stacked over blocks -> pos has leading dim n_blocks
    return c.pos[0] if c.pos.ndim else c.pos


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def count_active_params(cfg, params) -> int:
    """Active params per token (MoE: top_k of n_experts)."""
    total = count_params(params)
    if cfg.moe is None:
        return total
    moe_leaves = 0
    blocks = params["blocks"]
    for i, spec in enumerate(cfg.pattern):
        if spec.mlp == "moe":
            lp = blocks[f"layer{i}"]["mlp"]
            moe_leaves += sum(
                x.size for k, x in _flat_items(lp) if k != "router"
            )
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - moe_leaves * (1 - frac))


def _flat_items(d, prefix=""):
    for k, v in d.items():
        if isinstance(v, dict):
            yield from _flat_items(v, prefix + k + "/")
        else:
            yield k, v


def model_flops_per_token(cfg, params) -> float:
    """MODEL_FLOPS = 6 * N_active per token (dense) — roofline §."""
    return 6.0 * count_active_params(cfg, params)
