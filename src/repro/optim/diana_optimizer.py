"""DianaOptimizer — the paper's full iterate as a composable update rule.

Per step (Algorithm 1; with ``vr`` the VR-DIANA iterate of arXiv:1904.05115;
with ``down_method`` the broadcast is downlink-compressed too — DESIGN.md
§Bidirectional):
    1. per-worker grads g_i            (caller, inside shard_map)
    2. ghat, h (+ VR snapshot, + downlink h_down) updates
                                       (core.diana.aggregate_shardmap)
    3. v = inner optimizer on ghat     (momentum beta -> paper's v^k)
    4. x = prox_{gamma R}(x + update)  (core.prox)

This module owns steps 3-4 plus the state plumbing; step 2 lives in core so it
can also be unit-tested single-process.  The same ``apply_direction`` is used
by the reference/benchmark path, guaranteeing the distributed and reference
optimizers are the same code.
"""

from __future__ import annotations

from dataclasses import replace as _dc_replace
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import CompressionConfig
from repro.core.diana import DianaState, init_state
from repro.core.prox import Regularizer, none as no_reg
from .optimizers import Optimizer, constant_schedule

__all__ = ["DianaOptimizer", "DianaOptState"]


class DianaOptState(NamedTuple):
    step: jax.Array
    inner: Any
    diana: DianaState


class DianaOptimizer:
    """Bundles compression config + inner optimizer + schedule + regularizer.

    ``vr=True`` switches the iterate to VR-DIANA: ``init`` grows the
    per-worker L-SVRG (snapshot, mu) slot inside :class:`DianaState` and the
    training step must feed the snapshot gradients through
    ``aggregate_shardmap``'s ``vr_aux`` (launch/train.py does).  ``vr_p``
    overrides the snapshot probability (None keeps the config's value or the
    ``1/m`` default the caller resolves).

    ``down_method`` switches the iterate to BIDIRECTIONAL DIANA: ``init``
    grows the downlink memory ``h_down`` inside :class:`DianaState` and the
    training step must feed ``aggregate_shardmap`` a worker-independent
    ``down_key`` (launch/train.py does).  ``down_k`` overrides the sparse
    downlink budget (None inherits the config's ``k``).
    """

    def __init__(
        self,
        compression: CompressionConfig,
        inner: Optimizer,
        schedule: Callable = None,
        regularizer: Regularizer = None,
        lr: float = 1e-3,
        vr: Optional[bool] = None,
        vr_p: Optional[float] = None,
        down_method: Optional[str] = None,
        down_k: Optional[int] = None,
    ):
        if vr is not None or vr_p is not None:
            compression = _dc_replace(
                compression,
                vr=compression.vr if vr is None else vr,
                vr_p=compression.vr_p if vr_p is None else vr_p,
            )
        if down_method is not None or down_k is not None:
            compression = _dc_replace(
                compression,
                down_method=compression.down_method if down_method is None else down_method,
                down_k=compression.down_k if down_k is None else down_k,
            )
        self.compression = compression
        self.inner = inner
        self.schedule = schedule or constant_schedule(lr)
        self.regularizer = regularizer or no_reg()

    @property
    def compressor(self):
        """The registry-resolved compression operator this optimizer runs."""
        return self.compression.make()

    @property
    def variance_reduced(self) -> bool:
        """Whether this optimizer runs the VR-DIANA iterate."""
        return self.compression.vr

    @property
    def bidirectional(self) -> bool:
        """Whether the server broadcast is compressed (downlink configured)."""
        return self.compression.bidirectional

    def init(self, params, n_workers: int) -> DianaOptState:
        return DianaOptState(
            step=jnp.zeros((), jnp.int32),
            inner=self.inner.init(params),
            diana=init_state(params, self.compression, n_workers),
        )

    def refresh_snapshot(self, state: DianaOptState, params, mu) -> DianaOptState:
        """Deterministically refresh EVERY worker's L-SVRG snapshot to
        ``params`` with control variate ``mu`` (leaves ``(n_workers, *shape)``
        — each worker's full local gradient at ``params``).

        The probabilistic per-step refresh lives inside the aggregation
        round; this is the epoch-mode escape hatch (classic SVRG outer loop,
        or warm-starting ``mu`` right after ``init`` so the first steps run
        with exact semantics instead of waiting for a coin).
        """
        from repro.core.vr import refresh

        assert state.diana.vr is not None, "refresh_snapshot needs vr=True"
        n = jax.tree_util.tree_leaves(state.diana.vr.mu)[0].shape[0]
        new_vr = refresh(state.diana.vr, jnp.ones((n,), bool), params, mu)
        return state._replace(diana=state.diana._replace(vr=new_vr))

    def apply_direction(self, params, ghat, state: DianaOptState, new_diana: DianaState):
        """Steps 3-4: inner update on the aggregated estimator + prox."""
        lr = self.schedule(state.step)
        updates, inner_state = self.inner.update(ghat, state.inner, params, lr)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        new_params = self.regularizer.tree_prox(new_params, lr)
        return new_params, DianaOptState(
            step=state.step + 1, inner=inner_state, diana=new_diana
        )
