"""DianaOptimizer — the paper's full iterate as a composable update rule.

Per step (Algorithm 1):
    1. per-worker grads g_i            (caller, inside shard_map)
    2. ghat, h updates                 (core.diana.aggregate_shardmap)
    3. v = inner optimizer on ghat     (momentum beta -> paper's v^k)
    4. x = prox_{gamma R}(x + update)  (core.prox)

This module owns steps 3-4 plus the state plumbing; step 2 lives in core so it
can also be unit-tested single-process.  The same ``apply_direction`` is used
by the reference/benchmark path, guaranteeing the distributed and reference
optimizers are the same code.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import CompressionConfig
from repro.core.diana import DianaState, init_state
from repro.core.prox import Regularizer, none as no_reg
from .optimizers import Optimizer, constant_schedule

__all__ = ["DianaOptimizer", "DianaOptState"]


class DianaOptState(NamedTuple):
    step: jax.Array
    inner: Any
    diana: DianaState


class DianaOptimizer:
    """Bundles compression config + inner optimizer + schedule + regularizer."""

    def __init__(
        self,
        compression: CompressionConfig,
        inner: Optimizer,
        schedule: Callable = None,
        regularizer: Regularizer = None,
        lr: float = 1e-3,
    ):
        self.compression = compression
        self.inner = inner
        self.schedule = schedule or constant_schedule(lr)
        self.regularizer = regularizer or no_reg()

    @property
    def compressor(self):
        """The registry-resolved compression operator this optimizer runs."""
        return self.compression.make()

    def init(self, params, n_workers: int) -> DianaOptState:
        return DianaOptState(
            step=jnp.zeros((), jnp.int32),
            inner=self.inner.init(params),
            diana=init_state(params, self.compression, n_workers),
        )

    def apply_direction(self, params, ghat, state: DianaOptState, new_diana: DianaState):
        """Steps 3-4: inner update on the aggregated estimator + prox."""
        lr = self.schedule(state.step)
        updates, inner_state = self.inner.update(ghat, state.inner, params, lr)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        new_params = self.regularizer.tree_prox(new_params, lr)
        return new_params, DianaOptState(
            step=state.step + 1, inner=inner_state, diana=new_diana
        )
