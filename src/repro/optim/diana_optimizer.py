"""DianaOptimizer — the paper's full iterate as a composable update rule.

Per step (Algorithm 1; with ``vr`` the VR-DIANA iterate of arXiv:1904.05115;
with a downlink channel the broadcast is downlink-compressed too — DESIGN.md
§Bidirectional):
    1. per-worker grads g_i            (caller, inside shard_map)
    2. ghat, h (+ VR snapshot, + downlink h_down) updates
                                       (core.diana.aggregate_shardmap)
    3. v = inner optimizer on ghat     (momentum beta -> paper's v^k)
    4. x = prox_{gamma R}(x + update)  (core.prox)

This module owns steps 3-4 plus the state plumbing; step 2 lives in core so it
can also be unit-tested single-process.  The same ``apply_direction`` is used
by the reference/benchmark path, guaranteeing the distributed and reference
optimizers are the same code.

Compression is configured by ONE object: a
:class:`~repro.core.policy.CompressionPolicy` (``policy=``), or — the legacy
shim — a flat :class:`~repro.core.compression.CompressionConfig` that lifts to
a one-rule uniform policy (bitwise the pre-policy path, DESIGN.md §Policy).
The old ``vr``/``vr_p``/``down_method``/``down_k`` override kwargs survive as
a deprecation shim over ``policy.replace(...)`` / ``policy.with_down(...)``.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compression import CompressionConfig
from repro.core.diana import DianaState, init_state
from repro.core.policy import CompressionPolicy, as_policy
from repro.core.prox import Regularizer, none as no_reg
from .optimizers import Optimizer, constant_schedule

__all__ = ["DianaOptimizer", "DianaOptState"]


class DianaOptState(NamedTuple):
    step: jax.Array
    inner: Any
    diana: DianaState


class DianaOptimizer:
    """Bundles a compression policy + inner optimizer + schedule + regularizer.

    ``compression`` accepts the legacy flat :class:`CompressionConfig` (lifted
    to a uniform one-rule policy — the exact pre-policy behaviour) or a
    :class:`CompressionPolicy` directly; ``policy=`` is the explicit keyword
    for the latter.  Passing both is an error.

    The legacy override kwargs are a DEPRECATION SHIM over the policy API
    (each emits a ``DeprecationWarning``; ``tests/test_policy.py`` asserts the
    shim and the explicit policy build identical objects):

    * ``vr=`` / ``vr_p=``  ->  ``policy.replace(vr=..., vr_p=...)`` — switches
      the iterate to VR-DIANA; ``init`` grows the per-worker L-SVRG
      (snapshot, mu) slot inside :class:`DianaState` and the training step
      must feed the snapshot gradients through ``aggregate_shardmap``'s
      ``vr_aux`` (launch/train.py does).
    * ``down_method=`` / ``down_k=``  ->  ``policy.with_down(...)`` — attaches
      a downlink channel to every rule; ``init`` grows the downlink memory
      ``h_down`` and the training step must feed a worker-independent
      ``down_key`` (launch/train.py does).

    ``participation=`` (not deprecated) attaches an elastic
    :class:`~repro.core.participation.ParticipationSpec` via
    ``policy.replace(participation=...)``; the training step must then feed
    ``part_key``/``step``/``worker_index`` through ``aggregate_shardmap``
    (launch/train.py does — DESIGN.md §Elasticity).
    """

    def __init__(
        self,
        compression=None,
        inner: Optimizer = None,
        schedule: Callable = None,
        regularizer: Regularizer = None,
        lr: float = 1e-3,
        policy: Optional[CompressionPolicy] = None,
        vr: Optional[bool] = None,
        vr_p: Optional[float] = None,
        down_method: Optional[str] = None,
        down_k: Optional[int] = None,
        participation=None,
    ):
        if policy is not None and compression is not None:
            raise ValueError("pass either compression= (flat config) or "
                             "policy= (CompressionPolicy), not both")
        if policy is None:
            policy = as_policy(compression if compression is not None
                               else CompressionConfig())
        elif not isinstance(policy, CompressionPolicy):
            policy = as_policy(policy)
        if vr is not None or vr_p is not None:
            warnings.warn(
                "DianaOptimizer(vr=, vr_p=) is a deprecation shim — prefer "
                "policy.replace(vr=..., vr_p=...)", DeprecationWarning,
                stacklevel=2)
            policy = policy.replace(
                vr=policy.vr if vr is None else vr,
                vr_p=policy.vr_p if vr_p is None else vr_p,
            )
        if down_method is not None or down_k is not None:
            warnings.warn(
                "DianaOptimizer(down_method=, down_k=) is a deprecation shim "
                "— prefer policy.with_down(method=..., k=...)",
                DeprecationWarning, stacklevel=2)
            policy = policy.with_down(method=down_method, k=down_k)
        if participation is not None:
            # Not a shim — participation is model-wide like vr, and this is
            # its canonical attachment point: the elastic spec rides the
            # policy so every consumer (aggregation, checkpoint metadata,
            # the CLI) sees one source of truth.
            policy = policy.replace(participation=participation)
        self.policy = policy
        self.inner = inner
        self.schedule = schedule or constant_schedule(lr)
        self.regularizer = regularizer or no_reg()

    def replace(self, *, policy: CompressionPolicy) -> "DianaOptimizer":
        """Same inner/schedule/regularizer, different policy (used by
        ``launch.train.resolve_bucketed`` for the layout downgrade)."""
        return DianaOptimizer(inner=self.inner, schedule=self.schedule,
                              regularizer=self.regularizer, policy=policy)

    @property
    def compression(self) -> CompressionConfig:
        """The legacy flat-config view: EXACT for uniform policies (the
        round-trip law), the catch-all rule's representative view — with the
        model-wide fields (``worker_axes``/``vr``/``h_dtype``) authoritative —
        for grouped ones."""
        return self.policy.representative_config()

    @property
    def compressor(self):
        """The registry-resolved operator of the flat/catch-all rule."""
        return self.compression.make()

    @property
    def variance_reduced(self) -> bool:
        """Whether this optimizer runs the VR-DIANA iterate."""
        return self.policy.vr

    @property
    def bidirectional(self) -> bool:
        """Whether any group's server broadcast is compressed."""
        return any(r.down is not None for r in self.policy.rules)

    def init(self, params, n_workers: int) -> DianaOptState:
        return DianaOptState(
            step=jnp.zeros((), jnp.int32),
            inner=self.inner.init(params),
            diana=init_state(params, self.policy, n_workers),
        )

    def refresh_snapshot(self, state: DianaOptState, params, mu) -> DianaOptState:
        """Deterministically refresh EVERY worker's L-SVRG snapshot to
        ``params`` with control variate ``mu`` (leaves ``(n_workers, *shape)``
        — each worker's full local gradient at ``params``).

        The probabilistic per-step refresh lives inside the aggregation
        round; this is the epoch-mode escape hatch (classic SVRG outer loop,
        or warm-starting ``mu`` right after ``init`` so the first steps run
        with exact semantics instead of waiting for a coin).
        """
        from repro.core.vr import refresh

        assert state.diana.vr is not None, "refresh_snapshot needs vr=True"
        n = jax.tree_util.tree_leaves(state.diana.vr.mu)[0].shape[0]
        new_vr = refresh(state.diana.vr, jnp.ones((n,), bool), params, mu)
        return state._replace(diana=state.diana._replace(vr=new_vr))

    def apply_direction(self, params, ghat, state: DianaOptState, new_diana: DianaState):
        """Steps 3-4: inner update on the aggregated estimator + prox."""
        lr = self.schedule(state.step)
        updates, inner_state = self.inner.update(ghat, state.inner, params, lr)
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        new_params = self.regularizer.tree_prox(new_params, lr)
        return new_params, DianaOptState(
            step=state.step + 1, inner=inner_state, diana=new_diana
        )
