"""Inner optimizers + learning-rate schedules (no external deps).

An optimizer is a pair of pure functions, optax-style:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)

``updates`` are *descent directions already scaled by lr* — the caller applies
``x <- prox_{lr R}(x + updates)``.  Keeping lr a call-time argument (not baked
into the state) lets DIANA's decreasing-stepsize schedule (Thm 3) and the
prox coupling ``gamma = lr`` stay exact.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw",
    "constant_schedule", "diana_decreasing_schedule", "warmup_cosine_schedule",
]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple]


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return _tmap(lambda g: -lr * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    """Heavy-ball momentum — Algorithm 1's ``v^k = beta v^{k-1} + ghat^k``."""

    def init(params):
        return _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, v, params, lr):
        v = _tmap(lambda v0, g: beta * v0 + g.astype(jnp.float32), v, grads)
        return _tmap(lambda vv: -lr * vv, v), v

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(mu=_tmap(z, params), nu=_tmap(z, params), count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = _tmap(lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m, n, p):
            step = (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        return _tmap(upd, mu, nu, params), AdamState(mu=mu, nu=nu, count=c)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Schedules — callables step -> lr
# ---------------------------------------------------------------------------

def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def diana_decreasing_schedule(mu: float, theta: float):
    """Theorem 3/5: gamma^k = 2 / (mu*k + theta) — O(1/k) to the exact optimum."""
    return lambda step: 2.0 / (mu * step.astype(jnp.float32) + theta)


def warmup_cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)

    return f
