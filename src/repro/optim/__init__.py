"""Optimizers: inner rules, schedules, and the DIANA wrapper."""

from .optimizers import (
    Optimizer,
    sgd,
    momentum,
    adamw,
    constant_schedule,
    diana_decreasing_schedule,
    warmup_cosine_schedule,
)
from .diana_optimizer import DianaOptimizer, DianaOptState

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw",
    "constant_schedule", "diana_decreasing_schedule", "warmup_cosine_schedule",
    "DianaOptimizer", "DianaOptState",
]
