"""Optimizers: inner rules, schedules, and the DIANA / VR-DIANA wrapper."""

from .optimizers import (
    Optimizer,
    sgd,
    momentum,
    adamw,
    constant_schedule,
    diana_decreasing_schedule,
    warmup_cosine_schedule,
)
from .diana_optimizer import DianaOptimizer, DianaOptState
# VR-DIANA state/knob helpers, re-exported for optimizer users (the `vr=`
# knob on DianaOptimizer grows this slot; resolve_vr_p owns the 1/m default).
from repro.core.vr import VRState, resolve_vr_p

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw",
    "constant_schedule", "diana_decreasing_schedule", "warmup_cosine_schedule",
    "DianaOptimizer", "DianaOptState", "VRState", "resolve_vr_p",
]
