"""Dense (identity-operator) payload kernels.

The identity "compressor" ships raw f32 values, so there is nothing to
decode — but the server still folds ``n`` worker payloads into one mean, and
on the bucketed path that reduction is the whole server tail.  The kernels
here accumulate the worker sum in place over the sequential TPU grid (one
``(d,)`` stripe of VMEM instead of an ``(n, d)`` HBM temporary) and the
``_mean`` variant fuses the divide, mirroring the accumulate-then-epilogue
pattern of :mod:`repro.kernels.unpack_reduce`.

``dense_copy`` is the compress-side counterpart (a straight VMEM pass-through)
so the identity operator exercises the same kernel-capability plumbing as the
real compressors — the linter (``tools/check_kernels.py``) can then assert
the full registry matrix without special-casing identity.

Shapes are exact and validated bitwise against
:func:`repro.kernels.ref.ref_dense_decode_sum` under ``interpret=True``;
like the sparse kernels these are interpret-contract only and ``use_kernel``
auto resolves to off for identity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dense_copy", "dense_decode_sum", "dense_decode_sum_mean"]


def _copy_kernel(x_ref, out_ref):
    out_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_copy(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x (d,) f32 -> (d,) f32 (wire payload pass-through)."""
    d = x.shape[0]
    return pl.pallas_call(
        _copy_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32))


def _accumulate(i, dense, out_ref):
    # Init with the first worker's row (the fallback recurrence starts from
    # ``values[0]``, and zeros + (-0.0) would flip signed zeros).
    @pl.when(i == 0)
    def _init():
        out_ref[...] = dense

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += dense


def _sum_kernel(val_ref, out_ref):
    _accumulate(pl.program_id(0), val_ref[0], out_ref)


def _mean_kernel(val_ref, out_ref, *, n):
    _sum_kernel(val_ref, out_ref)

    @pl.when(pl.program_id(0) == n - 1)
    def _mean():
        out_ref[...] = out_ref[...] / jnp.float32(n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_decode_sum(values: jax.Array, *, interpret: bool = True) -> jax.Array:
    """values (n, d) f32 -> (d,) f32 accumulated worker sum."""
    n, d = values.shape
    return pl.pallas_call(
        _sum_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(values.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_decode_sum_mean(
    values: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """Fused sum + divide: values (n, d) f32 -> (d,) mean over workers."""
    n, d = values.shape
    return pl.pallas_call(
        functools.partial(_mean_kernel, n=n),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(values.astype(jnp.float32))
