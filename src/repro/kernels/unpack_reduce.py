"""Server-side decode: unpack 2-bit ternary payloads and accumulate the sum
over workers — the Pallas realisation of DIANA's ``mean_i dhat_i``.

Grid layout ``(n_workers, m_tiles)``: the TPU grid is sequential, so the
kernel revisits each output tile once per worker and accumulates in place
(``out += unpack(packed_i) * scale_i``), initialising on the first visit with
``pl.when``.  Peak VMEM per step is one packed tile (``TILE_M * B/4`` bytes),
one scales column and the f32 accumulator tile — the dense per-worker payload
is never materialised in HBM, which is the whole point: HBM traffic is
``n * d/4`` bytes in, ``4d`` bytes out, instead of the ``n * 4d`` a naive
unpack-then-sum would move.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantization import pad_axis_to_multiple

__all__ = ["unpack_reduce", "DEFAULT_TILE_M"]

DEFAULT_TILE_M = 8


def _kernel(packed_ref, scales_ref, out_ref):
    i = pl.program_id(0)  # worker index

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    packed = packed_ref[0]                                    # (TILE_M, B/4)
    # Unpack with unrolled shifts (no captured constant arrays in Pallas).
    parts = [
        ((packed >> jnp.uint8(s)) & jnp.uint8(3)).astype(jnp.int8) - 1
        for s in (0, 2, 4, 6)
    ]
    g = jnp.stack(parts, axis=-1)                             # (TILE_M, B/4, 4)
    tm = packed.shape[0]
    dense = g.reshape(tm, -1).astype(jnp.float32)             # (TILE_M, B)
    out_ref[...] += dense * scales_ref[0].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def unpack_reduce(
    packed: jax.Array,
    scales: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """packed (n, m, B/4) u8, scales (n, m, 1) f32 -> (m, B) f32 sum over n."""
    n, m, b4 = packed.shape
    packed = pad_axis_to_multiple(packed, tile_m, axis=1)
    scales = pad_axis_to_multiple(scales, tile_m, axis=1)
    mp = packed.shape[1]

    grid = (n, mp // tile_m)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_m, b4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile_m, 1), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, b4 * 4), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, b4 * 4), jnp.float32),
        interpret=interpret,
    )(packed, scales)
    return out[:m]
