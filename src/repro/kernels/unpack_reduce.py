"""Server-side decode: unpack 2-bit ternary payloads and accumulate the sum
over workers — the Pallas realisation of DIANA's ``mean_i dhat_i``.

Grid layout ``(n_workers, m_tiles)``: the TPU grid is sequential, so the
kernel revisits each output tile once per worker and accumulates in place
(``out += unpack(packed_i) * scale_i``), initialising on the first visit with
``pl.when``.  Peak VMEM per step is one packed tile (``TILE_M * B/4`` bytes),
one scales column and the f32 accumulator tile — the dense per-worker payload
is never materialised in HBM, which is the whole point: HBM traffic is
``n * d/4`` bytes in, ``4d`` bytes out, instead of the ``n * 4d`` a naive
unpack-then-sum would move.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantization import pad_axis_to_multiple

__all__ = [
    "unpack_reduce",
    "unpack_reduce_mean",
    "unpack_reduce_apply",
    "DEFAULT_TILE_M",
]

DEFAULT_TILE_M = 8


def _unpack_dense(packed):
    """(TILE_M, B/4) u8 -> (TILE_M, B) f32 in {-1, 0, +1}.

    Unpack with unrolled shifts (no captured constant arrays in Pallas).
    """
    parts = [
        ((packed >> jnp.uint8(s)) & jnp.uint8(3)).astype(jnp.int8) - 1
        for s in (0, 2, 4, 6)
    ]
    g = jnp.stack(parts, axis=-1)                             # (TILE_M, B/4, 4)
    return g.reshape(packed.shape[0], -1).astype(jnp.float32)


def _kernel(packed_ref, scales_ref, out_ref):
    i = pl.program_id(0)  # worker index

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dense = _unpack_dense(packed_ref[0])                      # (TILE_M, B)
    out_ref[...] += dense * scales_ref[0].astype(jnp.float32)


def _kernel_mean(packed_ref, scales_ref, out_ref, *, n):
    _kernel(packed_ref, scales_ref, out_ref)

    @pl.when(pl.program_id(0) == n - 1)
    def _mean():
        out_ref[...] = out_ref[...] / jnp.float32(n)


def _kernel_apply(packed_ref, scales_ref, h_ref, ghat_ref, newh_ref, *, n, alpha):
    # Accumulate the worker sum in ghat_ref, then on the LAST worker visit run
    # the server epilogue in-register: dm = s/n, ghat = h + dm, h' = h + a*dm.
    # The aggregated sum never round-trips HBM between decode and apply.
    _kernel(packed_ref, scales_ref, ghat_ref)

    @pl.when(pl.program_id(0) == n - 1)
    def _apply():
        dm = ghat_ref[...] / jnp.float32(n)
        h = h_ref[...]
        ghat_ref[...] = h + dm
        newh_ref[...] = h + jnp.float32(alpha) * dm


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def unpack_reduce(
    packed: jax.Array,
    scales: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """packed (n, m, B/4) u8, scales (n, m, 1) f32 -> (m, B) f32 sum over n."""
    n, m, b4 = packed.shape
    packed = pad_axis_to_multiple(packed, tile_m, axis=1)
    scales = pad_axis_to_multiple(scales, tile_m, axis=1)
    mp = packed.shape[1]

    grid = (n, mp // tile_m)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_m, b4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile_m, 1), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, b4 * 4), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, b4 * 4), jnp.float32),
        interpret=interpret,
    )(packed, scales)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def unpack_reduce_mean(
    packed: jax.Array,
    scales: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """Fused decode_sum + divide: (n, m, B/4) u8 -> (m, B) f32 mean over n."""
    n, m, b4 = packed.shape
    packed = pad_axis_to_multiple(packed, tile_m, axis=1)
    scales = pad_axis_to_multiple(scales, tile_m, axis=1)
    mp = packed.shape[1]

    out = pl.pallas_call(
        functools.partial(_kernel_mean, n=n),
        grid=(n, mp // tile_m),
        in_specs=[
            pl.BlockSpec((1, tile_m, b4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile_m, 1), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, b4 * 4), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, b4 * 4), jnp.float32),
        interpret=interpret,
    )(packed, scales)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("alpha", "tile_m", "interpret"))
def unpack_reduce_apply(
    packed: jax.Array,
    scales: jax.Array,
    h: jax.Array,
    *,
    alpha: float,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused decode_sum + DIANA server update for the ternary family.

    packed (n, m, B/4) u8, scales (n, m, 1) f32, h (d,) f32 with
    d <= m * B.  Returns flat ``(ghat, new_h) = (h + dm, h + alpha * dm)``
    where ``dm = sum_i unpack(packed_i) * scales_i / n``, both (d,).
    """
    n, m, b4 = packed.shape
    b = b4 * 4
    d = h.shape[0]
    h2 = pad_axis_to_multiple(h.astype(jnp.float32), b).reshape(-1, b)
    if h2.shape[0] != m:
        raise ValueError(f"h rows {h2.shape[0]} != packed rows {m}")
    packed = pad_axis_to_multiple(packed, tile_m, axis=1)
    scales = pad_axis_to_multiple(scales, tile_m, axis=1)
    h2 = pad_axis_to_multiple(h2, tile_m, axis=0)
    mp = packed.shape[1]

    ghat, newh = pl.pallas_call(
        functools.partial(_kernel_apply, n=n, alpha=float(alpha)),
        grid=(n, mp // tile_m),
        in_specs=[
            pl.BlockSpec((1, tile_m, b4), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, tile_m, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tile_m, b), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, b), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_m, b), lambda i, j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, b), jnp.float32),
            jax.ShapeDtypeStruct((mp, b), jnp.float32),
        ],
        interpret=interpret,
    )(packed, scales, h2)
    return ghat.reshape(-1)[:d], newh.reshape(-1)[:d]
