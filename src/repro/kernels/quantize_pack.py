"""Fused block p-quantization + 2-bit pack as a Pallas TPU kernel.

One HBM->VMEM pass per tile of quantization blocks: the kernel computes the
per-block ``||.||_p`` scale (a VPU row reduction), draws the Bernoulli mask by
comparing uniform bits against ``|delta| / scale``, forms ternary signs, and
packs four 2-bit codes per byte — so the value leaving VMEM is already the
wire format for the compressed all-gather.  This is the TPU adaptation of the
paper's CPU-side quantize + Elias-encode step (DESIGN.md §2).

Tiling: the grid walks ``m`` (number of blocks) in tiles of ``TILE_M`` rows of
``B = block_size`` lanes.  ``B`` is a multiple of 128 in every production
config, so rows map cleanly onto VPU lanes; the packed output has ``B/4``
bytes per row (int8 lanes).  VMEM footprint per grid step is
``TILE_M * B * (4 + 4 + 1 + 0.25)`` bytes — with the default TILE_M=8 and
B=2048 that is ~150 KiB, far under the ~16 MiB VMEM budget, leaving headroom
for double buffering.

Randomness — two variants sharing one quantization body:

* :func:`quantize_pack` takes pre-drawn uint32 bits, so the identical body
  runs under ``interpret=True`` on CPU — the CI oracle, validated bitwise
  against :func:`repro.kernels.ref.ref_quantize_pack`.
* :func:`quantize_pack_prng` (compiled TPU only) draws the bits INSIDE the
  kernel with ``pltpu.prng_seed`` + ``pltpu.prng_random_bits``, seeded per
  tile from two key words + the grid index.  This removes the uint32 bits
  operand entirely — 4 bytes/dim of pure HBM input traffic, as large as the
  gradient itself — cutting the encode's HBM reads roughly in half.  Values
  agree with the bits variant in distribution, not bitwise (independent
  stream), which is already the stated contract for the kernel encode.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import pad_axis_to_multiple

__all__ = ["quantize_pack", "quantize_pack_prng", "DEFAULT_TILE_M"]

DEFAULT_TILE_M = 8


def _quantize_body(delta, bits, packed_ref, scales_ref, *, p: float):
    """Shared quantize+pack body: delta (TILE_M, B) f32, bits uint32."""
    delta = delta.astype(jnp.float32)
    if p == math.inf:
        scale = jnp.max(jnp.abs(delta), axis=-1, keepdims=True)
    elif p == 2:
        scale = jnp.sqrt(jnp.sum(delta * delta, axis=-1, keepdims=True))
    elif p == 1:
        scale = jnp.sum(jnp.abs(delta), axis=-1, keepdims=True)
    else:
        scale = jnp.sum(jnp.abs(delta) ** p, axis=-1, keepdims=True) ** (1.0 / p)

    safe = jnp.where(scale > 0, scale, 1.0)
    probs = jnp.abs(delta) / safe
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    )
    xi = (u < probs).astype(jnp.int8)
    signs = jnp.sign(delta).astype(jnp.int8) * xi       # {-1, 0, 1}

    # 2-bit pack: code = sign + 1 in {0,1,2}; 4 codes / byte, little-endian.
    # (shifts unrolled — Pallas kernels may not capture constant arrays)
    codes = (signs + 1).astype(jnp.uint8)
    tm, b = codes.shape
    g = codes.reshape(tm, b // 4, 4)
    packed = (
        g[..., 0]
        | (g[..., 1] << jnp.uint8(2))
        | (g[..., 2] << jnp.uint8(4))
        | (g[..., 3] << jnp.uint8(6))
    )
    packed_ref[...] = packed.astype(jnp.uint8)
    scales_ref[...] = scale.astype(jnp.float32)


def _kernel(delta_ref, bits_ref, packed_ref, scales_ref, *, p: float):
    _quantize_body(delta_ref[...], bits_ref[...], packed_ref, scales_ref, p=p)


def _kernel_prng(seed_ref, delta_ref, packed_ref, scales_ref, *, p: float):
    # Per-tile stream: two key words + the grid index, so every tile of
    # blocks draws independent bits regardless of launch shape.
    pltpu.prng_seed(seed_ref[0], seed_ref[1], pl.program_id(0))
    bits = pltpu.bitcast(
        pltpu.prng_random_bits(delta_ref.shape), jnp.uint32
    )
    _quantize_body(delta_ref[...], bits, packed_ref, scales_ref, p=p)


def _check_block(b: int):
    if b % 128:
        raise ValueError(f"block size {b} must be a multiple of 128 (VPU lanes)")


@functools.partial(
    jax.jit, static_argnames=("p", "tile_m", "interpret")
)
def quantize_pack(
    delta: jax.Array,
    bits: jax.Array,
    *,
    p: float = math.inf,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
):
    """delta (m, B) f32, bits (m, B) uint32 -> (packed (m, B/4) u8, scales (m,1) f32).

    ``m`` is padded to a multiple of ``tile_m`` internally (zero blocks quantize
    to zero, so padding is harmless and stripped on return).
    """
    m, b = delta.shape
    _check_block(b)
    delta = pad_axis_to_multiple(delta, tile_m)
    bits = pad_axis_to_multiple(bits, tile_m)
    mp = delta.shape[0]

    grid = (mp // tile_m,)
    packed, scales = pl.pallas_call(
        functools.partial(_kernel, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, b), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, b // 4), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, b // 4), jnp.uint8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(delta, bits)
    return packed[:m], scales[:m]


@functools.partial(jax.jit, static_argnames=("p", "tile_m"))
def quantize_pack_prng(
    delta: jax.Array,
    seed: jax.Array,
    *,
    p: float = math.inf,
    tile_m: int = DEFAULT_TILE_M,
):
    """In-kernel-PRNG variant: delta (m, B) f32, seed (2,) int32 words.

    Compiled Mosaic only — the ``pltpu`` PRNG primitives have no interpret
    lowering, so CI keeps validating the shared quantization body through the
    pre-drawn-bits oracle (:func:`quantize_pack`) and this wrapper is reached
    exclusively on real TPU backends (see ``repro.kernels.ops``).
    """
    m, b = delta.shape
    _check_block(b)
    delta = pad_axis_to_multiple(delta, tile_m)
    mp = delta.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, b), lambda i, seed_ref: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, b // 4), lambda i, seed_ref: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i, seed_ref: (i, 0)),
        ],
    )
    packed, scales = pl.pallas_call(
        functools.partial(_kernel_prng, p=p),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((mp, b // 4), jnp.uint8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
    )(seed.astype(jnp.int32), delta)
    return packed[:m], scales[:m]
