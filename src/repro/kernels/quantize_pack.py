"""Fused block p-quantization + 2-bit pack as a Pallas TPU kernel.

One HBM->VMEM pass per tile of quantization blocks: the kernel computes the
per-block ``||.||_p`` scale (a VPU row reduction), draws the Bernoulli mask by
comparing uniform bits against ``|delta| / scale``, forms ternary signs, and
packs four 2-bit codes per byte — so the value leaving VMEM is already the
wire format for the compressed all-gather.  This is the TPU adaptation of the
paper's CPU-side quantize + Elias-encode step (DESIGN.md §2).

Tiling: the grid walks ``m`` (number of blocks) in tiles of ``TILE_M`` rows of
``B = block_size`` lanes.  ``B`` is a multiple of 128 in every production
config, so rows map cleanly onto VPU lanes; the packed output has ``B/4``
bytes per row (int8 lanes).  VMEM footprint per grid step is
``TILE_M * B * (4 + 4 + 1 + 0.25)`` bytes — with the default TILE_M=8 and
B=2048 that is ~150 KiB, far under the ~16 MiB VMEM budget, leaving headroom
for double buffering.

Randomness: the kernel takes pre-drawn uint32 bits so the same body runs under
``interpret=True`` on CPU (the CI oracle path).  On a real TPU deployment the
bits input is replaced by ``pltpu.prng_seed + pltpu.prng_random_bits`` inside
the kernel, eliminating the HBM traffic of the bits operand; the surrounding
math is unchanged.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_pack", "DEFAULT_TILE_M"]

DEFAULT_TILE_M = 8


def _kernel(delta_ref, bits_ref, packed_ref, scales_ref, *, p: float):
    delta = delta_ref[...].astype(jnp.float32)          # (TILE_M, B)
    if p == math.inf:
        scale = jnp.max(jnp.abs(delta), axis=-1, keepdims=True)
    elif p == 2:
        scale = jnp.sqrt(jnp.sum(delta * delta, axis=-1, keepdims=True))
    elif p == 1:
        scale = jnp.sum(jnp.abs(delta), axis=-1, keepdims=True)
    else:
        scale = jnp.sum(jnp.abs(delta) ** p, axis=-1, keepdims=True) ** (1.0 / p)

    safe = jnp.where(scale > 0, scale, 1.0)
    probs = jnp.abs(delta) / safe
    u = (bits_ref[...] >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    )
    xi = (u < probs).astype(jnp.int8)
    signs = jnp.sign(delta).astype(jnp.int8) * xi       # {-1, 0, 1}

    # 2-bit pack: code = sign + 1 in {0,1,2}; 4 codes / byte, little-endian.
    # (shifts unrolled — Pallas kernels may not capture constant arrays)
    codes = (signs + 1).astype(jnp.uint8)
    tm, b = codes.shape
    g = codes.reshape(tm, b // 4, 4)
    packed = (
        g[..., 0]
        | (g[..., 1] << jnp.uint8(2))
        | (g[..., 2] << jnp.uint8(4))
        | (g[..., 3] << jnp.uint8(6))
    )
    packed_ref[...] = packed.astype(jnp.uint8)
    scales_ref[...] = scale.astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("p", "tile_m", "interpret")
)
def quantize_pack(
    delta: jax.Array,
    bits: jax.Array,
    *,
    p: float = math.inf,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
):
    """delta (m, B) f32, bits (m, B) uint32 -> (packed (m, B/4) u8, scales (m,1) f32).

    ``m`` is padded to a multiple of ``tile_m`` internally (zero blocks quantize
    to zero, so padding is harmless and stripped on return).
    """
    m, b = delta.shape
    if b % 128:
        raise ValueError(f"block size {b} must be a multiple of 128 (VPU lanes)")
    mp = -(-m // tile_m) * tile_m
    if mp != m:
        # concatenate, not jnp.pad (partial-manual shard_map, see pad_to_blocks)
        delta = jnp.concatenate([delta, jnp.zeros((mp - m, b), delta.dtype)])
        bits = jnp.concatenate([bits, jnp.zeros((mp - m, b), bits.dtype)])

    grid = (mp // tile_m,)
    packed, scales = pl.pallas_call(
        functools.partial(_kernel, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, b), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, b // 4), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, b // 4), jnp.uint8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(delta, bits)
    return packed[:m], scales[:m]
