"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated with ``assert_allclose`` against the
functions here across a sweep of shapes / dtypes / norm powers (see
``tests/test_kernels.py``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.packing import pack2bit, unpack2bit
from repro.core.quantization import lp_norm

__all__ = ["uniform_from_bits", "ref_quantize_pack", "ref_unpack_reduce"]


def uniform_from_bits(bits: jax.Array) -> jax.Array:
    """uint32 -> uniform [0,1) f32 using the top 24 bits (TPU-friendly)."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def ref_quantize_pack(delta: jax.Array, bits: jax.Array, p: float):
    """Fused block p-quantize + 2-bit pack oracle.

    delta: (m, B) f32 — one row per quantization block.
    bits:  (m, B) uint32 random bits.
    Returns (packed (m, B/4) uint8, scales (m, 1) f32).
    """
    scales = lp_norm(delta, p, axis=-1, keepdims=True)            # (m, 1)
    safe = jnp.where(scales > 0, scales, 1.0)
    probs = jnp.abs(delta) / safe
    u = uniform_from_bits(bits)
    xi = (u < probs).astype(jnp.int8)
    signs = jnp.sign(delta).astype(jnp.int8) * xi
    return pack2bit(signs), scales.astype(jnp.float32)


def ref_unpack_reduce(packed: jax.Array, scales: jax.Array) -> jax.Array:
    """Server-side decode: sum_i unpack(packed_i) * scales_i.

    packed: (n, m, B/4) uint8; scales: (n, m, 1) f32 -> (m, B) f32 sum.
    """
    signs = unpack2bit(packed).astype(jnp.float32)                # (n, m, B)
    return jnp.sum(signs * scales, axis=0)
