"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against the functions here across a
sweep of shapes / dtypes / norm powers (see ``tests/test_kernels.py`` and
``tests/test_kernel_coverage.py``) — bitwise under ``interpret=True``, which
is the CI contract (``tools/check_kernels.py`` enforces that every registry
operator names its oracle).

The oracles are deliberately written in the most literal jnp style (frexp for
natural compression, ``.at[].add`` scatters, sequential worker accumulation)
while the kernels use TPU-shaped bodies (exponent bit masks, ``pl.when``
accumulators).  Bitwise agreement between the two is therefore a real check
of the kernels' bit tricks, not a tautology.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import pack2bit, unpack2bit

# The one bits->uniform map, shared with every fallback operator (re-exported
# here for the kernel tests; the definition lives with the quantizers so the
# operators never import the kernel package).
from repro.core.quantization import lp_norm, uniform_from_bits

__all__ = [
    "uniform_from_bits",
    "ref_quantize_pack",
    "ref_unpack_reduce",
    "ref_unpack_reduce_apply",
    "ref_nat_pack",
    "ref_nat_decode_sum",
    "ref_sparse_gather",
    "ref_sparse_decode_sum",
    "ref_dense_decode_sum",
    "ref_apply_server",
]

NAT_BIAS = 160  # == repro.core.compressors.natural._BIAS (int16 code bias)


def ref_quantize_pack(delta: jax.Array, bits: jax.Array, p: float):
    """Fused block p-quantize + 2-bit pack oracle.

    delta: (m, B) f32 — one row per quantization block.
    bits:  (m, B) uint32 random bits.
    Returns (packed (m, B/4) uint8, scales (m, 1) f32).
    """
    scales = lp_norm(delta, p, axis=-1, keepdims=True)            # (m, 1)
    safe = jnp.where(scales > 0, scales, 1.0)
    probs = jnp.abs(delta) / safe
    u = uniform_from_bits(bits)
    xi = (u < probs).astype(jnp.int8)
    signs = jnp.sign(delta).astype(jnp.int8) * xi
    return pack2bit(signs), scales.astype(jnp.float32)


def ref_unpack_reduce(packed: jax.Array, scales: jax.Array) -> jax.Array:
    """Server-side decode: sum_i unpack(packed_i) * scales_i.

    packed: (n, m, B/4) uint8; scales: (n, m, 1) f32 -> (m, B) f32 sum,
    accumulated worker by worker from zeros — the exact recurrence of the
    ternary fallback ``decode_sum`` (a parallel ``jnp.sum`` reduces in a
    different association order and is NOT bitwise-comparable).
    """
    signs = unpack2bit(packed).astype(jnp.float32)                # (n, m, B)
    acc = jnp.zeros(signs.shape[1:], jnp.float32)
    for i in range(signs.shape[0]):
        acc = acc + signs[i] * scales[i]
    return acc


def ref_apply_server(s: jax.Array, n: int, h: jax.Array, alpha) -> tuple:
    """The fused-apply epilogue oracle: ``dm = s / n`` then the alpha-memory
    server rule ``(ghat, new_h) = (h + dm, h + alpha * dm)`` — exactly the
    composition ``Compressor.decode_sum_apply`` runs as its fallback.

    Compare under ``jax.jit``: XLA CPU contracts ``h + alpha * dm`` into an
    FMA inside any jitted graph (kernel epilogues and the jitted fallback
    alike, consistently), while op-by-op eager execution rounds the multiply
    separately — so eager-vs-jit differs by 1 ulp, jit-vs-jit is bitwise."""
    dm = s / jnp.float32(n)
    return h + dm, h + alpha * dm


def ref_unpack_reduce_apply(packed, scales, h, alpha, n: int):
    """Fused decode_sum + server update oracle for the ternary family."""
    s = ref_unpack_reduce(packed, scales).reshape(-1)[: h.shape[0]]
    return ref_apply_server(s, n, h, alpha)


def ref_nat_pack(x: jax.Array, bits: jax.Array) -> jax.Array:
    """Natural-compression encode oracle — the literal frexp formulation.

    x, bits: (d,) f32 / uint32 -> int16 sign*(exponent+NAT_BIAS) codes, 0 for
    exact zeros.  The kernel computes the same codes from the exponent BITS of
    the float representation (no frexp on the VPU); bitwise agreement between
    the two formulations is exact on all finite inputs including subnormals.
    """
    u = uniform_from_bits(bits)
    mant, expo = jnp.frexp(x)                     # |mant| in [0.5, 1)
    p_up = 2.0 * jnp.abs(mant) - 1.0              # exact (Sterbenz)
    chosen = expo - 1 + (u < p_up).astype(expo.dtype)
    sign = jnp.sign(x).astype(jnp.int16)
    code = sign * (chosen.astype(jnp.int16) + jnp.int16(NAT_BIAS))
    return jnp.where(x == 0.0, jnp.int16(0), code)


def _nat_decode(code: jax.Array) -> jax.Array:
    mag = jnp.exp2((jnp.abs(code) - NAT_BIAS).astype(jnp.float32))
    return jnp.where(code == 0, 0.0, jnp.sign(code).astype(jnp.float32) * mag)


def ref_nat_decode_sum(codes: jax.Array) -> jax.Array:
    """codes (n, d) int16 -> (d,) f32 — the sequential worker recurrence."""
    acc = _nat_decode(codes[0])
    for i in range(1, codes.shape[0]):
        acc = acc + _nat_decode(codes[i])
    return acc


def ref_sparse_gather(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Compress-side value gather oracle: x (d,) f32, idx (k,) int -> (k,)."""
    return x[idx]


def ref_sparse_decode_sum(idx: jax.Array, values: jax.Array,
                          scale: jax.Array, d: int) -> jax.Array:
    """Sparse server decode: idx/values (n, k), scale (k,) -> (d,) f32 sum,
    accumulated worker by worker (the fallback scatter-add recurrence)."""

    def one(i):
        return jnp.zeros((d,), jnp.float32).at[idx[i]].add(values[i] * scale)

    acc = one(0)
    for i in range(1, idx.shape[0]):
        acc = acc + one(i)
    return acc


def ref_dense_decode_sum(values: jax.Array) -> jax.Array:
    """Dense (identity) decode: values (n, d) f32 -> (d,) sequential sum."""
    acc = values[0]
    for i in range(1, values.shape[0]):
        acc = acc + values[i]
    return acc
