"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
TPU, where the same kernel bodies compile to Mosaic.  Kernel-backed
compressors (:class:`repro.core.compressors.TernaryCompressor` with
``use_kernel=True``) advertise the capability themselves and route their
encode through :func:`quantize_pack_op` and their server-side decode through
:func:`unpack_reduce_op` — consumers of the compressor interface never switch
on an external flag (DESIGN.md §2).

The kernel encode draws its Bernoulli bits from an independent PRNG stream,
so values agree with the pure-jnp path in distribution, not bitwise; the
kernel *decode* is bitwise-equal to the fallback loop (same f32 accumulate
recurrence) and tested as such in ``tests/test_compressors.py``.
"""

from __future__ import annotations

import jax

from .quantize_pack import quantize_pack
from .unpack_reduce import unpack_reduce

__all__ = ["default_interpret", "quantize_pack_op", "unpack_reduce_op"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_pack_op(delta2d, bits, *, p: float):
    return quantize_pack(delta2d, bits, p=p, interpret=default_interpret())


def unpack_reduce_op(packed, scales):
    return unpack_reduce(packed, scales, interpret=default_interpret())
