"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
TPU, where the same kernel bodies compile to Mosaic.  Kernel-backed
compressors (:class:`repro.core.compressors.TernaryCompressor` with
``use_kernel=True``) advertise the capability themselves and route their
encode through :func:`quantize_pack_op` and their server-side decode through
:func:`unpack_reduce_op` — consumers of the compressor interface never switch
on an external flag (DESIGN.md §2).

The kernel encode draws its Bernoulli bits from an independent PRNG stream,
so values agree with the pure-jnp path in distribution, not bitwise; the
kernel *decode* is bitwise-equal to the fallback loop (same f32 accumulate
recurrence) and tested as such in ``tests/test_compressors.py``.

On compiled TPU backends the encode routes through
:func:`quantize_pack_prng_op`: the Bernoulli bits are drawn INSIDE the kernel
(``pltpu.prng_seed`` + ``prng_random_bits`` seeded from the PRNG key's two
words), so the uint32 bits operand and its 4 bytes/dim of HBM input traffic
disappear.  Under ``interpret=True`` (CPU CI) the pre-drawn-bits body remains
the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantize_pack import quantize_pack, quantize_pack_prng
from .unpack_reduce import unpack_reduce

__all__ = [
    "default_interpret",
    "quantize_pack_op",
    "quantize_pack_prng_op",
    "unpack_reduce_op",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_pack_op(delta2d, bits, *, p: float):
    return quantize_pack(delta2d, bits, p=p, interpret=default_interpret())


def _key_words(key) -> jax.Array:
    """A PRNG key's two 32-bit words as an (2,) int32 seed for the in-kernel
    PRNG (accepts both raw uint32 keys and new-style typed keys)."""
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.key_data(key)
    except (AttributeError, TypeError):
        pass
    words = key.reshape(-1).astype(jnp.uint32)
    if words.shape[0] < 2:
        words = jnp.concatenate([words, jnp.zeros((1,), jnp.uint32)])
    return jax.lax.bitcast_convert_type(words[:2], jnp.int32)


def quantize_pack_prng_op(delta2d, key, *, p: float):
    return quantize_pack_prng(delta2d, _key_words(key), p=p)


def unpack_reduce_op(packed, scales):
    return unpack_reduce(packed, scales, interpret=default_interpret())
