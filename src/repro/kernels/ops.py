"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
TPU, where the same kernel bodies compile to Mosaic.  ``compress_tree_kernel``
is the drop-in used by :func:`repro.core.compression.compress_tree` when
``CompressionConfig.use_kernel`` is set: identical semantics, fused data path.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.packing import unpack2bit
from repro.core.quantization import QuantizedBlocks, pad_to_blocks

from .quantize_pack import quantize_pack
from .unpack_reduce import unpack_reduce

__all__ = ["default_interpret", "quantize_pack_op", "unpack_reduce_op", "compress_tree_kernel"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_pack_op(delta2d, bits, *, p: float):
    return quantize_pack(delta2d, bits, p=p, interpret=default_interpret())


def unpack_reduce_op(packed, scales):
    return unpack_reduce(packed, scales, interpret=default_interpret())


def compress_tree_kernel(tree, key, cfg):
    """Kernel-backed equivalent of ``compression.compress_tree``.

    Matches the reference path's *representation* exactly (same payload pytree
    structure); the Bernoulli draws use an independent PRNG stream, so values
    agree in distribution, not bitwise — tests compare moments and the packed
    format, plus bitwise equality of pack(unpack(x)).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    payloads, qs = [], []
    p = cfg.effective_p()
    for leaf, k in zip(leaves, keys):
        blocks = pad_to_blocks(leaf.astype(jnp.float32), cfg.block_size)
        bits = jax.random.bits(k, blocks.shape, dtype=jnp.uint32)
        packed, scales = quantize_pack_op(blocks, bits, p=p)
        scales1 = scales[:, 0]
        payloads.append({"packed": packed, "scales": scales1})
        qs.append(QuantizedBlocks(signs=unpack2bit(packed), scales=scales1))
    payload = jax.tree_util.tree_unflatten(treedef, payloads)
    qtree = jax.tree_util.tree_unflatten(treedef, qs)
    return payload, qtree
