"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
TPU, where the same kernel bodies compile to Mosaic.  Kernel-backed
compressors (every operator in :mod:`repro.core.compressors` constructed with
``use_kernel=True``) advertise the capability themselves and route their
encode / server-side decode through the ``*_op`` wrappers here — consumers of
the compressor interface never switch on an external flag (DESIGN.md §2).

Since the PRNG unification (every fallback draws ``jax.random.bits`` and maps
them through :func:`repro.core.quantization.uniform_from_bits`, the same
shift/scale the kernel bodies apply), the pre-drawn-bits kernel encodes are
bitwise-EQUAL to the pure-jnp fallbacks given the same key — as are all
decode_sum and fused decode_sum+apply kernels (same f32 accumulate
recurrence).  ``tools/check_kernels.py`` enforces that every registry
operator names its interpret-mode oracle for exactly this contract.

The ONE exception: on compiled TPU backends the stochastic encodes route
through the ``*_prng_op`` variants, which draw their bits INSIDE the kernel
(``pltpu.prng_seed`` + ``prng_random_bits`` seeded from the PRNG key's two
words), so the uint32 bits operand and its 4 bytes/dim of HBM input traffic
disappear.  Those agree with the fallback in distribution, not bitwise; under
``interpret=True`` (CPU CI) the pre-drawn-bits bodies remain the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .dense import dense_copy, dense_decode_sum, dense_decode_sum_mean
from .nat_pack import (
    nat_decode_sum,
    nat_decode_sum_apply,
    nat_decode_sum_mean,
    nat_pack,
    nat_pack_prng,
)
from .quantize_pack import quantize_pack, quantize_pack_prng
from .sparse import sparse_decode_sum, sparse_decode_sum_mean, sparse_gather
from .unpack_reduce import unpack_reduce, unpack_reduce_apply, unpack_reduce_mean

__all__ = [
    "default_interpret",
    "quantize_pack_op",
    "quantize_pack_prng_op",
    "unpack_reduce_op",
    "unpack_reduce_mean_op",
    "unpack_reduce_apply_op",
    "nat_pack_op",
    "nat_pack_prng_op",
    "nat_decode_sum_op",
    "nat_decode_sum_mean_op",
    "nat_decode_sum_apply_op",
    "sparse_gather_op",
    "sparse_decode_sum_op",
    "sparse_decode_sum_mean_op",
    "dense_copy_op",
    "dense_decode_sum_op",
    "dense_decode_sum_mean_op",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _key_words(key) -> jax.Array:
    """A PRNG key's two 32-bit words as an (2,) int32 seed for the in-kernel
    PRNG (accepts both raw uint32 keys and new-style typed keys)."""
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.key_data(key)
    except (AttributeError, TypeError):
        pass
    words = key.reshape(-1).astype(jnp.uint32)
    if words.shape[0] < 2:
        words = jnp.concatenate([words, jnp.zeros((1,), jnp.uint32)])
    return jax.lax.bitcast_convert_type(words[:2], jnp.int32)


# -- ternary (diana / qsgd / terngrad / dqgd) -------------------------------

def quantize_pack_op(delta2d, bits, *, p: float):
    return quantize_pack(delta2d, bits, p=p, interpret=default_interpret())


def quantize_pack_prng_op(delta2d, key, *, p: float):
    return quantize_pack_prng(delta2d, _key_words(key), p=p)


def unpack_reduce_op(packed, scales):
    return unpack_reduce(packed, scales, interpret=default_interpret())


def unpack_reduce_mean_op(packed, scales):
    return unpack_reduce_mean(packed, scales, interpret=default_interpret())


def unpack_reduce_apply_op(packed, scales, h, *, alpha: float):
    return unpack_reduce_apply(
        packed, scales, h, alpha=alpha, interpret=default_interpret()
    )


# -- natural ----------------------------------------------------------------

def nat_pack_op(x, bits):
    return nat_pack(x, bits, interpret=default_interpret())


def nat_pack_prng_op(x, key):
    return nat_pack_prng(x, _key_words(key))


def nat_decode_sum_op(codes):
    return nat_decode_sum(codes, interpret=default_interpret())


def nat_decode_sum_mean_op(codes):
    return nat_decode_sum_mean(codes, interpret=default_interpret())


def nat_decode_sum_apply_op(codes, h, *, alpha: float):
    return nat_decode_sum_apply(
        codes, h, alpha=alpha, interpret=default_interpret()
    )


# -- sparse (rand-k / top-k + EF) -------------------------------------------

def sparse_gather_op(x, idx):
    return sparse_gather(x, idx.astype(jnp.int32), interpret=default_interpret())


def sparse_decode_sum_op(idx, values, scale, *, d: int):
    return sparse_decode_sum(
        idx.astype(jnp.int32), values, scale, d=d, interpret=default_interpret()
    )


def sparse_decode_sum_mean_op(idx, values, scale, *, d: int):
    return sparse_decode_sum_mean(
        idx.astype(jnp.int32), values, scale, d=d, interpret=default_interpret()
    )


# -- dense (identity) -------------------------------------------------------

def dense_copy_op(x):
    return dense_copy(x, interpret=default_interpret())


def dense_decode_sum_op(values):
    return dense_decode_sum(values, interpret=default_interpret())


def dense_decode_sum_mean_op(values):
    return dense_decode_sum_mean(values, interpret=default_interpret())
