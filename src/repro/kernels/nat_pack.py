"""Natural compression as Pallas TPU kernels (paper §Natural, omega = 1/8).

Encode: each f32 gradient entry is stochastically rounded to a signed power of
two and stored as a 9-bit sign+exponent code in an int16 container (the wire
format of :class:`repro.core.compressors.natural.NaturalCompressor`).  The
fallback derives the rounding probability through ``jnp.frexp``; the kernel
reads the exponent and mantissa straight out of the float's BIT pattern:

* ``p_up = (bits & 0x7FFFFF) * 2^-23`` — for a normal float this is exactly
  ``2*|mant| - 1`` (the fractional part of the mantissa; Sterbenz applies, no
  rounding), i.e. the probability of rounding UP to the next power of two.
* ``chosen = (bits >> 23) - 127 + bernoulli(u < p_up)`` — the unbiased
  exponent, bumped with the stochastic-rounding draw.
* Subnormals are pre-scaled by ``2^24`` (exact — it only shifts the exponent)
  so the same two lines apply, then 24 is subtracted back.

Bitwise agreement with the frexp oracle (:func:`repro.kernels.ref.ref_nat_pack`)
holds on ALL finite inputs including subnormals — that equality is a real test
of the bit trick and is enforced in CI under ``interpret=True``.

Decode_sum: the server unpacks each worker's codes (``sign * 2^(|code|-BIAS)``
via ``exp2`` on the VPU) and accumulates in place over the sequential TPU
grid, so no ``(n, d)`` dense float tensor ever materialises in HBM — traffic
is ``2nd`` bytes of codes in, ``4d`` bytes out.  The ``_apply`` variant fuses
DIANA's server memory update into the last grid step (see
:mod:`repro.kernels.unpack_reduce` for the pattern).

Randomness mirrors :mod:`repro.kernels.quantize_pack`: a pre-drawn-bits
variant (the CI oracle, bitwise-equal to the fallback because both use
``uniform_from_bits``) and a compiled-TPU-only in-kernel PRNG variant that
never materialises the ``(d,)`` uint32 bits operand in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import pad_axis_to_multiple

__all__ = [
    "nat_pack",
    "nat_pack_prng",
    "nat_decode_sum",
    "nat_decode_sum_mean",
    "nat_decode_sum_apply",
    "NAT_BIAS",
    "LANES",
    "DEFAULT_TILE_M",
]

NAT_BIAS = 160  # == repro.core.compressors.natural._BIAS
LANES = 128
DEFAULT_TILE_M = 8


# ---------------------------------------------------------------------------
# Encode
# ---------------------------------------------------------------------------

def _encode_body(x, bits):
    """f32 tile + uint32 bits -> int16 nat codes, bitwise == the frexp oracle."""
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    )
    b0 = jax.lax.bitcast_convert_type(jnp.abs(x), jnp.uint32)
    # Subnormals have a zero exponent field; scaling by 2^24 is exact and
    # moves them into the normal range so one code path covers everything.
    is_sub = ((b0 >> jnp.uint32(23)) == 0) & (x != 0.0)
    xs = jnp.where(is_sub, x * jnp.float32(1 << 24), x)
    bs = jax.lax.bitcast_convert_type(jnp.abs(xs), jnp.uint32)
    p_up = (bs & jnp.uint32(0x7FFFFF)).astype(jnp.float32) * jnp.float32(
        2.0 ** -23
    )
    expo = (
        (bs >> jnp.uint32(23)).astype(jnp.int32)
        - 127
        - jnp.where(is_sub, 24, 0)
    )
    chosen = expo + (u < p_up).astype(jnp.int32)
    sign = jnp.where(x < 0.0, -1, 1)
    code = sign * (chosen + NAT_BIAS)
    return jnp.where(x == 0.0, 0, code).astype(jnp.int16)


def _kernel(x_ref, bits_ref, out_ref):
    out_ref[...] = _encode_body(x_ref[...], bits_ref[...])


def _kernel_prng(seed_ref, x_ref, out_ref):
    pltpu.prng_seed(seed_ref[0], seed_ref[1], pl.program_id(0))
    bits = pltpu.bitcast(pltpu.prng_random_bits(x_ref.shape), jnp.uint32)
    out_ref[...] = _encode_body(x_ref[...], bits)


def _rows(flat: jax.Array, tile_m: int) -> jax.Array:
    """(d,) -> (mp, LANES) with mp a multiple of tile_m (zero padded)."""
    x2 = pad_axis_to_multiple(flat, LANES * tile_m).reshape(-1, LANES)
    return x2


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def nat_pack(
    x: jax.Array,
    bits: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """x (d,) f32, bits (d,) uint32 -> (d,) int16 natural-compression codes."""
    d = x.shape[0]
    x2 = _rows(x.astype(jnp.float32), tile_m)
    b2 = _rows(bits, tile_m)
    mp = x2.shape[0]
    codes = pl.pallas_call(
        _kernel,
        grid=(mp // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, LANES), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, LANES), jnp.int16),
        interpret=interpret,
    )(x2, b2)
    return codes.reshape(-1)[:d]


@functools.partial(jax.jit, static_argnames=("tile_m",))
def nat_pack_prng(
    x: jax.Array,
    seed: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
) -> jax.Array:
    """In-kernel-PRNG encode: x (d,) f32, seed (2,) int32 -> (d,) int16.

    Compiled Mosaic only (``pltpu`` PRNG has no interpret lowering); reached
    exclusively on real TPU backends via ``repro.kernels.ops``.
    """
    d = x.shape[0]
    x2 = _rows(x.astype(jnp.float32), tile_m)
    mp = x2.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(mp // tile_m,),
        in_specs=[pl.BlockSpec((tile_m, LANES), lambda i, seed_ref: (i, 0))],
        out_specs=pl.BlockSpec((tile_m, LANES), lambda i, seed_ref: (i, 0)),
    )
    codes = pl.pallas_call(
        _kernel_prng,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, LANES), jnp.int16),
    )(seed.astype(jnp.int32), x2)
    return codes.reshape(-1)[:d]


# ---------------------------------------------------------------------------
# Decode + accumulate (+ fused server apply)
# ---------------------------------------------------------------------------

def _decode_body(codes):
    c = codes.astype(jnp.int32)
    mag = jnp.exp2((jnp.abs(c) - NAT_BIAS).astype(jnp.float32))
    sign = jnp.sign(c).astype(jnp.float32)
    return jnp.where(c == 0, 0.0, sign * mag)


def _accumulate(i, dense, out_ref):
    # Initialise with the FIRST worker's decode (not zeros) so the kernel
    # reproduces the fallback recurrence ``acc = decode(0); acc += decode(i)``
    # bitwise — natural decode can produce -0.0 (sign * underflowed exp2) and
    # ``0.0 + (-0.0)`` would flip it to +0.0.
    @pl.when(i == 0)
    def _init():
        out_ref[...] = dense

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += dense


def _sum_kernel(codes_ref, out_ref):
    _accumulate(pl.program_id(0), _decode_body(codes_ref[0]), out_ref)


def _mean_kernel(codes_ref, out_ref, *, n):
    _sum_kernel(codes_ref, out_ref)

    @pl.when(pl.program_id(0) == n - 1)
    def _mean():
        out_ref[...] = out_ref[...] / jnp.float32(n)


def _apply_kernel(codes_ref, h_ref, ghat_ref, newh_ref, *, n, alpha):
    _accumulate(pl.program_id(0), _decode_body(codes_ref[0]), ghat_ref)

    @pl.when(pl.program_id(0) == n - 1)
    def _apply():
        dm = ghat_ref[...] / jnp.float32(n)
        h = h_ref[...]
        ghat_ref[...] = h + dm
        newh_ref[...] = h + jnp.float32(alpha) * dm


def _codes_rows(codes: jax.Array, tile_m: int) -> jax.Array:
    """(n, d) int16 -> (n, mp, LANES), zero padded (code 0 decodes to 0.0)."""
    n, d = codes.shape
    c = pad_axis_to_multiple(codes, LANES * tile_m, axis=1)
    return c.reshape(n, -1, LANES)


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def nat_decode_sum(
    codes: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """codes (n, d) int16 -> (d,) f32 sum of decodes over workers."""
    d = codes.shape[1]
    c = _codes_rows(codes, tile_m)
    n, mp, _ = c.shape
    out = pl.pallas_call(
        _sum_kernel,
        grid=(n, mp // tile_m),
        in_specs=[pl.BlockSpec((1, tile_m, LANES), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((tile_m, LANES), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, LANES), jnp.float32),
        interpret=interpret,
    )(c)
    return out.reshape(-1)[:d]


@functools.partial(jax.jit, static_argnames=("tile_m", "interpret"))
def nat_decode_sum_mean(
    codes: jax.Array,
    *,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> jax.Array:
    """Fused decode_sum + divide: codes (n, d) -> (d,) mean of decodes."""
    d = codes.shape[1]
    c = _codes_rows(codes, tile_m)
    n, mp, _ = c.shape
    out = pl.pallas_call(
        functools.partial(_mean_kernel, n=n),
        grid=(n, mp // tile_m),
        in_specs=[pl.BlockSpec((1, tile_m, LANES), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((tile_m, LANES), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, LANES), jnp.float32),
        interpret=interpret,
    )(c)
    return out.reshape(-1)[:d]


@functools.partial(jax.jit, static_argnames=("alpha", "tile_m", "interpret"))
def nat_decode_sum_apply(
    codes: jax.Array,
    h: jax.Array,
    *,
    alpha: float,
    tile_m: int = DEFAULT_TILE_M,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fused decode_sum + DIANA server update.

    codes (n, d) int16, h (d,) f32 -> flat ``(h + dm, h + alpha * dm)`` with
    ``dm = sum_i decode(codes_i) / n``, both (d,).
    """
    d = codes.shape[1]
    if h.shape[0] != d:
        raise ValueError(f"h length {h.shape[0]} != payload dim {d}")
    c = _codes_rows(codes, tile_m)
    n, mp, _ = c.shape
    h2 = pad_axis_to_multiple(h.astype(jnp.float32), LANES * tile_m).reshape(
        -1, LANES
    )
    ghat, newh = pl.pallas_call(
        functools.partial(_apply_kernel, n=n, alpha=float(alpha)),
        grid=(n, mp // tile_m),
        in_specs=[
            pl.BlockSpec((1, tile_m, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((tile_m, LANES), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, LANES), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_m, LANES), lambda i, j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, LANES), jnp.float32),
            jax.ShapeDtypeStruct((mp, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(c, h2)
    return ghat.reshape(-1)[:d], newh.reshape(-1)[:d]
