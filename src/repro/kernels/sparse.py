"""Sparse (rand-k / top-k) payload kernels: value gather and scatter-add
decode_sum, with the optional fused DIANA server update.

The fusion boundary (DESIGN.md §Kernels): index SELECTION — ``top_k`` of
random tags for rand-k, magnitude ``top_k`` for top-k — stays in lax.  It is
control logic, it owns the PRNG schedule that the bitwise bucketed==per-leaf
contract depends on, and XLA's sort lowerings are already tuned.  What Pallas
owns is the data movement: the compress-side value gather and the server-side
scatter-add accumulation ``sum_i scatter(idx_i, values_i * scale)``, which the
sequential TPU grid accumulates in place so the ``(n, d)`` dense per-worker
tensor never materialises in HBM (traffic: ``n*k`` index/value pairs in,
``4d`` bytes out, instead of ``n * 4d``).

Shapes are exact (no lane padding) and the kernels are validated bitwise
against :func:`repro.kernels.ref.ref_sparse_decode_sum` under
``interpret=True`` — the CI contract.  Compiled Mosaic lowering of dynamic
gather/scatter is not portable across TPU generations, so these kernels are
interpret-contract only and ``use_kernel`` stays opt-in for the sparse
operators (``auto`` resolves to off; see ``tools/check_kernels.py``).

``scale`` is always a per-entry (k,) vector operand: ``full(d/k)`` for
per-leaf rand-k (bitwise-equal to the scalar multiply of the fallback),
the per-segment ``d_l/k_l`` staircase for bucketed rand-k, and ones for
top-k (``x * 1.0 == x`` exactly).

The fused ``_mean`` variant folds the final ``/n`` into the last grid step —
a single correctly rounded op, so fusing it cannot perturb bits.  There is
deliberately NO fused alpha-apply variant: the DIANA memory tail
``h' = h + alpha*dm`` composes OUTSIDE the kernel via the operator's base
hooks.  XLA's FMA contraction of that multiply-add is decided per-fusion at
codegen, so the kernel route stays bitwise-equal to the lax fallback only if
both routes feed the IDENTICAL epilogue fusion a materialised sum — which
they do: the fallback's scatter chain and this kernel's grid loop both
materialise ``s``, and the base-hook composition downstream is literally the
same code.  (The ternary/natural families fuse their epilogue in-kernel
instead; their fallback decode is one elementwise fusion, which contracts
the same way as the kernel body — asserted by the coverage tests.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "sparse_gather",
    "sparse_decode_sum",
    "sparse_decode_sum_mean",
]


def _gather_kernel(x_ref, idx_ref, out_ref):
    out_ref[...] = x_ref[...][idx_ref[...]]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_gather(
    x: jax.Array, idx: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """Compress-side value gather: x (d,) f32, idx (k,) int32 -> (k,) f32."""
    d, k = x.shape[0], idx.shape[0]
    return pl.pallas_call(
        _gather_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), idx)


def _dense_row(idx_ref, val_ref, scale_ref, d: int):
    scaled = val_ref[0] * scale_ref[...]
    return jnp.zeros((d,), jnp.float32).at[idx_ref[0]].add(scaled)


def _accumulate(i, dense, out_ref):
    # Init with the first worker's scatter (not zeros + add): the fallback
    # recurrence starts from ``decode(select(0))`` and -0.0 products must
    # survive bitwise (0.0 + (-0.0) == +0.0 would lose them).
    @pl.when(i == 0)
    def _init():
        out_ref[...] = dense

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += dense


def _sum_kernel(idx_ref, val_ref, scale_ref, out_ref):
    i = pl.program_id(0)
    _accumulate(i, _dense_row(idx_ref, val_ref, scale_ref, out_ref.shape[0]), out_ref)


def _mean_kernel(idx_ref, val_ref, scale_ref, out_ref, *, n):
    _sum_kernel(idx_ref, val_ref, scale_ref, out_ref)

    @pl.when(pl.program_id(0) == n - 1)
    def _mean():
        out_ref[...] = out_ref[...] / jnp.float32(n)


def _sparse_specs(n, k, d):
    in_specs = [
        pl.BlockSpec((1, k), lambda i: (i, 0)),   # idx
        pl.BlockSpec((1, k), lambda i: (i, 0)),   # values
        pl.BlockSpec((k,), lambda i: (0,)),       # scale (shared)
    ]
    out_spec = pl.BlockSpec((d,), lambda i: (0,))
    return in_specs, out_spec


@functools.partial(jax.jit, static_argnames=("d", "interpret"))
def sparse_decode_sum(
    idx: jax.Array,
    values: jax.Array,
    scale: jax.Array,
    *,
    d: int,
    interpret: bool = True,
) -> jax.Array:
    """idx/values (n, k), scale (k,) -> (d,) f32 scatter-add sum over workers."""
    n, k = idx.shape
    in_specs, out_spec = _sparse_specs(n, k, d)
    return pl.pallas_call(
        _sum_kernel,
        grid=(n,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(idx, values.astype(jnp.float32), scale.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("d", "interpret"))
def sparse_decode_sum_mean(
    idx: jax.Array,
    values: jax.Array,
    scale: jax.Array,
    *,
    d: int,
    interpret: bool = True,
) -> jax.Array:
    """Fused scatter-add decode_sum + divide -> (d,) mean over workers.

    The divide is a single correctly rounded op, so fusing it is
    contraction-safe — unlike the memory multiply-add, which is why there is
    no ``apply`` variant (module docstring)."""
    n, k = idx.shape
    in_specs, out_spec = _sparse_specs(n, k, d)
    return pl.pallas_call(
        functools.partial(_mean_kernel, n=n),
        grid=(n,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=interpret,
    )(idx, values.astype(jnp.float32), scale.astype(jnp.float32))


