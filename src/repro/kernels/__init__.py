"""Pallas TPU kernels for DIANA's compression hot path.

quantize_pack:  fused block p-quantize + 2-bit pack (one HBM->VMEM pass)
unpack_reduce:  streaming ternary decode + accumulate over workers, with
                fused ``_mean`` / ``_apply`` (server memory update) variants
nat_pack:       natural-compression encode via exponent bit masks, plus the
                matching streaming decode_sum(+apply)
sparse:         rand-k / top-k value gather and scatter-add decode_sum(+apply)
dense:          identity payload pass-through and accumulate

Each kernel has a pure-jnp oracle in :mod:`ref` and is validated bitwise with
``interpret=True`` in ``tests/test_kernels.py`` / ``tests/test_kernel_coverage.py``;
``tools/check_kernels.py`` lints that every registry operator declares its
kernel capability and names its oracle.
"""

from . import dense, nat_pack, ops, ref, sparse
from .quantize_pack import quantize_pack, quantize_pack_prng
from .unpack_reduce import unpack_reduce, unpack_reduce_apply, unpack_reduce_mean

__all__ = [
    "dense",
    "nat_pack",
    "ops",
    "ref",
    "sparse",
    "quantize_pack",
    "quantize_pack_prng",
    "unpack_reduce",
    "unpack_reduce_apply",
    "unpack_reduce_mean",
]
