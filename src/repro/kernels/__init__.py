"""Pallas TPU kernels for DIANA's compression hot path.

quantize_pack:  fused block p-quantize + 2-bit pack (one HBM->VMEM pass)
unpack_reduce:  streaming decode + accumulate over workers (server side)

Each kernel has a pure-jnp oracle in :mod:`ref` and is validated in
``tests/test_kernels.py`` over a shape/dtype/p sweep with ``interpret=True``.
"""

from . import ops, ref
from .quantize_pack import quantize_pack, quantize_pack_prng
from .unpack_reduce import unpack_reduce

__all__ = ["ops", "ref", "quantize_pack", "quantize_pack_prng", "unpack_reduce"]
