"""Version shims over jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and grew ``axis_names=`` / ``check_vma=`` in place of ``auto=`` /
``check_rep=``).  Call sites in this repo always use the NEW keyword style:

    shard_map(f, mesh=mesh, in_specs=..., out_specs=...,
              axis_names={...}, check_vma=False)

and this module translates to whatever the installed jax provides:

* new jax:  forwarded verbatim (``axis_names`` dropped if the installed
  ``jax.shard_map`` predates it and the call manualizes every mesh axis).
* old jax (<= 0.4.x): routed to ``jax.experimental.shard_map.shard_map`` with
  ``auto = mesh.axis_names - axis_names`` and ``check_rep = check_vma``.
"""

from __future__ import annotations

import inspect
from typing import Any

import jax

__all__ = ["shard_map", "axis_size", "supports_nested_manual"]


def supports_nested_manual() -> bool:
    """Whether this jax/XLA can nest a shard_map that completes the
    manualization inside an already partial-manual body.

    On 0.4.x the SPMD partitioner RET_CHECKs (``IsManualSubgroup``) on the
    nested pattern; callers fall back to keeping the inner axes auto (GSPMD
    constraints) instead of the nested fully-manual map (DESIGN.md §6).
    """
    return _NEW is not None


def axis_size(name) -> "jax.Array | int":
    """``jax.lax.axis_size`` (added after 0.4) with a ``psum(1, name)``
    fallback — inside a shard_map/pmap body both yield the mapped size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)

_NEW = getattr(jax, "shard_map", None)

if _NEW is None:
    try:  # pragma: no cover - exercised only on old jax
        from jax.experimental.shard_map import shard_map as _LEGACY
    except ImportError:  # pragma: no cover
        _LEGACY = None
else:
    _LEGACY = None


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """New-style ``shard_map`` on any supported jax version.

    ``axis_names`` — the mesh axes the body manualizes (``None`` = all of
    them); ``check_vma`` — replication/varying-manual-axes checking (named
    ``check_rep`` before jax 0.5).
    """
    if _NEW is not None:
        kwargs: dict[str, Any] = dict(
            mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
        params = inspect.signature(_NEW).parameters
        if axis_names is not None:
            if "axis_names" in params:
                kwargs["axis_names"] = set(axis_names)
            elif "auto" in params:
                # transitional signature: manual axes are implied, the
                # complement is passed as auto
                kwargs["auto"] = frozenset(
                    a for a in mesh.axis_names if a not in set(axis_names)
                )
            elif set(axis_names) != set(mesh.axis_names):
                raise NotImplementedError(
                    "installed jax.shard_map supports neither axis_names= nor "
                    "auto=; partial-manual mapping is not expressible"
                )
        if "check_vma" in params:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in params:
            kwargs["check_rep"] = check_vma
        return _NEW(f, **kwargs)

    if _LEGACY is None:  # pragma: no cover
        raise ImportError("no shard_map implementation found in this jax")

    manual = set(mesh.axis_names) if axis_names is None else set(axis_names)
    auto = frozenset(a for a in mesh.axis_names if a not in manual)
    return _LEGACY(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )
