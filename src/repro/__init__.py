"""repro — a JAX/Pallas reproduction framework for DIANA
(Mishchenko et al., Distributed Learning with Compressed Gradient Differences).

Package layout: core/ (the paper's algorithm), models/, optim/, data/,
checkpoint/, configs/, kernels/ (Pallas), launch/ (mesh, train, serve, dryrun).
"""

import jax as _jax

# Pin the classic GSPMD partitioner. Shardy (the JAX 0.8 default) lowers
# with_sharding_constraint inside shard_map *manual-axes* bodies as fully-open
# ``sdy.sharding_constraint [{?}...]`` hints, dropping the named-axis
# assignment — measured +54 GiB/device of replicated vocab/payload tensors on
# the 16x16 production mesh (see DESIGN.md §Known-limitations). Revisit when
# Shardy honours closed constraints under manual subgroups.
_jax.config.update("jax_use_shardy_partitioner", False)

__version__ = "0.1.0"
