"""The four assigned input shapes + ShapeDtypeStruct input specs for dry-runs.

Decode shapes lower ``serve_step`` (ONE new token against a KV/SSM cache of
``seq_len``); train/prefill shapes lower ``train_step`` / prefill forward.
``long_500k`` engages each architecture's sub-quadratic path: native for
SSM/hybrid, sliding-window (cfg.sliding_window) for attention archs.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig

__all__ = ["SHAPES", "get_shape", "input_specs", "shape_applicable"]

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason). long_500k needs a sub-quadratic path."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, (
            f"{cfg.name} is pure full-attention with no sliding_window configured; "
            "long_500k requires a sub-quadratic variant (DESIGN.md §Arch-applicability)"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: full (B, S) token batch — frontend models receive their
    stub embeddings for a ``cfg.frontend_tokens`` prefix and tokens for the rest.
    decode: one token per sequence (the cache is part of serve state, not input).
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    s_tokens = s
    if cfg.frontend == "vision":
        from repro.models.transformer import FRONTEND_DIM

        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, FRONTEND_DIM["vision"]), f32
        )
        s_tokens = s - cfg.frontend_tokens
    elif cfg.frontend == "audio":
        from repro.models.transformer import FRONTEND_DIM

        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, FRONTEND_DIM["audio"]), f32
        )
        s_tokens = s - cfg.frontend_tokens
    specs["tokens"] = jax.ShapeDtypeStruct((b, s_tokens), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s_tokens), jnp.int32)
    return specs
