"""mamba2-130m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] — 24L, d_model=768, vocab=50280, ssm_state=128.
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads.

Natively sub-quadratic: long_500k runs the recurrent decode with O(1) state.
DIANA applies unchanged (gradients are architecture-agnostic) — this arch
demonstrates the technique on a non-attention family.
"""

from .base import LayerSpec, ModelConfig, SSMConfig, register


@register("mamba2-130m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        arch_type="ssm",
        citation="arXiv:2405.21060",
        n_layers=24,
        d_model=768,
        n_heads=24,                   # SSD heads (d_inner / head_dim)
        n_kv_heads=24,
        d_ff=0,                       # no MLP — mamba blocks only
        vocab=50280,
        pattern=(LayerSpec(mixer="mamba", mlp="none"),),
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256),
        comp_block=1024,              # smaller blocks for a 130M model
        # Curated SSM policy (--comp-policy default): the SSD dynamics
        # parameters (A_log/D/dt_bias), conv kernels and norms are tiny and
        # govern the recurrence's stability -> exact; embeddings top-k;
        # projections ternary at the model's block size.
        comp_policy=("A_log|dt_bias|/D$|scale$|conv_=identity,"
                     "^embed$|^lm_head$=topk_ef:k=256,"
                     "*=diana:block=1024"),
    )
