"""jamba-v0.1-52b — AI21 Jamba: Mamba + attention 1:7 interleave, MoE.

[arXiv:2403.19887] — 32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=65536, 16 experts top-2.  Block period 8: one attention layer per 7
mamba layers (attention at in-block index 3), MoE replacing the MLP on every
other layer (odd indices) — 4 scanned super-blocks of 8.

Sub-quadratic natively (mamba carries long context; the 4 attention layers
keep full 500k KV caches, sequence-sharded over the data axes in decode).
"""

import jax.numpy as jnp

from .base import LayerSpec, ModelConfig, MoEConfig, SSMConfig, register


def _pattern():
    specs = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        mlp = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, mlp=mlp))
    return tuple(specs)


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        citation="arXiv:2403.19887",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=65536,
        act="swiglu",
        pattern=_pattern(),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, partition="expert"),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=64, chunk_size=256),
        h_dtype=jnp.bfloat16,
        comp_worker_axes=("pod",),    # 52B: hierarchical DIANA (compress the slow link)
    )
