"""internvl2-2b — InternVL2: InternViT vision encoder + InternLM2 decoder.

[arXiv:2404.16821] — LM backbone: 24L, d_model=2048, 16 heads (GQA kv=8),
d_ff=8192, vocab=92553.  The InternViT encoder + MLP projector is a STUB:
``input_specs`` supplies precomputed patch embeddings (B, 256, 1024); the
language decoder that consumes them is fully implemented (allowed carve-out).
"""

from .base import ModelConfig, register


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        arch_type="vlm",
        citation="arXiv:2404.16821",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=92553,
        act="swiglu",
        frontend="vision",
        frontend_tokens=256,
        sliding_window=8192,          # engaged only by long_500k
    )
