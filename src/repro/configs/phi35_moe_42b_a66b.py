"""phi3.5-moe-42b-a6.6b — Microsoft Phi-3.5-MoE.

[hf:microsoft/Phi-3.5-MoE-instruct] — 32L, d_model=4096, 32 heads (GQA kv=8),
per-expert d_ff=6400, vocab=32064, 16 experts top-2.

16 experts divide the 16-wide model axis exactly -> expert parallelism.
42B total params: DIANA memory kept in bf16 and ZeRO-style sharding of the
optimizer state (see launch/train.py) keep the per-chip footprint in budget.
"""

import jax.numpy as jnp

from .base import LayerSpec, ModelConfig, MoEConfig, register


@register("phi3.5-moe-42b-a6.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        arch_type="moe",
        citation="hf:microsoft/Phi-3.5-MoE-instruct",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab=32064,
        act="swiglu",
        pattern=(LayerSpec(mixer="attn", mlp="moe"),),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400, partition="expert"),
        sliding_window=8192,          # engaged only by long_500k
        h_dtype=jnp.bfloat16,
        comp_worker_axes=("pod",),    # 42B: hierarchical DIANA + ZeRO over data
    )
