"""stablelm-3b — Stability AI StableLM-2 family scaled per assignment.

[hf:stabilityai/stablelm-2-1_6b] — 32L, d_model=2560, 32 heads (GQA kv=32,
i.e. MHA), d_ff=6912, vocab=50304.
"""

from .base import LayerSpec, ModelConfig, register


@register("stablelm-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        arch_type="dense",
        citation="hf:stabilityai/stablelm-2-1_6b",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab=50304,
        act="swiglu",
        rope_theta=10_000.0,
        sliding_window=8192,          # engaged only by long_500k
    )
