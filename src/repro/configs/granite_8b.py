"""granite-8b — IBM Granite Code 8B (llama architecture).

[arXiv:2405.04324] — 36L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=49152.
"""

from .base import ModelConfig, register


@register("granite-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        arch_type="dense",
        citation="arXiv:2405.04324",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=49152,
        act="swiglu",
        sliding_window=8192,          # engaged only by long_500k
    )
