"""nemotron-4-15b — NVIDIA Nemotron-4.

[arXiv:2402.16819] — 32L, d_model=6144, 48 heads (GQA kv=8), d_ff=24576,
vocab=256000, squared-ReLU MLP (no gate), RoPE.
"""

import jax.numpy as jnp

from .base import ModelConfig, register


@register("nemotron-4-15b")
def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        citation="arXiv:2402.16819",
        n_layers=32,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab=256000,
        act="relu2",                  # squared ReLU
        rope_theta=10_000.0,
        sliding_window=8192,          # engaged only by long_500k
        h_dtype=jnp.bfloat16,         # 15B: halve DIANA memory footprint
    )
