"""Config dataclasses + the architecture registry (``--arch <id>``)."""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "LayerSpec",
    "MoEConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "register",
    "get_config",
    "list_archs",
    "reduced",
    "VOCAB_PAD",
]

VOCAB_PAD = 4096  # embedding tables padded to a multiple of this (sharding rule)


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating block pattern."""

    mixer: str = "attn"   # attn | mamba
    mlp: str = "dense"    # dense | moe | none


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    capacity_factor: float = 1.25
    partition: str = "expert"      # expert | ffn  (ffn when n_experts % model_axis != 0)
    aux_loss_weight: float = 0.01
    token_chunk: int = 0           # 0 = default MOE_TOKEN_CHUNK; §Perf knob:
                                   # weight-restreaming vs dispatch-buffer memory


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""
    head_dim: Optional[int] = None
    act: str = "swiglu"            # swiglu | gelu | relu2
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: str = "none"         # none | vision | audio
    frontend_tokens: int = 256     # patch/frame positions supplied by the stub
    sliding_window: Optional[int] = None   # engaged only by long_500k
    tie_embeddings: bool = False
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"            # none | full | dots
    scan_unroll: bool = False      # unroll layer/chunk scans (no dynamic-slice:
                                   # required under >1 manual mesh axes, see train.py)
    attn_q_chunk: int = 2048       # query-chunked attention above this seq len
    # --- DIANA / training defaults (overridable from the CLI) ---
    compression: str = "diana"     # any repro.core.compressors registry name/alias
    comp_p: float = math.inf
    comp_block: int = 2048
    comp_k: int = 64               # kept coordinates for rand-k / top-k
    comp_worker_axes: Tuple[str, ...] = ("pod", "data")
    comp_bucketed: bool = True     # whole-model flat-buffer aggregation (one
                                   # compress / gather / decode per step,
                                   # repro.core.bucket); False = per-leaf
    vr: bool = False               # VR-DIANA: L-SVRG control variates under
                                   # the compressed-difference loop (core.vr)
    vr_p: Optional[float] = None   # snapshot-refresh probability; None = the
                                   # paper's 1/m (resolved by launch/train.py)
    comp_down_method: Optional[str] = None  # downlink (server->worker)
                                   # compressor for the broadcast direction;
                                   # None = full-precision broadcast
    comp_down_k: Optional[int] = None  # sparse downlink budget; None = comp_k
    comp_policy: Optional[str] = None  # the model's curated per-parameter-
                                   # group compression policy (inline rule
                                   # syntax, repro.core.policy.parse_rules) —
                                   # OPT-IN via --comp-policy default /
                                   # make_optimizer(policy="default"); the
                                   # flat comp_* surface stays the default so
                                   # existing configs/checkpoints are bitwise
                                   # untouched.  tools/check_policy.py lints
                                   # these strings against the arch's actual
                                   # parameter tree in CI.
    h_dtype: Any = jnp.float32

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // VOCAB_PAD) * VOCAB_PAD

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by pattern "
            f"period {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    def has_attention(self) -> bool:
        return any(l.mixer == "attn" for l in self.pattern)

    def has_mamba(self) -> bool:
        return any(l.mixer == "mamba" for l in self.pattern)

    def supports_long_context(self) -> bool:
        """long_500k eligibility: SSM/hybrid natively; attention via sliding window."""
        return self.has_mamba() or self.sliding_window is not None


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401 — populate registry

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced variants for CPU smoke tests (2 layers, d_model <= 512, <= 4 experts)
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same family, toy size: shapes asserted + no-NaN forward on CPU."""
    period = len(cfg.pattern)
    n_layers = period if period > 1 else 2
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, max(1, n_heads // 2))
    # capacity_factor = n_experts -> capacity = T*top_k: no token drops, so
    # prefill and decode route identically (parity tests are exact)
    moe = cfg.moe and replace(cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
                              top_k=min(cfg.moe.top_k, 2), d_ff=128,
                              capacity_factor=float(min(cfg.moe.n_experts, 4)))
    ssm = cfg.ssm and replace(cfg.ssm, d_state=32, head_dim=32, chunk_size=64)
    return replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=min(cfg.d_ff, 512) or cfg.d_ff,
        vocab=min(cfg.vocab, 512),
        moe=moe,
        ssm=ssm,
        frontend_tokens=min(cfg.frontend_tokens, 16),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        remat="none",
    )
