"""Architecture registry: importing this package registers all configs."""

from .base import (
    LayerSpec,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    get_config,
    list_archs,
    reduced,
)
from .shapes import SHAPES, get_shape, input_specs, shape_applicable

# Register every assigned architecture (order = assignment table).
from . import granite_moe_3b_a800m  # noqa: F401
from . import stablelm_3b           # noqa: F401
from . import nemotron_4_15b        # noqa: F401
from . import musicgen_large        # noqa: F401
from . import granite_8b            # noqa: F401
from . import phi35_moe_42b_a66b    # noqa: F401
from . import mamba2_130m           # noqa: F401
from . import jamba_v01_52b         # noqa: F401
from . import internvl2_2b          # noqa: F401
from . import llama32_1b            # noqa: F401
from . import diana_paper           # noqa: F401

ASSIGNED_ARCHS = (
    "granite-moe-3b-a800m",
    "stablelm-3b",
    "nemotron-4-15b",
    "musicgen-large",
    "granite-8b",
    "phi3.5-moe-42b-a6.6b",
    "mamba2-130m",
    "jamba-v0.1-52b",
    "internvl2-2b",
    "llama3.2-1b",
)

__all__ = [
    "LayerSpec", "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "get_config", "list_archs", "reduced",
    "SHAPES", "get_shape", "input_specs", "shape_applicable",
    "ASSIGNED_ARCHS",
]
