"""llama3.2-1b — Meta Llama 3.2 1B.

[hf:meta-llama/Llama-3.2-1B] — 16L, d_model=2048, 32 heads (GQA kv=8),
d_ff=8192, vocab=128256, rope theta 500k.
"""

from .base import ModelConfig, register


@register("llama3.2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        arch_type="dense",
        citation="hf:meta-llama/Llama-3.2-1B",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab=128256,
        act="swiglu",
        rope_theta=500_000.0,
        sliding_window=8192,          # engaged only by long_500k
        # Curated transformer policy (--comp-policy default): norms/biases
        # are tiny and conditioning-critical -> exact; embedding/unembedding
        # gradients are token-sparse -> top-k with error feedback; the dense
        # bulk runs the paper's ternary operator.  Theory-optimal per Def. 2:
        # each group's rate is governed by its own alpha_p(d_l).
        comp_policy=("scale$|bias=identity,"
                     "^embed$|^lm_head$=topk_ef:k=256,"
                     "*=diana"),
    )
