"""The paper's own experimental configurations (Sec. 6 / Sec. M).

Convex experiments: l2/l1-regularised logistic regression (LIBSVM
'mushrooms'-scale synthetic data), parameter grids the paper sweeps, and the
Rosenbrock decomposition of Sec. M.1.  Used by benchmarks/ and examples/.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Tuple

__all__ = ["LogRegProblem", "PAPER_GRIDS", "ROSENBROCK"]


@dataclass(frozen=True)
class LogRegProblem:
    """Synthetic stand-in for the paper's LIBSVM problems (offline CI has no
    dataset downloads): n_samples x dim binary classification, the same scale
    as 'mushrooms' (8124 x 112) / 'a5a' (6414 x 122)."""

    name: str = "mushrooms-synthetic"
    n_samples: int = 8124
    dim: int = 112
    n_workers: int = 10
    l2: float = 1e-4           # order 1/N as in the paper
    l1: float = 2e-3           # paper's l1 coefficient (sparse solutions)
    seed: int = 0


# Hyper-parameter grids from Sec. 6 (Cifar10/Mnist runs)
PAPER_GRIDS = {
    "learning_rates": (0.1, 0.2, 0.05),
    "bucket_sizes": (32, 128, 512),
    "momentum": (0.0, 0.95, 0.99),
    "alphas": ("0", "1/sqrt(bucket)"),
    "norms": (2.0, math.inf),
}


# Sec. M.1: f = average of f1, f2 — each worker holds one piece.
# f(x, y) = (x-1)^2 + 10(y - x^2)^2
# f1 = (x+16)^2 + 10(y-x^2)^2 + 16y ; f2 = (x-18)^2 + 10(y-x^2)^2 - 16y + c
ROSENBROCK = {
    "f1": lambda x, y: (x + 16.0) ** 2 + 10.0 * (y - x * x) ** 2 + 16.0 * y,
    "f2": lambda x, y: (x - 18.0) ** 2 + 10.0 * (y - x * x) ** 2 - 16.0 * y,
    "optimum": (1.0, 1.0),
}
