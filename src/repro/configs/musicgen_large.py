"""musicgen-large — Meta MusicGen, decoder-only over EnCodec tokens.

[arXiv:2306.05284] — 48L, d_model=2048, 32 heads (MHA kv=32), d_ff=8192,
vocab=2048 (EnCodec codebook).  The EnCodec/conv frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, frames, 128) that a
learned projector lifts to d_model; the transformer backbone is fully
implemented (the allowed carve-out).
"""

from .base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        arch_type="audio",
        citation="arXiv:2306.05284",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab=2048,
        act="gelu",
        frontend="audio",
        frontend_tokens=256,
        sliding_window=8192,          # engaged only by long_500k
    )
