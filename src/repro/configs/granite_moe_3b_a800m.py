"""granite-moe-3b-a800m — IBM Granite 3.0 MoE family.

[hf:ibm-granite/granite-3.0-1b-a400m-base] — 32L, d_model=1536, 24 heads
(GQA kv=8), per-expert d_ff=512, vocab=49155, 40 experts top-8.

40 experts do not divide the 16-wide model axis, so experts are
tensor-parallel over their d_ff (``partition="ffn"``) — see DESIGN.md sharding
rules.  Every layer is MoE (a800m active).
"""

import jax.numpy as jnp

from .base import LayerSpec, ModelConfig, MoEConfig, register


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        arch_type="moe",
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab=49155,
        act="swiglu",
        pattern=(LayerSpec(mixer="attn", mlp="moe"),),
        moe=MoEConfig(n_experts=40, top_k=8, d_ff=512, partition="ffn"),
        sliding_window=8192,          # engaged only by long_500k
        comp_block=2048,
        attn_q_chunk=512,             # 24 heads don't shard over model=16 ->
                                      # scores replicate; keep chunks small
        # Curated MoE policy (--comp-policy default): the router is tiny and
        # decides every token's expert assignment -> exact (a quantized
        # router reroutes tokens, compounding error); norms/biases exact;
        # embeddings top-k; the expert FFN bulk takes natural compression
        # (9 bits/dim, omega=1/8 — gentler than ternary on the sparsely-
        # activated expert gradients); everything else ternary.
        comp_policy=("router|scale$|bias=identity,"
                     "^embed$|^lm_head$=topk_ef:k=256,"
                     "mlp/w_=natural,"
                     "*=diana"),
    )
