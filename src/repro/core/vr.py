"""VR-DIANA variance reduction — L-SVRG control variates under compression.

DIANA removes the *compression* noise of the gradient differences, but with
stochastic finite-sum gradients the iterates still stall at a variance ball
set by the *sampling* noise (Thm 2's sigma term).  Horváth et al.,
"Stochastic Distributed Learning with Gradient Quantization and Variance
Reduction" (arXiv:1904.05115), close that gap: each worker layers an
L-SVRG control variate under the same compressed-difference mechanism,

    k_i^t = g_i^t - grad f_{ij_t}(w_i^t) + mu_i^t,
    mu_i^t = (1/m) sum_j grad f_{ij}(w_i^t),

and feeds ``k_i`` (instead of the raw stochastic gradient ``g_i``) into
DIANA's compressor input ``k_i - h_i``.  The snapshot ``w_i`` refreshes
probabilistically (loopless SVRG): with probability ``p`` — paper default
``p = 1/m`` — worker ``i`` sets ``w_i <- x^t`` and recomputes ``mu_i``.
The resulting estimator is unbiased (``E_j[k_i] = grad f_i(x)``) and its
variance vanishes as ``x, w_i -> x*``, giving LINEAR convergence to the
exact optimum with stochastic gradients (their Thm 3.1), where plain
DIANA/QSGD stall at the variance floor.

This module owns the *state and algebra* only — what the control-variated
gradient is, and how the (snapshot, mu) pair refreshes.  It is deliberately
oblivious to the loss: callers supply the gradients at the snapshot and the
refresh candidate for ``mu`` (a full local gradient in the finite-sum
setting; the freshest minibatch gradient in the streaming trainer — see
DESIGN.md §VR).  The aggregation plumbing lives in :mod:`repro.core.diana`,
which applies :func:`control_variate` BEFORE any layout decision, so VR
composes unchanged with every registry compressor in both the per-leaf and
bucketed layouts.

PRNG schedule contract: the Bernoulli coin of worker ``i`` at a step keyed
``key`` is ``bernoulli(fold_in(fold_in(key, i), VR_FOLD), p)``.  The
distributed path receives the already worker-folded key and folds
``VR_FOLD``; the reference path folds the worker index itself — both draw
the identical coin, which is what keeps ``aggregate_shardmap`` and
``reference_step`` bitwise-equal under VR (tests/test_convergence_laws.py).
``VR_FOLD`` is distinct from any compression fold, so enabling VR never
perturbs the compressor's draws.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "VRState",
    "VR_FOLD",
    "VarianceReducer",
    "init_vr",
    "control_variate",
    "vr_coin",
    "reference_coins",
    "refresh",
    "resolve_vr_p",
]

# Folded into the (worker-folded) step key for the snapshot coin; distinct
# from the compressor key schedule (which only ever splits / folds leaf and
# worker indices), so the coin stream never collides with compression draws.
VR_FOLD = 0x5652  # 'VR'


class VRState(NamedTuple):
    """Per-worker L-SVRG state carried inside :class:`~repro.core.diana.DianaState`.

    Both fields keep the PARAMETER layout (leaves ``(n_local, *shape)``) in
    every aggregation layout — VR algebra runs on parameter-shaped gradient
    trees *before* the per-leaf/bucketed flattening, so the slot is
    layout-independent (and checkpoints round-trip it like any other pytree).

    snapshot: the worker's snapshot point ``w_i`` — a per-worker copy of the
              params (param dtype, so a second grad pass can run on it).
    mu:       the control variate ``mu_i = (1/m) sum_j grad f_{ij}(w_i)``
              (f32, like every gradient accumulator in the repo).
    """

    snapshot: Any
    mu: Any


def init_vr(params, n_workers: int, mu=None) -> VRState:
    """``w_i^0 = x^0`` for every worker; ``mu`` defaults to zeros.

    Exact L-SVRG semantics need ``mu_i^0 = grad f_i(w_i^0)`` — the convex
    harness (benchmarks/common.py) computes it and installs it via
    ``state._replace``; the streaming trainer instead forces a refresh on
    step 0 (``vr_force_refresh`` in :func:`repro.core.diana.aggregate_shardmap`),
    after which the state is self-consistent.
    """
    snapshot = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n_workers,) + p.shape), params
    )
    if mu is None:
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_workers,) + p.shape, jnp.float32), params
        )
    return VRState(snapshot=snapshot, mu=mu)


def control_variate(g, g_snapshot, mu):
    """The L-SVRG estimator ``k = g - grad f_j(w) + mu``, elementwise in f32.

    All three trees share the gradient (= parameter) shapes; the result is
    f32 regardless of input dtypes — it feeds the compressor input, which
    always upcasts, so doing the algebra in f32 keeps the reference and
    distributed paths bit-identical.
    """
    return jax.tree_util.tree_map(
        lambda a, b, c: a.astype(jnp.float32) - b.astype(jnp.float32)
        + c.astype(jnp.float32),
        g, g_snapshot, mu,
    )


def vr_coin(worker_key: jax.Array, p: float) -> jax.Array:
    """This worker's Bernoulli(p) snapshot coin (``worker_key`` is already
    folded with the worker index — the distributed convention).

    Elastic rounds gate the coin AFTER drawing it: a non-participant (or any
    worker on a degraded step) must not refresh its snapshot — its (w_i,
    mu_i) freezes with the rest of its state — so the aggregation paths AND
    the coin with the scheduled participation mask
    (``repro.core.participation``, DESIGN.md §Elasticity).  Gating the
    drawn coin (rather than skipping the draw) keeps the PRNG schedule
    fixed-shape: the stream position of every later draw is independent of
    who participated, and the checksum verdict of a faulty wire payload
    never reaches the coin (it is drawn before the gather)."""
    return jax.random.bernoulli(jax.random.fold_in(worker_key, VR_FOLD), p)


def reference_coins(key: jax.Array, p: float, n_workers: int) -> jax.Array:
    """All workers' coins ``(n,)`` from the un-folded step key — the exact
    per-worker draws :func:`vr_coin` produces on the distributed path."""
    return jnp.stack([
        vr_coin(jax.random.fold_in(key, w), p) for w in range(n_workers)
    ])


def refresh(vr: VRState, coins: jax.Array, params, mu_candidate) -> VRState:
    """L-SVRG snapshot step: rows where ``coins`` is set take
    ``w_i <- params`` and ``mu_i <- mu_candidate_i``; others keep their state.

    ``coins`` is ``(n_local,)`` bool; ``params`` leaves are parameter-shaped
    (broadcast over the worker rows); ``mu_candidate`` leaves carry the
    worker dim ``(n_local, *shape)``.  A pure where-select, so the reference
    (n rows at once) and distributed (one local row) paths produce identical
    rows per worker.
    """

    def sel(new, old):
        c = coins.reshape(coins.shape + (1,) * (old.ndim - 1))
        return jnp.where(c, new.astype(old.dtype), old)

    snapshot = jax.tree_util.tree_map(
        lambda s, x: sel(jnp.broadcast_to(x[None], s.shape), s),
        vr.snapshot, params,
    )
    mu = jax.tree_util.tree_map(
        lambda m, g: sel(g.astype(jnp.float32), m), vr.mu, mu_candidate
    )
    return VRState(snapshot=snapshot, mu=mu)


def resolve_vr_p(vr_p: Optional[float], m: int) -> float:
    """The snapshot probability: an explicit override, else the paper's
    ``p = 1/m`` (``m`` = local finite-sum size; the trainer substitutes its
    per-worker batch size for the streaming case)."""
    if vr_p is not None:
        return float(vr_p)
    return 1.0 / max(int(m), 1)


class VarianceReducer:
    """Convenience facade: the snapshot probability bundled with the VR
    algebra, for callers that drive the layer directly rather than through
    ``CompressionConfig(vr=True)`` (the aggregation paths use the free
    functions — the probability there lives in the config)."""

    def __init__(self, p: float):
        if not 0.0 < p <= 1.0:
            raise ValueError(f"snapshot probability must be in (0, 1], got {p}")
        self.p = float(p)

    init = staticmethod(init_vr)
    control_variate = staticmethod(control_variate)
    refresh = staticmethod(refresh)

    def coin(self, worker_key: jax.Array) -> jax.Array:
        return vr_coin(worker_key, self.p)

    def coins(self, key: jax.Array, n_workers: int) -> jax.Array:
        return reference_coins(key, self.p, n_workers)

    @classmethod
    def for_finite_sum(cls, m: int, vr_p: Optional[float] = None) -> "VarianceReducer":
        return cls(resolve_vr_p(vr_p, m))
