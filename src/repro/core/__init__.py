"""Core DIANA library: quantization, packing, prox operators, compression policies."""

from .quantization import (
    QuantizedBlocks,
    alpha_p,
    lp_norm,
    quantize_blocks,
    dequantize_blocks,
    quantize_pytree,
    dequantize_pytree,
    expected_sparsity,
    quantization_variance,
)
from .packing import pack2bit, unpack2bit, packed_nbytes, PACK_FACTOR
from .compression import (
    CompressionConfig,
    compress_tree,
    decompress_tree,
    payload_bits_per_dim,
)
from .compressors import (
    Compressor,
    Payload,
    available_methods,
    make_compressor,
)
from .bucket import (
    BucketLayout,
    BucketedCompressor,
    GroupedBucketLayout,
    bucketed_compressor,
)
from .policy import (
    ChannelSpec,
    CompressionPolicy,
    Rule,
    as_policy,
    grouped_bucket_layout,
    load_policy,
    parse_rules,
    partition_for,
    policy_bits_per_dim,
)
from .participation import (
    PART_FOLD,
    ChurnEvent,
    FaultEvent,
    FaultPlan,
    ParticipationSpec,
    expected_rate,
    parse_faults,
    participation_mask,
    step_ctx,
)
from .vr import (
    VarianceReducer,
    VRState,
    control_variate,
    init_vr,
    refresh,
    resolve_vr_p,
    vr_coin,
)
from .diana import (
    DOWN_FOLD,
    GROUP_FOLD,
    DianaState,
    downlink_round,
    init_downlink,
    init_state,
    aggregate_shardmap,
    bucket_layout,
    reference_init,
    reference_step,
    tree_zeros_like,
)
from . import prox

__all__ = [
    "QuantizedBlocks", "alpha_p", "lp_norm", "quantize_blocks", "dequantize_blocks",
    "quantize_pytree", "dequantize_pytree", "expected_sparsity", "quantization_variance",
    "pack2bit", "unpack2bit", "packed_nbytes", "PACK_FACTOR",
    "CompressionConfig", "compress_tree", "decompress_tree", "payload_bits_per_dim",
    "ChannelSpec", "CompressionPolicy", "Rule", "as_policy", "parse_rules",
    "load_policy", "partition_for", "policy_bits_per_dim", "grouped_bucket_layout",
    "Compressor", "Payload", "available_methods", "make_compressor",
    "BucketLayout", "GroupedBucketLayout", "BucketedCompressor",
    "bucketed_compressor", "bucket_layout",
    "PART_FOLD", "ParticipationSpec", "ChurnEvent", "FaultPlan", "FaultEvent",
    "participation_mask", "step_ctx", "expected_rate", "parse_faults",
    "VarianceReducer", "VRState", "control_variate", "init_vr", "refresh",
    "resolve_vr_p", "vr_coin",
    "DianaState", "DOWN_FOLD", "GROUP_FOLD", "init_state", "init_downlink",
    "downlink_round",
    "aggregate_shardmap", "reference_init", "reference_step",
    "tree_zeros_like", "prox",
]
