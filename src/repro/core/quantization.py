"""p-quantization and block p-quantization operators (paper Def. 1 / Def. 2).

The operator transforms ``delta`` into a random ternary vector

    qhat_j = ||delta||_p * sign(delta_j) * xi_j,   xi_j ~ Be(|delta_j| / ||delta||_p)

It is unbiased (Lemma 2), has variance ``Psi(delta) = ||d||_1 ||d||_p - ||d||_2^2``
and expected sparsity ``E||qhat||_0 = ||d||_1 / ||d||_p`` (Theorem 1).

Everything here is pure jnp, shape-static and vmap/scan/pjit friendly.  The
internal representation of a quantized block is ``(signs, scale)`` where
``signs`` is an int8 tensor in {-1, 0, +1} and ``scale`` is the block's
``||.||_p`` norm — this is what gets bit-packed (2 bits/dim) for communication
(see :mod:`repro.core.packing`).
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "QuantizedBlocks",
    "alpha_p",
    "lp_norm",
    "quantize_blocks",
    "dequantize_blocks",
    "quantize_pytree",
    "dequantize_pytree",
    "expected_sparsity",
    "quantization_variance",
    "pad_axis_to_multiple",
    "pad_to_blocks",
    "num_blocks",
    "quantize_blocks_from_uniform",
    "uniform_from_bits",
]


def uniform_from_bits(bits: jax.Array) -> jax.Array:
    """uint32 -> uniform [0,1) f32 using the top 24 bits (TPU-friendly).

    THE one bits->uniform map of the repo: every stochastic operator draws
    ``jax.random.bits`` and feeds them through this function, and the Pallas
    kernels apply the identical shift/scale to their bits operand (or to
    ``pltpu.prng_random_bits`` on compiled TPU) — which is what makes the
    kernel routes bitwise-EQUAL to the pure-jnp fallbacks given the same
    bits, not merely equal in distribution.
    """
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    )


# ---------------------------------------------------------------------------
# alpha_p — the key geometric constant (Lemma 1)
# ---------------------------------------------------------------------------

def alpha_p(p: float, d: int) -> float:
    """``alpha_p(d) = inf_x ||x||_2^2 / (||x||_1 ||x||_p)`` (paper eq. 12).

    Closed forms (Lemma 1): ``alpha_1 = 1/d``, ``alpha_2 = 1/sqrt(d)``,
    ``alpha_inf = 2/(1+sqrt(d))``.  For other ``p`` we fall back to the valid
    lower bound ``d^{-(1 - 1/p)} * ...`` via interpolation; the three values the
    paper analyses are exact.
    """
    if d <= 0:
        raise ValueError(f"block size must be positive, got {d}")
    if d == 1:
        return 1.0
    if p == 1:
        return 1.0 / d
    if p == 2:
        return 1.0 / math.sqrt(d)
    if p == math.inf:
        return 2.0 / (1.0 + math.sqrt(d))
    # General p: ||x||_1 <= d^{1-1/p}||x||_p and ||x||_p <= ||x||_2 for p>=2 give
    # a valid lower bound; exactness only claimed for p in {1, 2, inf}.
    if p > 2:
        return 1.0 / (d ** (1.0 - 1.0 / p))
    raise ValueError(f"unsupported quantization norm power p={p}")


def lp_norm(x: jax.Array, p: float, axis=-1, keepdims: bool = False) -> jax.Array:
    """``||x||_p`` along ``axis`` with stable handling of p = inf."""
    if p == math.inf:
        return jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    if p == 2:
        return jnp.sqrt(jnp.sum(x * x, axis=axis, keepdims=keepdims))
    if p == 1:
        return jnp.sum(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.sum(jnp.abs(x) ** p, axis=axis, keepdims=keepdims) ** (1.0 / p)


# ---------------------------------------------------------------------------
# Block quantization
# ---------------------------------------------------------------------------

class QuantizedBlocks(NamedTuple):
    """Ternary representation of a block-quantized vector.

    signs:  int8  (num_blocks, block_size) in {-1, 0, +1}
    scales: f32   (num_blocks,)  — per-block ||.||_p norm

    The original (unpadded) length is NOT stored (it would become a traced
    pytree leaf under vmap/jit); pass ``shape`` to :func:`dequantize_blocks`.
    """

    signs: jax.Array
    scales: jax.Array


def num_blocks(d: int, block_size: int) -> int:
    return -(-d // block_size)


def pad_axis_to_multiple(x: jax.Array, multiple: int, axis: int = 0) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``.

    The ONE shared block-padding helper (used by :func:`pad_to_blocks` and the
    kernel wrappers in :mod:`repro.kernels`): implemented with ``concatenate``,
    not ``jnp.pad``, because the HLO Pad op RET_CHECKs in old XLA's SPMD
    partitioner inside partial-manual shard_map bodies (DESIGN.md §6) — the
    aggregation runs inside a shard_map whose worker axes are manual while the
    inner axes stay auto, and every op on that path must stay partitionable.
    Zero blocks quantize (and decode) to zero, so the padding is harmless.
    """
    n = x.shape[axis]
    pad = -n % multiple
    if pad:
        pad_shape = x.shape[:axis] + (pad,) + x.shape[axis + 1:]
        x = jnp.concatenate([x, jnp.zeros(pad_shape, x.dtype)], axis=axis)
    return x


def pad_to_blocks(x: jax.Array, block_size: int) -> jax.Array:
    """Flatten and zero-pad ``x`` to a (num_blocks, block_size) matrix."""
    flat = pad_axis_to_multiple(x.reshape(-1), block_size)
    return flat.reshape(-1, block_size)


def quantize_blocks_from_uniform(
    blocks: jax.Array, u: jax.Array, *, p: float
) -> QuantizedBlocks:
    """Block p-quantization of an (m, B) block matrix given the uniform draws.

    The PRNG-free body of :func:`quantize_blocks`, shared with the bucketed
    whole-model path (:mod:`repro.core.bucket`), which concatenates per-leaf
    uniform draws so ONE vectorized call reproduces the per-leaf quantization
    bitwise.
    """
    scales = lp_norm(blocks, p, axis=-1)             # (m,)
    safe = jnp.where(scales > 0, scales, 1.0)
    probs = jnp.abs(blocks) / safe[:, None]          # in [0, 1]
    xi = (u < probs).astype(jnp.int8)
    signs = jnp.sign(blocks).astype(jnp.int8) * xi
    scales = jnp.where(scales > 0, scales, 0.0).astype(jnp.float32)
    return QuantizedBlocks(signs=signs, scales=scales)


@partial(jax.jit, static_argnames=("p", "block_size"))
def quantize_blocks(
    x: jax.Array,
    key: jax.Array,
    *,
    p: float = math.inf,
    block_size: int = 1024,
) -> QuantizedBlocks:
    """Block p-quantization (Def. 2) of an arbitrary-shaped tensor.

    Zero blocks quantize to zero (Def. 1 handles ``delta = 0`` separately); the
    Bernoulli probabilities ``|x_j| / ||x(l)||_p`` are well-defined (<= 1) for
    every ``p >= 1``.
    """
    blocks = pad_to_blocks(x, block_size)            # (m, B)
    # Draw raw bits and derive the uniforms with the kernels' bits->uniform
    # map, so the pre-drawn-bits kernel route consumes the SAME stream and
    # produces bitwise-identical wire payloads (DESIGN.md §Kernels).
    bits = jax.random.bits(key, blocks.shape, dtype=jnp.uint32)
    return quantize_blocks_from_uniform(blocks, uniform_from_bits(bits), p=p)


def dequantize_blocks(q: QuantizedBlocks, shape=None, dtype=jnp.float32) -> jax.Array:
    """Reconstruct the dense (unbiased) estimate ``scale * signs``.

    ``shape`` (or its product) tells how many leading entries of the padded
    flat vector are real data; defaults to everything.
    """
    dense = q.signs.astype(dtype) * q.scales[:, None].astype(dtype)
    flat = dense.reshape(-1)
    if shape is not None:
        size = int(np_prod(shape))
        flat = flat[:size]
        return flat.reshape(shape)
    return flat


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# Pytree-level quantization (one leaf = one or more blocks)
# ---------------------------------------------------------------------------

def quantize_pytree(tree, key: jax.Array, *, p: float, block_size: int):
    """Quantize every leaf of a pytree with independent PRNG streams.

    Block boundaries never straddle leaves — this is the paper's "layers have
    different scales" motivation for bucketed quantization taken to its natural
    limit: blocks align with (slices of) parameter tensors.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    qs = [
        quantize_blocks(leaf, k, p=p, block_size=block_size)
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree_util.tree_unflatten(treedef, qs)


def dequantize_pytree(qtree, like):
    """Inverse of :func:`quantize_pytree` given the template pytree ``like``."""
    q_leaves = [
        x for x in jax.tree_util.tree_leaves(qtree, is_leaf=lambda t: isinstance(t, QuantizedBlocks))
    ]
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    outs = [
        dequantize_blocks(q, shape=l.shape, dtype=l.dtype)
        for q, l in zip(q_leaves, like_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, outs)


# ---------------------------------------------------------------------------
# Theory quantities (for tests / benchmarks)
# ---------------------------------------------------------------------------

def expected_sparsity(x: jax.Array, p: float, block_size: int) -> jax.Array:
    """Theorem 1: ``E ||qhat||_0 = sum_l ||x(l)||_1 / ||x(l)||_p``."""
    blocks = pad_to_blocks(x, block_size)
    n1 = lp_norm(blocks, 1, axis=-1)
    np_ = lp_norm(blocks, p, axis=-1)
    return jnp.sum(jnp.where(np_ > 0, n1 / jnp.where(np_ > 0, np_, 1.0), 0.0))


def quantization_variance(x: jax.Array, p: float, block_size: int) -> jax.Array:
    """Lemma 2: ``E||qhat - x||_2^2 = sum_l ||x(l)||_1 ||x(l)||_p - ||x(l)||_2^2``."""
    blocks = pad_to_blocks(x, block_size)
    n1 = lp_norm(blocks, 1, axis=-1)
    np_ = lp_norm(blocks, p, axis=-1)
    n2sq = jnp.sum(blocks * blocks, axis=-1)
    return jnp.sum(n1 * np_ - n2sq)
