"""Elastic participation: client sampling, stragglers, churn, fault injection.

DIANA's Algorithm 1 (and Thm. 1) assumes all ``n`` workers report every
step.  This module generalises the aggregation to a sampled participant set
``S_t`` while keeping the two properties the reproduction is built on:

* **Unbiased direction** — the server direction uses the RESCALED sum
  ``(1/|S_t|) * sum_{i in S_t} dhat_i`` (or the a-priori ``1/(n q)`` rule,
  :attr:`ParticipationSpec.rescale`), so ``E[ghat] = h + E[mean_i dhat_i]``
  exactly as in the all-workers round.
* **Memory correctness** — the server invariant ``h = mean_i h_i`` must
  survive sampling, so ``h_server`` advances with the UNRESCALED
  ``sum_{S_t} dhat_i / n`` (non-participants contribute an exact 0, and
  their ``h_i`` rows are frozen — see DESIGN.md §Elasticity).

PRNG contract (the :data:`PART_FOLD` stream): callers derive
``part_key = fold_in(step_key, PART_FOLD)`` from the step key BEFORE any
worker fold — like the downlink's DOWN_FOLD — and worker ``i``'s
participation draws come from ``split(fold_in(part_key, i), 3)``
(sampling coin, straggler coin, deadline latency).  Both the distributed
and the reference path draw the full ``(n,)`` mask from this stream, ONCE
per step and BEFORE any policy-group fold, so the mask is bitwise-shared
and never collides with a compression, VR or downlink draw.

Churn is a static schedule (:class:`ChurnEvent`): a worker that ``leave``s
at step ``s`` is absent from every mask at ``t >= s``; a ``join`` at step
``s`` makes it present again with its ``h_worker`` row re-initialised to
zero at exactly ``t == s`` (the paper's ``h_i^0 = 0`` choice for a fresh
worker).  Everything is traced against the scalar ``step``, so the program
stays a fixed-shape SPMD step for every mask outcome.

The fault-injection harness (:class:`FaultPlan`) perturbs the fused uint8
wire buffer of the bucketed layout per (step, worker): ``corrupt`` XORs a
payload byte, ``drop``/``delay`` invalidate the appended checksum
(:func:`repro.core.bucket.add_checksum`) so the receiver detects and
excludes the payload instead of letting corrupted bytes poison
``h_server``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PART_FOLD",
    "ChurnEvent",
    "ParticipationSpec",
    "PartCtx",
    "participation_mask",
    "reinit_rows",
    "direction_scale",
    "expected_rate",
    "step_ctx",
    "FaultEvent",
    "FaultPlan",
    "parse_faults",
    "apply_faults",
]

# Folded into the UN-worker-folded step key for the participation draws;
# disjoint from the compression schedule (worker folds then per-leaf splits),
# from VR_FOLD (applied to worker-folded keys), from DOWN_FOLD and from
# GROUP_FOLD (applied after the worker fold), so the mask stream is identical
# on every worker and never collides with any other draw.
PART_FOLD = 0x5041  # 'PA'


@dataclass(frozen=True)
class ChurnEvent:
    """One scheduled membership change: ``worker`` leaves or (re-)joins the
    cohort at ``step``.  A ``join`` re-initialises the worker's ``h_worker``
    row to zero at exactly that step (fresh-worker memory)."""

    step: int
    worker: int
    kind: str  # "leave" | "join"

    def __post_init__(self):
        if self.kind not in ("leave", "join"):
            raise ValueError(f"ChurnEvent kind must be leave|join, got {self.kind!r}")
        if self.step < 0 or self.worker < 0:
            raise ValueError("ChurnEvent step and worker must be >= 0")


@dataclass(frozen=True)
class ParticipationSpec:
    """Static description of WHO participates each step (hashable: lives on
    :class:`~repro.core.compression.CompressionConfig` /
    :class:`~repro.core.policy.CompressionPolicy` and in lru_cache keys).

    q:           client-sampling probability — each present worker joins
                 ``S_t`` with an independent Bernoulli(q) coin per step.
    dropout:     straggler probability — a sampled worker still fails to
                 report with this probability (independent coin).
    deadline:    timeout policy — each worker draws a latency ~ Exp(1) and
                 misses the deadline when ``latency > deadline``; ``None``
                 disables the timeout draw.
    churn:       static :class:`ChurnEvent` schedule (applied in step order).
    min_workers: below this many participants the step degrades gracefully:
                 ``ghat = 0`` (momentum ``v = beta*v`` carries), every memory
                 frozen — never a crash, never a shape change.
    rescale:     "sampled" divides the participant sum by ``|S_t|``
                 (self-normalised, unbiased conditional on ``|S_t|>0``);
                 "expected" divides by ``n * E[participation rate]`` (the
                 ``1/(nq)`` rule — unbiased a priori, higher variance).

    A trivial spec (``is_trivial``) keeps the aggregation on the exact
    pre-elastic code path, bit for bit.
    """

    q: float = 1.0
    dropout: float = 0.0
    deadline: Optional[float] = None
    churn: Tuple[ChurnEvent, ...] = ()
    min_workers: int = 1
    rescale: str = "sampled"

    def __post_init__(self):
        if not (0.0 < self.q <= 1.0):
            raise ValueError(f"participation q must be in (0, 1], got {self.q}")
        if not (0.0 <= self.dropout < 1.0):
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")
        if self.deadline is not None and self.deadline <= 0.0:
            raise ValueError(f"deadline must be positive, got {self.deadline}")
        if self.min_workers < 1:
            raise ValueError("min_workers must be >= 1")
        if self.rescale not in ("sampled", "expected"):
            raise ValueError(f"rescale must be sampled|expected, got {self.rescale}")
        object.__setattr__(
            self, "churn",
            tuple(sorted(self.churn, key=lambda e: (e.step, e.worker))))

    @property
    def is_trivial(self) -> bool:
        """True when every scheduled mask is all-workers — the aggregation
        then takes the exact pre-elastic code path (``min_workers`` is
        vacuous: ``|S_t| = n`` every step)."""
        return (self.q >= 1.0 and self.dropout == 0.0
                and self.deadline is None and not self.churn)

    # ------------------------------------------------------------- json
    def to_json_dict(self) -> dict:
        return {
            "q": self.q, "dropout": self.dropout, "deadline": self.deadline,
            "min_workers": self.min_workers, "rescale": self.rescale,
            "churn": [[e.step, e.worker, e.kind] for e in self.churn],
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> "ParticipationSpec":
        d = dict(d)
        d["churn"] = tuple(ChurnEvent(int(s), int(w), k)
                           for s, w, k in d.get("churn", ()))
        return cls(**d)


def presence(spec: ParticipationSpec, step, n: int) -> jax.Array:
    """(n,) bool — cohort membership at ``step`` under the churn schedule
    (all-present before any event; events applied in step order)."""
    pres = jnp.ones((n,), bool)
    step = jnp.asarray(step, jnp.int32)
    for ev in spec.churn:
        # elementwise one-hot select: no scatter/dynamic-slice, so the mask
        # partitions under manual subgroups on old XLA (DESIGN.md §6)
        hit = (jnp.arange(n) == ev.worker) & (step >= jnp.int32(ev.step))
        pres = jnp.where(hit, ev.kind == "join", pres)
    return pres


def reinit_rows(spec: ParticipationSpec, step, n: int) -> jax.Array:
    """(n,) bool — workers whose ``join`` fires at exactly ``step``: their
    ``h_worker`` row re-initialises to zero this step (before aggregation,
    and regardless of whether the step degrades)."""
    r = jnp.zeros((n,), bool)
    step = jnp.asarray(step, jnp.int32)
    for ev in spec.churn:
        if ev.kind == "join":
            r = r | ((jnp.arange(n) == ev.worker) & (step == jnp.int32(ev.step)))
    return r


def participation_mask(spec: ParticipationSpec, part_key: jax.Array,
                       n: int, step=0) -> jax.Array:
    """The (n,) participant mask ``S_t`` — the PART_FOLD stream contract.

    ``part_key`` must be ``fold_in(step_key, PART_FOLD)`` derived BEFORE any
    worker fold (identical on every worker); the same draws happen whichever
    knobs are active, so turning one on never perturbs another's stream.
    """
    bits = []
    for i in range(n):
        k_q, k_drop, k_lat = jax.random.split(jax.random.fold_in(part_key, i), 3)
        b = jax.random.bernoulli(k_q, spec.q)
        b = b & ~jax.random.bernoulli(k_drop, spec.dropout)
        if spec.deadline is not None:
            b = b & (jax.random.exponential(k_lat) <= spec.deadline)
        bits.append(b)
    return jnp.stack(bits) & presence(spec, step, n)


def expected_rate(spec: ParticipationSpec) -> float:
    """A-priori per-worker participation probability (ignoring churn):
    ``q * (1-dropout) * P[Exp(1) <= deadline]`` — the divisor of the
    "expected" rescale rule and the bench's effective-bits accounting."""
    rate = spec.q * (1.0 - spec.dropout)
    if spec.deadline is not None:
        rate *= 1.0 - math.exp(-spec.deadline)
    return rate


def direction_scale(spec: ParticipationSpec, mask: jax.Array,
                    ok: jax.Array) -> jax.Array:
    """Scalar f32 the participant SUM is multiplied by to form the server
    direction's mean — ``1/|S_t|`` (sampled) or ``1/(n * E[rate])``
    (expected); exactly 0 on a degraded step so ``ghat`` vanishes."""
    n = mask.shape[0]
    if spec.rescale == "expected":
        s = jnp.float32(1.0 / (n * expected_rate(spec)))
    else:
        count = jnp.sum(mask, dtype=jnp.int32)
        s = 1.0 / jnp.maximum(count, 1).astype(jnp.float32)
    return jnp.where(ok, s, jnp.float32(0.0))


class PartCtx(NamedTuple):
    """One step's resolved participation context, computed ONCE per step
    (before any policy-group fold) and shared by every aggregation group.

    ``m_own``/``reinit_own``/``widx`` are the calling worker's own bits,
    extracted with an elementwise one-hot reduce (never a dynamic slice) —
    ``None`` on the reference path, which indexes the (n,) rows directly.
    """

    spec: Any            # static ParticipationSpec
    mask: jax.Array      # (n,) bool — scheduled participants S_t
    reinit: jax.Array    # (n,) bool — h rows re-initialised this step
    ok: jax.Array        # ()  bool — |S_t| >= min_workers (degraded gate)
    dir_scale: jax.Array  # () f32 — multiplies the participant sum (0 if degraded)
    m_own: Any = None
    reinit_own: Any = None
    widx: Any = None


def step_ctx(spec: ParticipationSpec, part_key: jax.Array, n: int,
             step=0, worker_index=None) -> PartCtx:
    """Resolve one step's mask/reinit/degraded-gate/rescale from the
    PART_FOLD stream.  ``worker_index`` (the caller's linear worker index)
    populates the ``*_own`` bits on the distributed path."""
    mask = participation_mask(spec, part_key, n, step)
    reinit = reinit_rows(spec, step, n)
    ok = jnp.sum(mask, dtype=jnp.int32) >= jnp.int32(spec.min_workers)
    scale = direction_scale(spec, mask, ok)
    m_own = reinit_own = widx = None
    if worker_index is not None:
        widx = jnp.asarray(worker_index, jnp.int32)
        sel = jnp.arange(n) == widx
        m_own = jnp.any(mask & sel)
        reinit_own = jnp.any(reinit & sel)
    return PartCtx(spec=spec, mask=mask, reinit=reinit, ok=ok,
                   dir_scale=scale, m_own=m_own, reinit_own=reinit_own,
                   widx=widx)


# ---------------------------------------------------------------------------
# Fault injection: perturb the fused uint8 wire buffer per (step, worker)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One scheduled wire fault for ``worker`` at ``step``.

    kind="corrupt": XOR ``bits`` into payload byte ``byte`` — the checksum
    then fails on every receiver and the payload is excluded from the sum.
    kind="drop":    invalidate the checksum outright (the payload never
    arrives); kind="delay" is a drop lasting ``delay`` consecutive steps.
    """

    step: int
    worker: int
    kind: str = "corrupt"  # "corrupt" | "drop" | "delay"
    byte: int = 0
    bits: int = 0xFF
    delay: int = 1

    def __post_init__(self):
        if self.kind not in ("corrupt", "drop", "delay"):
            raise ValueError(f"FaultEvent kind must be corrupt|drop|delay, "
                             f"got {self.kind!r}")
        if self.kind == "corrupt" and not (1 <= self.bits <= 0xFF):
            raise ValueError("corrupt bits must be a non-zero byte")
        if self.kind == "delay" and self.delay < 1:
            raise ValueError("delay must be >= 1 steps")


@dataclass(frozen=True)
class FaultPlan:
    """Static fault schedule.  Passing ANY plan (even an empty one) turns the
    wire checksum on: the bucketed round always fuses the payload into one
    uint8 buffer, appends the 8-byte checksum
    (:func:`repro.core.bucket.add_checksum`) and excludes payloads whose
    checksum fails verification on the receivers."""

    events: Tuple[FaultEvent, ...] = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))


def parse_faults(text: Optional[str]) -> Optional[FaultPlan]:
    """CLI fault syntax -> :class:`FaultPlan` (``None`` passes through).

    Events separated by ';', each ``kind:key=value,...`` — e.g.
    ``corrupt:step=3,worker=1,byte=7;drop:step=5,worker=2`` or
    ``delay:step=6,worker=0,delay=2``.  The bare word ``checksum`` yields an
    empty plan (checksums on, no injected faults).
    """
    if text is None or not text.strip():
        return None
    if text.strip() == "checksum":
        return FaultPlan()
    events = []
    for part in text.split(";"):
        part = part.strip()
        if not part:
            continue
        kind, _, rest = part.partition(":")
        kw = {}
        for item in rest.split(","):
            item = item.strip()
            if not item:
                continue
            k, _, v = item.partition("=")
            kw[k.strip()] = int(v, 0)
        events.append(FaultEvent(kind=kind.strip(), **kw))
    return FaultPlan(events=tuple(events))


def apply_faults(wire: jax.Array, plan: FaultPlan, step, widx,
                 byte_offset: int = 0,
                 body_total: Optional[int] = None) -> jax.Array:
    """Inject ``plan``'s faults into THIS worker's 1-D wire buffer
    ``(payload bytes + checksum tail)`` for the traced ``(step, widx)``.

    Pure elementwise XOR against constant one-hot byte masks (fixed shape,
    no scatter), so the program is identical whether or not a fault fires.

    With the CHUNKED wire (repro.core.bucket.ChunkedSchedule) each chunk is
    its own checksummed wire object; the caller then passes this chunk's
    ``byte_offset`` into the concatenated payload body and the round's
    ``body_total`` (sum of every chunk's body bytes).  A ``corrupt`` event's
    ``byte % body_total`` addresses the concatenated body, so it lands in
    exactly ONE chunk — the same one-flipped-byte-per-round outcome as the
    monolithic wire; ``drop``/``delay`` break EVERY chunk's tail (the whole
    payload is late/lost, not one slice of it).  The defaults reproduce the
    single-wire behaviour byte for byte.
    """
    from .bucket import CHECKSUM_BYTES

    step = jnp.asarray(step, jnp.int32)
    widx = jnp.asarray(widx, jnp.int32)
    total = wire.shape[-1]
    own_body = total - CHECKSUM_BYTES
    body = own_body if body_total is None else body_total
    for ev in plan.events:
        mine = widx == jnp.int32(ev.worker)
        if ev.kind == "delay":
            hit = mine & (step >= jnp.int32(ev.step)) \
                       & (step < jnp.int32(ev.step + ev.delay))
        else:
            hit = mine & (step == jnp.int32(ev.step))
        flip = np.zeros((total,), np.uint8)
        if ev.kind == "corrupt":
            local = ev.byte % body - byte_offset
            if 0 <= local < own_body:
                flip[local] = ev.bits
            else:
                continue  # this event addresses another chunk's bytes
        else:  # drop / delay: break the checksum tail
            flip[total - 1] = 0xFF
        wire = wire ^ jnp.where(hit, jnp.asarray(flip), jnp.uint8(0))
    return wire
