"""Compression configuration — a thin, serializable factory over the
compressor registry.

The actual operators live in :mod:`repro.core.compressors`; this module keeps
the flat dataclass surface the configs / CLI / checkpoints use, resolves a
``method`` string (canonical name or legacy alias) through the registry, and
preserves the historic helper API (``compress_tree`` / ``decompress_tree`` /
``payload_bits_per_dim``) as thin delegations.

Legacy method strings remain first-class aliases: ``diana`` / ``qsgd`` /
``terngrad`` / ``dqgd`` / ``none`` are exactly the paper's Algorithm 1 /
Algorithm 2 special cases (Sec. 3 "Relation to QSGD and TernGrad"), now
expressed as registry entries over the ternary/identity operators.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .compressors import Payload, make_compressor
from .compressors.registry import available_methods, canonical_name
from .compressors.ternary import TernaryCompressor
from .quantization import QuantizedBlocks, alpha_p
from .packing import unpack2bit

__all__ = [
    "CompressionConfig",
    "compress_tree",
    "decompress_tree",
    "payload_bits_per_dim",
]


@dataclass(frozen=True)
class CompressionConfig:
    """Configuration of the gradient-communication compressor.

    method:      any registered compressor name or alias (see
                 :mod:`repro.core.compressors.registry`): ternary | natural |
                 randk | topk_ef | identity, or the legacy diana | qsgd |
                 terngrad | dqgd | none.
    p:           quantization norm power for the ternary family (2.0 or
                 math.inf analysed by the paper).
    block_size:  bucket size d_l for block quantization (Def. 2). Paper
                 guidance: blocks of size ~ n^2 match uncompressed SGD
                 iteration complexity.
    alpha:       memory learning rate override. None -> the operator's theory
                 default (ternary: alpha_p/2 per Cor. 1; natural: 8/9;
                 rand-k: k/d).
    k:           coordinates kept per parameter leaf by the sparsifying
                 operators (rand-k / top-k).
    h_dtype:     dtype of the DIANA memory h_i (f32 default; bf16 >10B).
    worker_axes: mesh axes whose product forms the DIANA worker set.
    use_kernel:  Pallas-kernel capability for kernel-backed operators.
                 None = auto (kernels on TPU, pure-jnp elsewhere).
    bucketed:    aggregate the whole model as ONE flat buffer (one compress,
                 one all-gather, one decode_sum per step — repro.core.bucket)
                 instead of per-leaf.  Bitwise-equal results either way; the
                 flag only selects the execution layout.
    vr:          VR-DIANA (arXiv:1904.05115): layer a per-worker L-SVRG
                 control variate under the compressed-difference loop
                 (repro.core.vr).  Orthogonal to the operator and the layout
                 — every registry compressor composes with it unchanged.
    vr_p:        L-SVRG snapshot-refresh probability.  None = the paper's
                 ``1/m`` default, resolved by the caller who knows the local
                 finite-sum size (repro.core.vr.resolve_vr_p); must be
                 concrete by aggregation time.
    """

    method: str = "diana"
    p: float = math.inf
    block_size: int = 2048
    alpha: Optional[float] = None
    k: int = 64
    h_dtype: Any = jnp.float32
    worker_axes: tuple = ("pod", "data")
    use_kernel: Optional[bool] = None
    bucketed: bool = False
    vr: bool = False
    vr_p: Optional[float] = None

    def __post_init__(self):
        canonical_name(self.method)  # raises on unknown methods
        if self.block_size % 4:
            raise ValueError("block_size must be a multiple of 4 for 2-bit packing")
        if self.vr_p is not None and not 0.0 < self.vr_p <= 1.0:
            raise ValueError(f"vr_p must be in (0, 1], got {self.vr_p}")

    # ------------------------------------------------------------- factory

    def make(self):
        """Build (memoized) the configured
        :class:`~repro.core.compressors.Compressor`.

        ``make()`` is called on every traced step (``_aggregate_local`` and
        ``aggregate_shardmap``, plus the reference path), so instances are
        cached per config — the dataclass is frozen/hashable and compressors
        are stateless, which makes sharing safe.  The ``use_kernel=None``
        backend auto-detection is resolved once per process, which is the
        intended semantics (the backend cannot change under a live process).
        """
        return _make_cached(self)

    # ----------------------------------------------- legacy introspection

    @property
    def uses_memory(self) -> bool:
        """Whether worker memories h_i are live state for this operator."""
        return self.make().carries_state

    @property
    def quantizes(self) -> bool:
        return canonical_name(self.method) != "identity"

    def effective_p(self) -> float:
        comp = self.make()
        return comp.p if isinstance(comp, TernaryCompressor) else self.p

    def effective_alpha(self) -> float:
        """The operator's memory rate (0 for memoryless); sparse operators
        resolve their per-leaf d at use time, this is the d-free default."""
        return self.make().memory_alpha()

    def theory_alpha_p(self) -> float:
        """alpha_p(d~) of the largest block — drives every rate in the paper."""
        return alpha_p(self.effective_p(), self.block_size)


@functools.lru_cache(maxsize=None)
def _make_cached(cfg: "CompressionConfig"):
    return make_compressor(cfg)


# ---------------------------------------------------------------------------
# Tree-level helpers over the compressor interface
# ---------------------------------------------------------------------------

def compress_tree(tree, key, cfg: CompressionConfig):
    """Compress a gradient(-difference) pytree leaf-by-leaf.

    Returns ``(payload_tree, local_tree)``: ``payload_tree`` has one
    :class:`Payload` per leaf (the communicated wire format);
    ``local_tree`` is the worker's own decode-ready representation —
    :class:`QuantizedBlocks` for the ternary family (back-compat with the
    sparsity/variance benchmarks), the payload itself otherwise.
    """
    comp = cfg.make()
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    payloads, locals_ = [], []
    for leaf, k in zip(leaves, keys):
        pay = comp.compress(leaf.reshape(-1).astype(jnp.float32), k)
        payloads.append(pay)
        if isinstance(comp, TernaryCompressor):
            locals_.append(QuantizedBlocks(signs=unpack2bit(pay.packed), scales=pay.scales))
        else:
            locals_.append(pay)
    return (
        jax.tree_util.tree_unflatten(treedef, payloads),
        jax.tree_util.tree_unflatten(treedef, locals_),
    )


def decompress_tree(payload, like, cfg: CompressionConfig):
    """Decode a payload pytree back to dense leaves shaped like ``like``."""
    comp = cfg.make()
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    pay_leaves = jax.tree_util.tree_leaves(
        payload, is_leaf=lambda t: isinstance(t, Payload)
    )
    outs = [
        comp.decode(pay, l.size).astype(l.dtype).reshape(l.shape)
        for pay, l in zip(pay_leaves, like_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, outs)


def payload_bits_per_dim(cfg: CompressionConfig, d: Optional[int] = None) -> float:
    """Communication cost per coordinate of the configured operator (``d`` is
    required for honest accounting of the sparse index+value payloads)."""
    return cfg.make().bits_per_dim(d)
