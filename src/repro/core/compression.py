"""Compression policies: DIANA, QSGD, TernGrad, DQGD, none.

A policy decides *what* is quantized (gradient vs gradient difference) and how
the worker memory evolves.  QSGD / TernGrad / DQGD are exactly the paper's
Algorithm 2 special cases (alpha = 0, h = 0) with p = 2 / p = inf respectively;
DQGD compresses the gradient directly with memory disabled as in Khirirat et
al. 2018.  This unification mirrors Sec. 3 "Relation to QSGD and TernGrad".
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .quantization import (
    QuantizedBlocks,
    alpha_p,
    dequantize_pytree,
    quantize_pytree,
)
from .packing import pack2bit, unpack2bit

__all__ = ["CompressionConfig", "compress_tree", "decompress_tree", "payload_bits_per_dim"]

_METHODS = ("diana", "qsgd", "terngrad", "dqgd", "none")


@dataclass(frozen=True)
class CompressionConfig:
    """Configuration of the gradient-communication compressor.

    method:      one of diana | qsgd | terngrad | dqgd | none
    p:           quantization norm power (2.0 or math.inf analysed by the paper)
    block_size:  bucket size d_l for block quantization (Def. 2). Paper guidance:
                 blocks of size ~ n^2 match uncompressed SGD iteration complexity.
    alpha:       memory learning rate. None -> theory default alpha_p/2 (Cor. 1);
                 the experiments' practical choice is 1/sqrt(block_size).
    h_dtype:     dtype of the DIANA memory h_i (f32 default; bf16 for >10B models)
    worker_axes: mesh axes whose product forms the DIANA worker set. ('pod','data')
                 = paper-faithful every-slice-a-worker; ('pod',) = hierarchical
                 beyond-paper mode (psum inside pod, compress across pods).
    """

    method: str = "diana"
    p: float = math.inf
    block_size: int = 2048
    alpha: Optional[float] = None
    h_dtype: Any = jnp.float32
    worker_axes: tuple = ("pod", "data")
    use_kernel: bool = False  # route quantize+pack through the Pallas kernel

    def __post_init__(self):
        if self.method not in _METHODS:
            raise ValueError(f"unknown compression method {self.method!r}; choose from {_METHODS}")
        if self.block_size % 4:
            raise ValueError("block_size must be a multiple of 4 for 2-bit packing")

    @property
    def uses_memory(self) -> bool:
        return self.method == "diana"

    @property
    def quantizes(self) -> bool:
        return self.method != "none"

    def effective_p(self) -> float:
        if self.method == "qsgd":
            return 2.0
        if self.method == "terngrad":
            return math.inf
        return self.p

    def effective_alpha(self) -> float:
        if not self.uses_memory:
            return 0.0
        if self.alpha is not None:
            return self.alpha
        return alpha_p(self.effective_p(), self.block_size) / 2.0  # Corollary 1

    def theory_alpha_p(self) -> float:
        """alpha_p(d~) of the largest block — drives every rate in the paper."""
        return alpha_p(self.effective_p(), self.block_size)


# ---------------------------------------------------------------------------
# Tree-level compress/decompress with packed payloads
# ---------------------------------------------------------------------------

def compress_tree(tree, key, cfg: CompressionConfig):
    """Quantize a gradient(-difference) pytree into a packed payload.

    Returns ``(payload, qtree)`` where ``payload`` is the communicated pytree of
    ``{"packed": uint8, "scales": f32}`` dicts and ``qtree`` the local ternary
    representation (for the worker's own h update without a second unpack).
    """
    if cfg.use_kernel:
        from repro.kernels import ops as _kops

        return _kops.compress_tree_kernel(tree, key, cfg)
    qtree = quantize_pytree(tree, key, p=cfg.effective_p(), block_size=cfg.block_size)
    payload = jax.tree_util.tree_map(
        lambda q: {"packed": pack2bit(q.signs), "scales": q.scales},
        qtree,
        is_leaf=lambda t: isinstance(t, QuantizedBlocks),
    )
    return payload, qtree


def decompress_tree(payload, like, cfg: CompressionConfig):
    """Unpack a payload pytree back to dense leaves shaped like ``like``."""
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    pay_leaves = [
        p for p in jax.tree_util.tree_leaves(
            payload, is_leaf=lambda t: isinstance(t, dict) and "packed" in t
        )
    ]
    outs = []
    for pay, l in zip(pay_leaves, like_leaves):
        signs = unpack2bit(pay["packed"])                       # (m, B)
        dense = signs.astype(l.dtype) * pay["scales"][:, None].astype(l.dtype)
        outs.append(dense.reshape(-1)[: l.size].reshape(l.shape))
    return jax.tree_util.tree_unflatten(treedef, outs)


def payload_bits_per_dim(cfg: CompressionConfig) -> float:
    """Communication cost per coordinate: 2 bits + per-block f32 scale."""
    if not cfg.quantizes:
        return 32.0
    return 2.0 + 32.0 / cfg.block_size
