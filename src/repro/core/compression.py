"""Compression configuration — a thin, serializable factory over the
compressor registry.

The actual operators live in :mod:`repro.core.compressors`; this module keeps
the flat dataclass surface the configs / CLI / checkpoints use, resolves a
``method`` string (canonical name or legacy alias) through the registry, and
preserves the historic helper API (``compress_tree`` / ``decompress_tree`` /
``payload_bits_per_dim``) as thin delegations.

Legacy method strings remain first-class aliases: ``diana`` / ``qsgd`` /
``terngrad`` / ``dqgd`` / ``none`` are exactly the paper's Algorithm 1 /
Algorithm 2 special cases (Sec. 3 "Relation to QSGD and TernGrad"), now
expressed as registry entries over the ternary/identity operators.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .compressors import Payload, make_compressor
from .compressors.registry import available_methods, canonical_name
from .compressors.ternary import TernaryCompressor
from .participation import ParticipationSpec
from .quantization import QuantizedBlocks, alpha_p
from .packing import unpack2bit

__all__ = [
    "CompressionConfig",
    "compress_tree",
    "decompress_tree",
    "payload_bits_per_dim",
]


@dataclass(frozen=True)
class CompressionConfig:
    """Configuration of the gradient-communication compressor.

    method:      any registered compressor name or alias (see
                 :mod:`repro.core.compressors.registry`): ternary | natural |
                 randk | topk_ef | identity, or the legacy diana | qsgd |
                 terngrad | dqgd | none.
    p:           quantization norm power for the ternary family (2.0 or
                 math.inf analysed by the paper).
    block_size:  bucket size d_l for block quantization (Def. 2). Paper
                 guidance: blocks of size ~ n^2 match uncompressed SGD
                 iteration complexity.
    alpha:       memory learning rate override. None -> the operator's theory
                 default (ternary: alpha_p/2 per Cor. 1; natural: 8/9;
                 rand-k: k/d).
    k:           coordinates kept per parameter leaf by the sparsifying
                 operators (rand-k / top-k).
    h_dtype:     dtype of the DIANA memory h_i (f32 default; bf16 >10B).
    worker_axes: mesh axes whose product forms the DIANA worker set.
    use_kernel:  Pallas-kernel capability for kernel-backed operators.
                 None = auto (kernels on TPU, pure-jnp elsewhere).
    bucketed:    aggregate the whole model as ONE flat buffer (one compress,
                 one all-gather, one decode_sum per step — repro.core.bucket)
                 instead of per-leaf.  Bitwise-equal results either way; the
                 flag only selects the execution layout.
    vr:          VR-DIANA (arXiv:1904.05115): layer a per-worker L-SVRG
                 control variate under the compressed-difference loop
                 (repro.core.vr).  Orthogonal to the operator and the layout
                 — every registry compressor composes with it unchanged.
    vr_p:        L-SVRG snapshot-refresh probability.  None = the paper's
                 ``1/m`` default, resolved by the caller who knows the local
                 finite-sum size (repro.core.vr.resolve_vr_p); must be
                 concrete by aggregation time.
    down_method: downlink (server -> worker) compressor for the aggregated
                 direction ``ghat`` — any registry name/alias, with its own
                 memory ``h_down`` (DESIGN.md §Bidirectional).  ``None``
                 (default) keeps the broadcast full-precision and the state
                 layout byte-identical to a uplink-only config.
    down_k:      kept coordinates for a sparse downlink operator.  ``None``
                 inherits ``k``.
    down_bucketed: downlink layout — ``True`` compresses ghat as ONE flat
                 buffer in the downlink operator's own BucketLayout, ``False``
                 per leaf.  ``None`` (default) follows ``bucketed``.
    participation: elastic-participation spec
                 (:class:`~repro.core.participation.ParticipationSpec`):
                 client sampling, straggler dropout, churn and the degraded
                 -step floor (DESIGN.md §Elasticity).  ``None`` or a trivial
                 spec keeps the round on the exact pre-elastic code path.
                 A frozen dataclass, so the config stays hashable.
    chunk_bytes: target size (bytes of padded f32 buffer) of each chunk of
                 the bucketed wire (:class:`~repro.core.bucket.ChunkedSchedule`)
                 — chunk *i+1*'s collective is issued before chunk *i*'s
                 decode so the gather overlaps the decode.  ``0`` (default)
                 keeps the monolithic single-chunk wire.  Bitwise-equal
                 results either way (DESIGN.md §Topology); bucketed only.
    topology:    ``"flat"`` (default) — every worker exchanges compressed
                 payloads directly; ``"hierarchical"`` — Bagua-style
                 two-level rounds: an uncompressed intra-node mean over
                 ``node_size``-worker groups, then the compressed DIANA
                 exchange between node leaders, whose h-memories are kept per
                 node so ``h == mean(h_i)`` holds exactly (DESIGN.md
                 §Topology).  Bucketed only.
    node_size:   workers per node for ``topology="hierarchical"`` (must
                 divide the worker count).  ``1`` degenerates to flat.
    """

    method: str = "diana"
    p: float = math.inf
    block_size: int = 2048
    alpha: Optional[float] = None
    k: int = 64
    h_dtype: Any = jnp.float32
    worker_axes: tuple = ("pod", "data")
    use_kernel: Optional[bool] = None
    bucketed: bool = False
    vr: bool = False
    vr_p: Optional[float] = None
    down_method: Optional[str] = None
    down_k: Optional[int] = None
    down_bucketed: Optional[bool] = None
    participation: Optional[ParticipationSpec] = None
    chunk_bytes: int = 0
    topology: str = "flat"
    node_size: int = 1

    def __post_init__(self):
        canonical_name(self.method)  # raises on unknown methods
        if self.down_method is not None:
            canonical_name(self.down_method)
        if self.block_size % 4:
            raise ValueError("block_size must be a multiple of 4 for 2-bit packing")
        if self.vr_p is not None and not 0.0 < self.vr_p <= 1.0:
            raise ValueError(f"vr_p must be in (0, 1], got {self.vr_p}")
        if self.participation is not None and not isinstance(
            self.participation, ParticipationSpec
        ):
            raise TypeError("participation must be a ParticipationSpec")
        if self.chunk_bytes < 0:
            raise ValueError(f"chunk_bytes must be >= 0, got {self.chunk_bytes}")
        if self.topology not in ("flat", "hierarchical"):
            raise ValueError(
                f"topology must be 'flat' or 'hierarchical', got {self.topology!r}")
        if self.node_size < 1:
            raise ValueError(f"node_size must be >= 1, got {self.node_size}")
        if self.topology == "hierarchical" and not self.bucketed:
            raise ValueError("topology='hierarchical' requires bucketed=True "
                             "(the two-level round runs on the fused wire)")

    # ------------------------------------------------------------- factory

    def make(self):
        """Build (memoized) the configured
        :class:`~repro.core.compressors.Compressor`.

        ``make()`` is called on every traced step (``_aggregate_local`` and
        ``aggregate_shardmap``, plus the reference path), so instances are
        cached per config — the dataclass is frozen/hashable and compressors
        are stateless, which makes sharing safe.  The ``use_kernel=None``
        backend auto-detection is resolved once per process, which is the
        intended semantics (the backend cannot change under a live process).
        """
        return _make_cached(self)

    def down_config(self) -> Optional["CompressionConfig"]:
        """The derived config of the DOWNLINK operator, or ``None``.

        The downlink is the same registry surface pointed at the server
        direction: ``down_method`` resolves through the identical factory,
        ``down_k``/``down_bucketed`` default to the uplink's ``k``/layout,
        and VR never applies (it is a worker-side estimator transform).  The
        derived config is a plain frozen dataclass, so ``make()`` memoization
        and the bucketed-compressor cache work on it unchanged.
        """
        if self.down_method is None:
            return None
        from dataclasses import replace

        return replace(
            self,
            method=self.down_method,
            k=self.k if self.down_k is None else self.down_k,
            bucketed=self.bucketed if self.down_bucketed is None else self.down_bucketed,
            down_method=None,
            down_k=None,
            down_bucketed=None,
            vr=False,
            vr_p=None,
            # The broadcast is replicated determinism, not a sampled sum —
            # elasticity acts on the uplink round (and freezes h_down on
            # degraded steps at the caller), never on the downlink operator.
            participation=None,
            # No collective on the downlink either: topology is an uplink
            # concern.  chunk_bytes is inherited — the broadcast wire chunks
            # the same way the uplink wire does.
            topology="flat",
            node_size=1,
        )

    @property
    def bidirectional(self) -> bool:
        """Whether the server broadcast is compressed too."""
        return self.down_method is not None

    # ----------------------------------------------- legacy introspection

    @property
    def uses_memory(self) -> bool:
        """Whether worker memories h_i are live state for this operator."""
        return self.make().carries_state

    @property
    def quantizes(self) -> bool:
        return canonical_name(self.method) != "identity"

    def effective_p(self) -> float:
        comp = self.make()
        return comp.p if isinstance(comp, TernaryCompressor) else self.p

    def effective_alpha(self) -> float:
        """The operator's memory rate (0 for memoryless); sparse operators
        resolve their per-leaf d at use time, this is the d-free default."""
        return self.make().memory_alpha()

    def theory_alpha_p(self) -> float:
        """alpha_p(d~) of the largest block — drives every rate in the paper."""
        return alpha_p(self.effective_p(), self.block_size)


@functools.lru_cache(maxsize=None)
def _make_cached(cfg: "CompressionConfig"):
    return make_compressor(cfg)


# ---------------------------------------------------------------------------
# Tree-level helpers over the compressor interface
# ---------------------------------------------------------------------------

def compress_tree(tree, key, cfg: CompressionConfig):
    """Compress a gradient(-difference) pytree leaf-by-leaf.

    Returns ``(payload_tree, local_tree)``: ``payload_tree`` has one
    :class:`Payload` per leaf (the communicated wire format);
    ``local_tree`` is the worker's own decode-ready representation —
    :class:`QuantizedBlocks` for the ternary family (back-compat with the
    sparsity/variance benchmarks), the payload itself otherwise.
    """
    comp = cfg.make()
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    payloads, locals_ = [], []
    for leaf, k in zip(leaves, keys):
        pay = comp.compress(leaf.reshape(-1).astype(jnp.float32), k)
        payloads.append(pay)
        if isinstance(comp, TernaryCompressor):
            locals_.append(QuantizedBlocks(signs=unpack2bit(pay.packed), scales=pay.scales))
        else:
            locals_.append(pay)
    return (
        jax.tree_util.tree_unflatten(treedef, payloads),
        jax.tree_util.tree_unflatten(treedef, locals_),
    )


def decompress_tree(payload, like, cfg: CompressionConfig):
    """Decode a payload pytree back to dense leaves shaped like ``like``."""
    comp = cfg.make()
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    pay_leaves = jax.tree_util.tree_leaves(
        payload, is_leaf=lambda t: isinstance(t, Payload)
    )
    outs = [
        comp.decode(pay, l.size).astype(l.dtype).reshape(l.shape)
        for pay, l in zip(pay_leaves, like_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, outs)


def payload_bits_per_dim(cfg: CompressionConfig, d: Optional[int] = None) -> float:
    """Communication cost per coordinate of the configured operator (``d`` is
    required for honest accounting of the sparse index+value payloads).

    Per-DIRECTION accounting (uplink payload + downlink broadcast, with
    size-weighted per-leaf costs) lives in
    ``benchmarks/bench_step_time.py::_direction_bits`` — it needs the model's
    :class:`~repro.core.bucket.BucketLayout`, which a bare config cannot
    provide.
    """
    return cfg.make().bits_per_dim(d)
