"""Proximal operators for the regularizers R the paper supports.

DIANA's iterate is ``x^{k+1} = prox_{gamma R}(x^k - gamma v^k)`` (Alg. 1 line 9)
for an arbitrary proper closed convex R — this is what QSGD/TernGrad cannot do
(their quantization noise does not vanish, so prox steps oscillate).

All operators are closed-form, elementwise, pytree-mapped.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Regularizer",
    "none",
    "l1",
    "l2",
    "elastic_net",
    "box_indicator",
    "nonneg_indicator",
]


@dataclass(frozen=True)
class Regularizer:
    """A regularizer given by its value and proximal operator.

    ``prox(u, gamma)`` solves ``argmin_v gamma*R(v) + 0.5*||v-u||^2`` per leaf.
    """

    name: str
    value: Callable[[jax.Array], jax.Array]
    prox: Callable[[jax.Array, float], jax.Array]

    def tree_value(self, tree) -> jax.Array:
        return sum(jnp.sum(self.value(leaf)) for leaf in jax.tree_util.tree_leaves(tree))

    def tree_prox(self, tree, gamma):
        return jax.tree_util.tree_map(lambda u: self.prox(u, gamma), tree)


def none() -> Regularizer:
    return Regularizer("none", value=lambda x: jnp.zeros_like(x), prox=lambda u, g: u)


def l1(lam: float) -> Regularizer:
    """R(x) = lam * ||x||_1; prox = soft-thresholding."""

    def _prox(u, gamma):
        t = gamma * lam
        return jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)

    return Regularizer("l1", value=lambda x: lam * jnp.abs(x), prox=_prox)


def l2(lam: float) -> Regularizer:
    """R(x) = (lam/2) * ||x||_2^2; prox = shrinkage u / (1 + gamma*lam)."""

    def _prox(u, gamma):
        return u / (1.0 + gamma * lam)

    return Regularizer("l2", value=lambda x: 0.5 * lam * x * x, prox=_prox)


def elastic_net(lam1: float, lam2: float) -> Regularizer:
    """R(x) = lam1*||x||_1 + (lam2/2)*||x||_2^2."""

    def _prox(u, gamma):
        soft = jnp.sign(u) * jnp.maximum(jnp.abs(u) - gamma * lam1, 0.0)
        return soft / (1.0 + gamma * lam2)

    return Regularizer(
        "elastic_net",
        value=lambda x: lam1 * jnp.abs(x) + 0.5 * lam2 * x * x,
        prox=_prox,
    )


def box_indicator(lo: float, hi: float) -> Regularizer:
    """Indicator of the box [lo, hi]^d — the paper's 'indicator-like' R
    (nonconvex analysis assumes R constant on its domain). prox = projection."""

    def _value(x):
        inside = jnp.logical_and(x >= lo, x <= hi)
        return jnp.where(inside, 0.0, jnp.inf)

    return Regularizer("box", value=_value, prox=lambda u, g: jnp.clip(u, lo, hi))


def nonneg_indicator() -> Regularizer:
    return Regularizer(
        "nonneg",
        value=lambda x: jnp.where(x >= 0, 0.0, jnp.inf),
        prox=lambda u, g: jnp.maximum(u, 0.0),
    )
