"""Compression policies: per-parameter-group operator rules.

The paper's block-quantization analysis (Def. 2 and the block-size theorem)
ties the quantization variance — and therefore every rate — to ``alpha_p(d_l)``
of each BLOCK, not of the whole model; Horváth et al. (arXiv:1904.05115)
likewise state their rates per operator.  Nothing in the theory requires every
parameter leaf to share one compressor, and the interesting regimes are
heterogeneous: keep layernorms/biases exact, top-k the embedding tables,
ternary-quantize the dense bulk, pick a different broadcast operator per group.

Two first-class objects express that:

* :class:`ChannelSpec` — ONE direction's operator for one group of leaves:
  ``method`` plus its knobs (``k``, ``block_size``, ``p``, ``alpha``) and the
  execution ``layout`` (``"bucketed"`` = the group aggregates as one fused
  flat buffer, ``"perleaf"``, or ``None`` = the policy default).  Unset knobs
  inherit the flat-config defaults — and, for a downlink spec, the uplink
  spec's values first (the legacy ``down_k``-inherits-``k`` semantics).

* :class:`CompressionPolicy` — an ORDERED list of :class:`Rule`\\ s mapping
  pytree path patterns (``re.search`` over ``/``-joined key paths) to specs,
  first match wins, plus the model-wide knobs that cannot vary per group
  (``h_dtype``, ``worker_axes``, ``use_kernel``, the default layout, and the
  VR switch — VR is a worker-side estimator transform applied before any
  grouping).  The last rule must be a catch-all (``".*"``) so every leaf
  resolves; ``tools/check_policy.py`` lints exactly that.

Back-compat is a LAW, not an aspiration: :meth:`CompressionPolicy.uniform`
lifts a legacy flat :class:`~repro.core.compression.CompressionConfig` into a
one-rule policy whose :meth:`flat_config` round-trips to an EQUAL config —
uniform policies dispatch through the identical pre-policy code path in
``repro.core.diana``, so every existing config, CLI flag and checkpoint keeps
working bitwise (``tests/test_policy.py``).  Grouped (multi-rule) policies run
the grouped driver: one aggregation sub-round per group with a disjoint PRNG
fold (``repro.core.diana.GROUP_FOLD``), at most one compress / all-gather /
decode_sum per group per direction.  DESIGN.md §Policy.
"""

from __future__ import annotations

import functools
import json
import math
import os
import re
from dataclasses import dataclass, replace as _dc_replace
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .bucket import BucketLayout, GroupedBucketLayout
from .compression import CompressionConfig
from .compressors.registry import canonical_name
from .participation import ParticipationSpec

__all__ = [
    "ChannelSpec",
    "Rule",
    "CompressionPolicy",
    "as_policy",
    "parse_rules",
    "load_policy",
    "partition_for",
    "PolicyPartition",
    "grouped_bucket_layout",
    "policy_bits_per_dim",
    "tree_paths",
]

# Single source of truth for unset ChannelSpec knobs: the flat config's own
# field defaults (k=64, block_size=2048, p=inf).
_FLAT_DEFAULTS = CompressionConfig()

_LAYOUTS = ("bucketed", "perleaf")
# Patterns recognised as the catch-all rule (the linter requires exactly one,
# in last position; ``parse_rules`` spells it ``*``).
_CATCH_ALL = ("", ".*")

_H_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
             "float16": jnp.float16}


@dataclass(frozen=True)
class ChannelSpec:
    """One direction's compression operator for one parameter group.

    method:     any registry name or alias (ternary | natural | randk |
                topk_ef | identity, or the legacy diana | qsgd | terngrad |
                dqgd | none).
    k:          kept coordinates for the sparse operators.  ``None`` inherits
                (downlink: the uplink's ``k``; else the flat default 64).
    block_size: quantization block d_l (Def. 2) for the ternary family.
    p:          norm power of the ternary family (2.0 or math.inf).
    alpha:      memory-rate override (``None`` = the operator's theory
                default).
    layout:     ``"bucketed"`` | ``"perleaf"`` | ``None`` (= the policy's
                default layout).  Bucketed groups aggregate as ONE fused flat
                buffer — one compress, one all-gather, one decode_sum.
    """

    method: str = "diana"
    k: Optional[int] = None
    block_size: Optional[int] = None
    p: Optional[float] = None
    alpha: Optional[float] = None
    layout: Optional[str] = None

    def __post_init__(self):
        canonical_name(self.method)  # raises on unknown methods
        if self.layout is not None and self.layout not in _LAYOUTS:
            raise ValueError(
                f"layout must be one of {_LAYOUTS} or None, got {self.layout!r}")
        if self.block_size is not None and self.block_size % 4:
            raise ValueError("block_size must be a multiple of 4 for 2-bit packing")
        if self.k is not None and self.k <= 0:
            raise ValueError(f"k must be positive, got {self.k}")


def _pick(spec: ChannelSpec, base: Optional[ChannelSpec], fld: str, default):
    """Resolve one spec field: own value, else the base (uplink) spec's, else
    the flat-config default."""
    v = getattr(spec, fld)
    if v is None and base is not None:
        v = getattr(base, fld)
    return default if v is None else v


@dataclass(frozen=True)
class Rule:
    """One policy rule: leaves whose path matches ``pattern`` (``re.search``
    over the ``/``-joined key path, e.g. ``blocks/layer0/norm1/scale``) use
    ``spec`` uplink and — when set — ``down`` for the server broadcast.
    ``name`` labels the group in state trees and benchmarks (default: the
    spec's canonical method name)."""

    pattern: str
    spec: ChannelSpec
    down: Optional[ChannelSpec] = None
    name: Optional[str] = None

    def __post_init__(self):
        re.compile(self.pattern)  # raises on invalid regexes

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None

    @property
    def is_catch_all(self) -> bool:
        return self.pattern in _CATCH_ALL

    def label(self) -> str:
        return self.name or canonical_name(self.spec.method)


@dataclass(frozen=True)
class CompressionPolicy:
    """Ordered path-pattern -> :class:`ChannelSpec` rules + model-wide knobs.

    rules:       first-match-wins, last must be a catch-all.  Group identity
                 is the rule (all leaves matching rule i form group i), so a
                 model's state layout is a pure function of (policy, pytree).
    bucketed:    default layout for specs with ``layout=None``.
    h_dtype / worker_axes / use_kernel:  as on the flat config — model-wide.
    vr / vr_p:   VR-DIANA switch.  Model-wide: the L-SVRG control variate is
                 applied to the parameter-shaped gradients BEFORE any grouping
                 (repro.core.vr), so it composes with every rule unchanged.
    participation: elastic-participation spec
                 (:class:`~repro.core.participation.ParticipationSpec`).
                 Model-wide BY CONSTRUCTION: a worker is in or out of the
                 whole step, never of one group, so the one PART_FOLD mask
                 draw is shared by every group and never appears on the
                 per-rule configs (tools/check_policy.py lints that the rule
                 resolution is participation-independent).
    """

    rules: Tuple[Rule, ...] = (Rule(".*", ChannelSpec()),)
    bucketed: bool = False
    h_dtype: Any = jnp.float32
    worker_axes: Tuple[str, ...] = ("pod", "data")
    use_kernel: Optional[bool] = None
    vr: bool = False
    vr_p: Optional[float] = None
    participation: Optional[ParticipationSpec] = None
    chunk_bytes: int = 0
    topology: str = "flat"
    node_size: int = 1

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        object.__setattr__(self, "worker_axes", tuple(self.worker_axes))
        if not self.rules:
            raise ValueError("a CompressionPolicy needs at least one rule")
        if len(self.rules) > 100:
            raise ValueError("at most 100 rules (group names are zero-padded "
                             "to two digits for stable dict ordering)")
        if self.vr_p is not None and not 0.0 < self.vr_p <= 1.0:
            raise ValueError(f"vr_p must be in (0, 1], got {self.vr_p}")
        if self.participation is not None and not isinstance(
            self.participation, ParticipationSpec
        ):
            raise TypeError("participation must be a ParticipationSpec")
        # chunk_bytes / topology / node_size are model-wide like vr: the chunk
        # schedule and the node grouping act on whole wire rounds, never on
        # one group.  Per-group validation happens on the rule configs.
        if self.chunk_bytes < 0:
            raise ValueError(f"chunk_bytes must be >= 0, got {self.chunk_bytes}")
        if self.topology not in ("flat", "hierarchical"):
            raise ValueError(
                f"topology must be 'flat' or 'hierarchical', got {self.topology!r}")
        if self.node_size < 1:
            raise ValueError(f"node_size must be >= 1, got {self.node_size}")

    # --------------------------------------------------------------- matching

    def match(self, path: str) -> int:
        """Index of the first rule matching ``path`` (ordered, first wins)."""
        for i, rule in enumerate(self.rules):
            if rule.matches(path):
                return i
        raise KeyError(
            f"no rule matches leaf path {path!r} — policies must end with a "
            f"catch-all rule ('.*'); have patterns "
            f"{[r.pattern for r in self.rules]}")

    # ------------------------------------------------- flat-config round-trip

    @property
    def is_uniform(self) -> bool:
        """One catch-all rule expressible as a flat ``CompressionConfig`` —
        such policies dispatch through the identical pre-policy code path
        (the bitwise back-compat law, tests/test_policy.py)."""
        if len(self.rules) != 1 or not self.rules[0].is_catch_all:
            return False
        d = self.rules[0].down
        # The flat config cannot give the downlink its own block/p/alpha.
        return d is None or all(
            getattr(d, f) is None for f in ("block_size", "p", "alpha"))

    @classmethod
    def uniform(cls, cfg: CompressionConfig) -> "CompressionPolicy":
        """Lift a legacy flat config into a one-rule policy.

        Law: ``uniform(cfg).flat_config() == cfg`` for every flat config, so
        the shimmed policy reaches the exact pre-policy aggregation path.
        """
        spec = ChannelSpec(method=cfg.method, k=cfg.k, block_size=cfg.block_size,
                           p=cfg.p, alpha=cfg.alpha)
        down = None
        if cfg.down_method is not None:
            down = ChannelSpec(
                method=cfg.down_method, k=cfg.down_k,
                layout=None if cfg.down_bucketed is None
                else _LAYOUTS[0] if cfg.down_bucketed else _LAYOUTS[1])
        return cls(rules=(Rule(".*", spec, down=down),), bucketed=cfg.bucketed,
                   h_dtype=cfg.h_dtype, worker_axes=cfg.worker_axes,
                   use_kernel=cfg.use_kernel, vr=cfg.vr, vr_p=cfg.vr_p,
                   participation=cfg.participation, chunk_bytes=cfg.chunk_bytes,
                   topology=cfg.topology, node_size=cfg.node_size)

    def flat_config(self) -> CompressionConfig:
        """The legacy flat config of a uniform policy (inverse of
        :meth:`uniform`); raises for grouped policies."""
        if not self.is_uniform:
            raise ValueError(
                "grouped policies have no flat CompressionConfig equivalent; "
                "use .rules / rule_config() (or representative_config() for "
                "the model-wide fields)")
        rule = self.rules[0]
        s, d = rule.spec, rule.down
        return CompressionConfig(
            method=s.method,
            p=_pick(s, None, "p", _FLAT_DEFAULTS.p),
            block_size=_pick(s, None, "block_size", _FLAT_DEFAULTS.block_size),
            alpha=s.alpha,
            k=_pick(s, None, "k", _FLAT_DEFAULTS.k),
            h_dtype=self.h_dtype,
            worker_axes=self.worker_axes,
            use_kernel=self.use_kernel,
            bucketed=self._spec_bucketed(s),
            vr=self.vr,
            vr_p=self.vr_p,
            down_method=None if d is None else d.method,
            down_k=None if d is None else d.k,
            down_bucketed=None if d is None or d.layout is None
            else d.layout == "bucketed",
            participation=self.participation,
            chunk_bytes=self.chunk_bytes,
            topology=self.topology,
            node_size=self.node_size,
        )

    def representative_config(self) -> CompressionConfig:
        """A flat view of the CATCH-ALL rule carrying the policy's model-wide
        fields (``worker_axes``/``vr``/``h_dtype``/...) — for call sites that
        only need those; per-group fields are representative only."""
        if self.is_uniform:
            return self.flat_config()
        catch = next((i for i, r in enumerate(self.rules) if r.is_catch_all),
                     len(self.rules) - 1)
        cfg = _rule_config(self, catch)
        return _dc_replace(cfg, vr=self.vr, vr_p=self.vr_p,
                           participation=self.participation)

    # ------------------------------------------------------- per-rule configs

    def _spec_bucketed(self, spec: ChannelSpec) -> bool:
        return self.bucketed if spec.layout is None else spec.layout == "bucketed"

    def rule_config(self, i: int) -> CompressionConfig:
        """The UPLINK :class:`CompressionConfig` of rule ``i``'s group
        (vr/downlink stripped — VR is applied globally, the downlink has its
        own config from :meth:`rule_down_config`)."""
        return _rule_config(self, i)

    def rule_down_config(self, i: int) -> Optional[CompressionConfig]:
        """Rule ``i``'s standalone DOWNLINK config (``None`` when the rule
        has no ``down`` spec).  Unset down knobs inherit the uplink spec's
        (the legacy ``down_config()`` derivation semantics)."""
        return _rule_down_config(self, i)

    def any_bucketed(self) -> bool:
        """Whether any group (either direction) resolves to the bucketed
        layout — the condition ``launch.train.resolve_bucketed`` gates on."""
        for i, rule in enumerate(self.rules):
            if self._spec_bucketed(rule.spec):
                return True
            d = self.rule_down_config(i)
            if d is not None and d.bucketed:
                return True
        return False

    # ------------------------------------------------------------- rewriting

    def replace(self, **kw) -> "CompressionPolicy":
        """``dataclasses.replace`` — the policy analogue of rebuilding a flat
        config; the legacy ``DianaOptimizer(vr=, vr_p=)`` kwargs shim onto
        ``policy.replace(vr=, vr_p=)``."""
        return _dc_replace(self, **kw)

    def with_down(self, method: Optional[str] = None,
                  k: Optional[int] = None) -> "CompressionPolicy":
        """Attach/override the downlink channel on EVERY rule — the legacy
        ``down_method``/``down_k`` override semantics.  A ``k`` override
        without a method (given or already present) is inert, exactly like
        ``down_k`` on a config whose ``down_method`` is None."""

        def upd(rule: Rule) -> Rule:
            m = method if method is not None else (
                rule.down.method if rule.down is not None else None)
            if m is None:
                return rule
            base = rule.down if rule.down is not None else ChannelSpec(method=m)
            return _dc_replace(rule, down=_dc_replace(
                base, method=m, k=k if k is not None else base.k))

        return _dc_replace(self, rules=tuple(upd(r) for r in self.rules))

    def force_perleaf(self) -> "CompressionPolicy":
        """Every group (both directions) downgraded to the per-leaf layout —
        what ``resolve_bucketed`` applies on toolchains where the flat-buffer
        round cannot lower (DESIGN.md §6).  Bitwise the same results, just
        more collectives."""

        def fix(rule: Rule) -> Rule:
            spec = (_dc_replace(rule.spec, layout="perleaf")
                    if rule.spec.layout == "bucketed" else rule.spec)
            down = rule.down
            if down is not None:
                down = _dc_replace(down, layout="perleaf")
            return _dc_replace(rule, spec=spec, down=down)

        # Hierarchical topology rides the fused wire, so the downgrade also
        # falls back to the flat exchange (resolve_bucketed's warning names
        # both losses).
        return _dc_replace(self, bucketed=False, topology="flat",
                           rules=tuple(fix(r) for r in self.rules))

    # ---------------------------------------------------------- serialization

    def to_json_dict(self) -> dict:
        def spec_dict(s: ChannelSpec) -> dict:
            d = {"method": s.method}
            for f in ("k", "block_size", "alpha", "layout"):
                if getattr(s, f) is not None:
                    d[f] = getattr(s, f)
            if s.p is not None:
                d["p"] = "inf" if s.p == math.inf else s.p
            return d

        rules = []
        for r in self.rules:
            rd = {"pattern": r.pattern, **spec_dict(r.spec)}
            if r.down is not None:
                rd["down"] = spec_dict(r.down)
            if r.name is not None:
                rd["name"] = r.name
            rules.append(rd)
        doc = {"rules": rules, "bucketed": self.bucketed,
               "worker_axes": list(self.worker_axes)}
        if self.h_dtype is not jnp.float32:
            doc["h_dtype"] = jnp.dtype(self.h_dtype).name
        if self.use_kernel is not None:
            doc["use_kernel"] = self.use_kernel
        if self.vr:
            doc["vr"] = True
        if self.vr_p is not None:
            doc["vr_p"] = self.vr_p
        if self.participation is not None:
            doc["participation"] = self.participation.to_json_dict()
        if self.chunk_bytes:
            doc["chunk_bytes"] = self.chunk_bytes
        if self.topology != "flat":
            doc["topology"] = self.topology
        if self.node_size != 1:
            doc["node_size"] = self.node_size
        return doc

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), indent=1)

    @classmethod
    def from_json_dict(cls, doc: dict, **defaults) -> "CompressionPolicy":
        """Build from a JSON dict; ``defaults`` seed the model-wide fields and
        the document's explicit keys win."""

        def spec_of(d: dict) -> ChannelSpec:
            kw = {"method": d["method"]}
            for f in ("k", "block_size", "alpha", "layout"):
                if f in d:
                    kw[f] = d[f]
            if "block" in d:  # inline-syntax alias tolerated in JSON too
                kw["block_size"] = d["block"]
            if "p" in d:
                kw["p"] = math.inf if d["p"] in ("inf", "Infinity") else float(d["p"])
            return ChannelSpec(**kw)

        rules = tuple(
            Rule(pattern=rd["pattern"], spec=spec_of(rd),
                 down=spec_of(rd["down"]) if rd.get("down") else None,
                 name=rd.get("name"))
            for rd in doc["rules"])
        kw = dict(defaults)
        for f in ("bucketed", "use_kernel", "vr", "vr_p",
                  "chunk_bytes", "topology", "node_size"):
            if f in doc:
                kw[f] = doc[f]
        if "worker_axes" in doc:
            kw["worker_axes"] = tuple(doc["worker_axes"])
        if "h_dtype" in doc:
            kw["h_dtype"] = _H_DTYPES[doc["h_dtype"]]
        if "participation" in doc:
            kw["participation"] = (
                None if doc["participation"] is None
                else ParticipationSpec.from_json_dict(doc["participation"]))
        return cls(rules=rules, **kw)

    @classmethod
    def from_json(cls, text: str, **defaults) -> "CompressionPolicy":
        return cls.from_json_dict(json.loads(text), **defaults)


@functools.lru_cache(maxsize=None)
def _rule_config(policy: CompressionPolicy, i: int) -> CompressionConfig:
    spec = policy.rules[i].spec
    bucketed = policy._spec_bucketed(spec)
    # Hierarchical exchange rides the fused wire; a per-leaf group in a
    # hierarchical policy runs the flat exchange (and node_size is inert).
    topology = policy.topology if bucketed else "flat"
    return CompressionConfig(
        method=spec.method,
        p=_pick(spec, None, "p", _FLAT_DEFAULTS.p),
        block_size=_pick(spec, None, "block_size", _FLAT_DEFAULTS.block_size),
        alpha=spec.alpha,
        k=_pick(spec, None, "k", _FLAT_DEFAULTS.k),
        h_dtype=policy.h_dtype,
        worker_axes=policy.worker_axes,
        use_kernel=policy.use_kernel,
        bucketed=bucketed,
        chunk_bytes=policy.chunk_bytes,
        topology=topology,
        node_size=policy.node_size if topology == "hierarchical" else 1,
    )


@functools.lru_cache(maxsize=None)
def _rule_down_config(policy: CompressionPolicy, i: int) -> Optional[CompressionConfig]:
    rule = policy.rules[i]
    if rule.down is None:
        return None
    up, d = rule.spec, rule.down
    up_bucketed = policy._spec_bucketed(up)
    return CompressionConfig(
        method=d.method,
        p=_pick(d, up, "p", _FLAT_DEFAULTS.p),
        block_size=_pick(d, up, "block_size", _FLAT_DEFAULTS.block_size),
        alpha=d.alpha if d.alpha is not None else up.alpha,
        k=_pick(d, up, "k", _FLAT_DEFAULTS.k),
        h_dtype=policy.h_dtype,
        worker_axes=policy.worker_axes,
        use_kernel=policy.use_kernel,
        bucketed=up_bucketed if d.layout is None else d.layout == "bucketed",
        # The broadcast has no collective: topology never applies downlink,
        # but the wire chunks the same way the uplink's does.
        chunk_bytes=policy.chunk_bytes,
    )


def as_policy(spec) -> CompressionPolicy:
    """Coerce a :class:`CompressionConfig` | :class:`CompressionPolicy` to a
    policy (the config becomes a one-rule uniform policy)."""
    if isinstance(spec, CompressionPolicy):
        return spec
    return CompressionPolicy.uniform(spec)


# ---------------------------------------------------------------------------
# Tree partitioning: leaves -> groups by rule (static, cached)
# ---------------------------------------------------------------------------

def _path_entry_str(entry) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def tree_paths(tree, is_leaf=None) -> Tuple[str, ...]:
    """The ``/``-joined key path of every leaf (tree_flatten order) — the
    strings rule patterns match against."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return tuple("/".join(_path_entry_str(e) for e in path) for path, _ in flat)


class PolicyPartition:
    """Static partition of ONE pytree structure under a policy.

    Built once per (policy, treedef) — cacheable because group membership is
    a pure function of leaf paths — and reused by init/aggregation/sharding/
    checkpointing, so every consumer agrees on the grouping.  Group g holds
    the leaves matching rule ``rule_ids[g]``, in tree-flatten order; group
    names are ``g<rule_index:02d>_<label>`` (zero-padded so dict key sorting
    — jax's pytree ordering for dicts — preserves rule order).
    """

    def __init__(self, policy: CompressionPolicy, treedef, paths: Tuple[str, ...]):
        self.policy = policy
        self.treedef = treedef
        self.paths = paths
        leaf_rule = tuple(policy.match(p) for p in paths)
        active = sorted(set(leaf_rule))
        self.rule_ids: Tuple[int, ...] = tuple(active)
        self.group_names: Tuple[str, ...] = tuple(
            f"g{ri:02d}_{policy.rules[ri].label()}" for ri in active)
        self.group_leaf_ids: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(i for i, r in enumerate(leaf_rule) if r == ri)
            for ri in active)
        self.configs: Tuple[CompressionConfig, ...] = tuple(
            policy.rule_config(ri) for ri in active)
        self.down_configs: Tuple[Optional[CompressionConfig], ...] = tuple(
            policy.rule_down_config(ri) for ri in active)

    @property
    def n_groups(self) -> int:
        return len(self.rule_ids)

    def split(self, tree, is_leaf=None):
        """Per-group LISTS of this tree's leaves (a list is a pytree, so the
        per-group sub-round machinery consumes them unchanged).  Works for any
        tree sharing the partition's leaf order — grads, params, stacked
        per-worker trees, PartitionSpec trees (pass ``is_leaf``)."""
        leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_leaf)
        if len(leaves) != len(self.paths):
            raise ValueError(
                f"tree has {len(leaves)} leaves, partition expects "
                f"{len(self.paths)}")
        return [[leaves[i] for i in ids] for ids in self.group_leaf_ids]

    def merge(self, group_parts):
        """Inverse of :meth:`split`: per-group leaf lists -> the full tree."""
        out = [None] * len(self.paths)
        for ids, part in zip(self.group_leaf_ids, group_parts):
            leaves = jax.tree_util.tree_leaves(part)
            assert len(leaves) == len(ids)
            for i, leaf in zip(ids, leaves):
                out[i] = leaf
        return jax.tree_util.tree_unflatten(self.treedef, out)


@functools.lru_cache(maxsize=None)
def _partition_cached(policy, treedef, paths) -> PolicyPartition:
    return PolicyPartition(policy, treedef, paths)


def partition_for(policy: CompressionPolicy, tree) -> PolicyPartition:
    """The (cached) partition of ``tree``'s structure under ``policy``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = tuple("/".join(_path_entry_str(e) for e in path) for path, _ in flat)
    return _partition_cached(policy, treedef, paths)


# ---------------------------------------------------------------------------
# Grouped bucket layout + policy-aware wire accounting
# ---------------------------------------------------------------------------

def grouped_bucket_layout(policy: CompressionPolicy, tree) -> GroupedBucketLayout:
    """One :class:`~repro.core.bucket.BucketLayout` per group (each aligned to
    its own operator's ``bucket_align()``) — the flat-buffer layout a grouped
    bucketed round aggregates in: one fused buffer per group."""
    part = partition_for(policy, tree)
    groups = part.split(tree)
    layouts = tuple(
        BucketLayout.for_tree(groups[g], align=part.configs[g].make().bucket_align())
        for g in range(part.n_groups))
    return GroupedBucketLayout(names=part.group_names, rule_ids=part.rule_ids,
                               layouts=layouts)


def policy_bits_per_dim(policy: CompressionPolicy, layout, *,
                        checksum: bool = False) -> float:
    """Size-weighted mean UPLINK wire cost per coordinate across groups — the
    policy-aware analogue of
    :func:`repro.core.compression.payload_bits_per_dim`.  ``layout`` is a
    :class:`~repro.core.bucket.GroupedBucketLayout` (or any params-like
    pytree, from which one is derived).

    ``checksum=True`` (faults armed) counts the 8-byte wire tail every
    bucketed group's fused buffer carries — one tail PER WIRE BUFFER, i.e.
    per chunk of the group's :class:`~repro.core.bucket.ChunkedSchedule`
    (:func:`~repro.core.bucket.checksum_tail_bits_per_dim`); per-leaf groups
    carry none (the fault harness requires the bucketed layout)."""
    from .bucket import checksum_tail_bits_per_dim

    if not isinstance(layout, GroupedBucketLayout):
        layout = grouped_bucket_layout(policy, layout)
    bits = total = 0.0
    for ri, lay in zip(layout.rule_ids, layout.layouts):
        cfg = policy.rule_config(ri)
        comp = cfg.make()
        for s in lay.sizes:
            bits += comp.bits_per_dim(s) * s
            total += s
        if checksum and cfg.bucketed:
            bits += checksum_tail_bits_per_dim(lay, cfg.chunk_bytes) * lay.size
    return bits / max(total, 1.0)


# ---------------------------------------------------------------------------
# Inline rule syntax + file loading (the trainer's --comp-policy surface)
# ---------------------------------------------------------------------------

_SPEC_FIELDS = {"k": int, "block_size": int, "alpha": float}
_FIELD_ALIASES = {"block": "block_size"}


def _parse_spec(text: str) -> ChannelSpec:
    parts = [b.strip() for b in text.strip().split(":") if b.strip()]
    if not parts:
        raise ValueError("empty operator spec")
    kw: dict = {"method": parts[0]}
    for item in parts[1:]:
        fld, sep, val = item.partition("=")
        if not sep:
            raise ValueError(f"spec option {item!r} is not field=value")
        fld = _FIELD_ALIASES.get(fld, fld)
        if fld == "layout":
            kw[fld] = val
        elif fld == "p":
            kw[fld] = math.inf if val in ("inf", "Inf", "INF") else float(val)
        elif fld in _SPEC_FIELDS:
            kw[fld] = _SPEC_FIELDS[fld](val)
        else:
            raise ValueError(f"unknown spec field {fld!r} in {text!r}")
    return ChannelSpec(**kw)


def parse_rules(text: str) -> Tuple[Rule, ...]:
    """Parse the inline rule syntax:

        pattern=method[:field=value...][/down_method[:field=value...]] , ...

    e.g. ``scale|bias=identity,embed=topk_ef:k=256,*=diana:block=1024/natural``
    — ``*`` is the catch-all, ``block`` aliases ``block_size``, ``/`` attaches
    the downlink channel.  Patterns are ``re.search`` regexes and may contain
    ``/`` (paths are ``/``-joined, e.g. ``mlp/w_``; only the ``/`` AFTER the
    first ``=`` separates the downlink spec); they may not contain ``,`` or
    ``=`` (use a JSON policy file for those).
    """
    rules = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        pattern, sep, spec_txt = part.partition("=")
        if not sep or not spec_txt:
            raise ValueError(f"rule {part!r} is not pattern=method[...]")
        up_txt, _, down_txt = spec_txt.partition("/")
        pattern = pattern.strip()
        rules.append(Rule(
            pattern=".*" if pattern == "*" else pattern,
            spec=_parse_spec(up_txt),
            down=_parse_spec(down_txt) if down_txt.strip() else None,
        ))
    if not rules:
        raise ValueError(f"no rules in {text!r}")
    return tuple(rules)


def load_policy(source, **globals_kw) -> CompressionPolicy:
    """Build a policy from any of the trainer's surfaces: an existing
    :class:`CompressionPolicy` (returned as-is), a ``.json`` file path (the
    document's model-wide keys override ``globals_kw``), or an inline rule
    string (``globals_kw`` supply the model-wide fields)."""
    if isinstance(source, CompressionPolicy):
        return source
    if isinstance(source, CompressionConfig):
        return CompressionPolicy.uniform(source)
    if isinstance(source, str) and source.endswith(".json"):
        if not os.path.exists(source):
            raise FileNotFoundError(f"policy file {source!r} does not exist")
        with open(source) as f:
            return CompressionPolicy.from_json_dict(json.load(f), **globals_kw)
    return CompressionPolicy(rules=parse_rules(source), **globals_kw)
