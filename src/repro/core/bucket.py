"""Flat-buffer (bucketed) aggregation layout — one wire object per step.

The paper's cost model (Sec. 2, and the per-round accounting in Horváth et
al., 2019) counts ONE compressed message per worker per iteration; the
per-leaf pipeline in :mod:`repro.core.diana` instead pays per-leaf costs — a
transformer with ~100 parameter leaves issues ~100 small collectives and ~100
kernel launches per step.  This module provides the single-vector formulation:

* :class:`BucketLayout` — a static layout of a parameter pytree as ONE flat
  f32 buffer: per-leaf offsets, segments padded to the operator's block
  alignment (so quantization blocks never straddle leaves), tail pads only.
* :class:`BucketedCompressor` — an adapter presenting the ordinary
  :class:`~repro.core.compressors.Compressor` interface over that buffer by
  delegating to the operator's ``*_bucketed`` hooks, so the whole round is
  ONE ``compress`` call, ONE :class:`Payload`, ONE all-gather and ONE
  ``decode_sum`` launch.
* payload **wire fusion** (:func:`fuse_payload` / :func:`unfuse_payload`) —
  every Payload field byte-cast into one contiguous uint8 buffer so the
  gather really is a single collective, not one per field.  The compressed
  downlink broadcast (DESIGN.md §Bidirectional) shares this path via
  :func:`wire_roundtrip`: one uint8 wire object per direction per step.

Bitwise contract: the bucketed path reproduces the per-leaf path EXACTLY
(same PRNG draws per segment, same per-block scales, same f32 summation
order) — ``tests/test_bucket.py`` asserts equality for every registry
operator.  The only documented exception is the TPU in-kernel-PRNG encode
(`kernels/quantize_pack.py`), which, like the kernel encode generally, agrees
in distribution rather than bitwise.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compressors.base import Compressor, Payload

__all__ = [
    "BucketLayout",
    "ChunkedSchedule",
    "GroupedBucketLayout",
    "BucketedCompressor",
    "bucketed_compressor",
    "fuse_payload",
    "payload_recipe",
    "unfuse_payload",
    "wire_roundtrip",
    "CHECKSUM_BYTES",
    "add_checksum",
    "verify_checksum",
    "checksum_tail_bits_per_dim",
]


@dataclass(frozen=True)
class BucketLayout:
    """Static flat layout of a pytree (hashable: usable as a cache key).

    treedef:      pytree structure of the source tree
    shapes:       per-leaf shapes (tree_flatten order)
    dtypes:       per-leaf dtypes
    sizes:        per-leaf element counts (unpadded)
    padded_sizes: per-leaf segment lengths, ``sizes`` rounded up to ``align``
    offsets:      start of each leaf's segment in the flat buffer
    align:        segment alignment (the operator's ``bucket_align()``) —
                  blocked operators align to their block size so no
                  quantization block straddles a leaf boundary
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    padded_sizes: Tuple[int, ...]
    offsets: Tuple[int, ...]
    align: int

    @classmethod
    def for_tree(cls, tree, align: int = 1) -> "BucketLayout":
        """Build the layout from a pytree of arrays or ShapeDtypeStructs."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(np.dtype(l.dtype) for l in leaves)
        sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
        padded = tuple(-(-s // align) * align for s in sizes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + padded[:-1]))
        return cls(treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
                   padded_sizes=padded, offsets=offsets, align=align)

    # ------------------------------------------------------------ properties

    @property
    def n_leaves(self) -> int:
        return len(self.sizes)

    @property
    def size(self) -> int:
        """Total unpadded element count."""
        return sum(self.sizes)

    @property
    def padded_size(self) -> int:
        """Length of the flat buffer (sum of aligned segments)."""
        return sum(self.padded_sizes)

    # ------------------------------------------------------------- plumbing

    def flatten(self, tree) -> jax.Array:
        """Pytree -> ONE padded flat f32 buffer (segment pads are zeros).

        Unpadded layouts (align=1, the sparse/elementwise operators) lower to
        a single fast concatenate.  Block-aligned layouts write each leaf
        into a zeros buffer at its static offset via ``dynamic_update_slice``
        — XLA folds the chain into in-place stores, where per-leaf
        pad+concat pairs (or zero-interleaved concatenates) each pay per-op
        overhead on exactly the many-small-ops pattern this layout exists to
        remove.  (No ``jnp.pad`` anywhere on this path — DESIGN.md §6.)
        """
        leaves = jax.tree_util.tree_leaves(tree)
        flats = [l.reshape(-1).astype(jnp.float32) for l in leaves]
        if self.padded_size == self.size:
            return flats[0] if len(flats) == 1 else jnp.concatenate(flats)
        buf = jnp.zeros((self.padded_size,), jnp.float32)
        for f, off in zip(flats, self.offsets):
            buf = jax.lax.dynamic_update_slice(buf, f, (off,))
        return buf

    def unflatten(self, flat: jax.Array, cast: bool = True):
        """Flat buffer -> pytree (dropping segment pads).

        ``cast=True`` restores the recorded leaf dtypes (the distributed
        path); ``cast=False`` keeps f32 leaves (the reference path, matching
        the per-leaf ``reference_step`` which never downcasts ghat).
        """
        outs = []
        for off, size, shape, dt in zip(self.offsets, self.sizes, self.shapes,
                                        self.dtypes):
            seg = jax.lax.slice_in_dim(flat, off, off + size).reshape(shape)
            outs.append(seg.astype(dt) if cast else seg)
        return jax.tree_util.tree_unflatten(self.treedef, outs)

    def split_padded(self, flat: jax.Array):
        """The per-leaf padded segment views of the flat buffer."""
        return [
            jax.lax.slice_in_dim(flat, off, off + ps)
            for off, ps in zip(self.offsets, self.padded_sizes)
        ]


@dataclass(frozen=True)
class ChunkedSchedule:
    """A :class:`BucketLayout` split into consecutive whole-leaf chunks.

    The chunked wire (repro.core.diana) compresses, gathers and decodes the
    flat buffer one chunk at a time, issuing chunk *i+1*'s collective before
    chunk *i*'s ``decode_sum`` so the gather overlaps the decode (async
    collectives double-buffer the wire).  Chunk boundaries sit on LEAF
    boundaries only:

    * each leaf keeps its position in the monolithic key schedule, so the
      per-chunk compress of leaf ``j`` with key ``keys[j]`` draws exactly the
      monolithic bits — sum-of-chunks is bitwise the monolithic sum;
    * segments stay ``align``-padded, so quantization blocks never straddle a
      chunk boundary either.

    ``bounds`` are the leaf indices at which chunks begin/end
    (``bounds[0] == 0``, ``bounds[-1] == n_leaves``); the greedy packer
    :meth:`for_layout` closes a chunk once it holds at least ``chunk_bytes``
    of padded f32 payload, so chunk sizes need not divide the buffer.
    """

    layout: BucketLayout
    bounds: Tuple[int, ...]

    @classmethod
    def for_layout(cls, layout: BucketLayout,
                   chunk_bytes: int) -> "ChunkedSchedule":
        """Greedy whole-leaf packing toward ``chunk_bytes`` per chunk
        (buffer bytes = 4 * padded elements).  ``chunk_bytes <= 0`` or larger
        than the buffer yields the single-chunk (monolithic) schedule."""
        if chunk_bytes <= 0:
            return cls(layout=layout, bounds=(0, layout.n_leaves))
        bounds = [0]
        acc = 0
        for i, ps in enumerate(layout.padded_sizes):
            if acc >= chunk_bytes and acc > 0:
                bounds.append(i)
                acc = 0
            acc += 4 * ps
        bounds.append(layout.n_leaves)
        return cls(layout=layout, bounds=tuple(bounds))

    @property
    def n_chunks(self) -> int:
        return len(self.bounds) - 1

    @property
    def chunk_layouts(self) -> Tuple[BucketLayout, ...]:
        return _chunk_layouts(self)

    @property
    def chunk_offsets(self) -> Tuple[int, ...]:
        """Element offset of each chunk in the monolithic flat buffer."""
        return tuple(self.layout.offsets[b] if b < self.layout.n_leaves
                     else self.layout.padded_size for b in self.bounds[:-1])

    @property
    def chunk_sizes(self) -> Tuple[int, ...]:
        """Padded element count of each chunk."""
        return tuple(l.padded_size for l in self.chunk_layouts)

    def split(self, flat: jax.Array):
        """Flat buffer -> the per-chunk buffer views (static slices)."""
        return [
            jax.lax.slice_in_dim(flat, off, off + sz)
            for off, sz in zip(self.chunk_offsets, self.chunk_sizes)
        ]

    def chunk_keys(self, keys: jax.Array, c: int) -> jax.Array:
        """Chunk ``c``'s slice of the MONOLITHIC per-leaf key schedule
        (``jax.random.split(key, n_leaves)``) — the bitwise-equality
        linchpin: chunking never re-splits keys."""
        return keys[self.bounds[c]:self.bounds[c + 1]]


@functools.lru_cache(maxsize=None)
def _chunk_layouts(sched: ChunkedSchedule) -> Tuple[BucketLayout, ...]:
    """Per-chunk sub-layouts with offsets rebased to the chunk's origin, so
    every ``*_bucketed`` hook (and its index arithmetic — the sparse
    operators embed layout offsets in their payloads) works per chunk
    unchanged."""
    lay = sched.layout
    outs = []
    for b0, b1 in zip(sched.bounds[:-1], sched.bounds[1:]):
        base = lay.offsets[b0] if b0 < lay.n_leaves else lay.padded_size
        outs.append(BucketLayout(
            treedef=jax.tree_util.tree_structure([0] * (b1 - b0)),
            shapes=lay.shapes[b0:b1],
            dtypes=lay.dtypes[b0:b1],
            sizes=lay.sizes[b0:b1],
            padded_sizes=lay.padded_sizes[b0:b1],
            offsets=tuple(o - base for o in lay.offsets[b0:b1]),
            align=lay.align,
        ))
    return tuple(outs)


@dataclass(frozen=True)
class GroupedBucketLayout:
    """One :class:`BucketLayout` per compression-policy group.

    A grouped bucketed round (repro.core.policy / repro.core.diana) fuses each
    GROUP — not the whole model — into one flat buffer: a ternary-group +
    top-k-group model still pays ~one collective per group per direction
    instead of per leaf.  ``names`` are the policy's group names (the keys of
    the grouped ``DianaState`` dicts), ``rule_ids`` the owning rule index of
    each group (stable across trees, used by the wire-cost accounting).
    """

    names: Tuple[str, ...]
    rule_ids: Tuple[int, ...]
    layouts: Tuple[BucketLayout, ...]

    @property
    def n_groups(self) -> int:
        return len(self.layouts)

    @property
    def size(self) -> int:
        """Total unpadded element count over every group."""
        return sum(l.size for l in self.layouts)

    @property
    def padded_size(self) -> int:
        return sum(l.padded_size for l in self.layouts)

    @property
    def n_leaves(self) -> int:
        return sum(l.n_leaves for l in self.layouts)


# ---------------------------------------------------------------------------
# Payload wire fusion: one uint8 buffer per gather
# ---------------------------------------------------------------------------

def payload_recipe(pay: Payload):
    """Static (field, shape, dtype) description used to un-fuse the buffer."""
    return tuple(
        (i, tuple(f.shape), np.dtype(f.dtype))
        for i, f in enumerate(pay) if f is not None
    )


def fuse_payload(pay: Payload) -> jax.Array:
    """Byte-cast and concatenate every populated field into ONE uint8 buffer
    of shape ``(lead, W)`` (``lead`` = the fields' shared leading dim), so the
    worker all-gather is literally a single collective.  ``bitcast`` is
    exact, so fusion cannot perturb the bitwise decode contract."""
    parts = []
    lead = None
    for f in pay:
        if f is None:
            continue
        lead = f.shape[0] if lead is None else lead
        assert f.shape[0] == lead, "payload fields must share the leading dim"
        b = jax.lax.bitcast_convert_type(f, jnp.uint8)
        parts.append(b.reshape(lead, -1))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)


def wire_roundtrip(pay: Payload) -> Payload:
    """Materialise a payload's single-buffer wire object and split it back.

    The compressed DOWNLINK broadcast (repro.core.diana.downlink_round) rides
    the same fused uint8 path as the uplink gather — in the BUCKETED layout,
    like the uplink: every populated field byte-casts into ONE contiguous
    buffer — the object a real parameter server would put on the broadcast
    wire — and unfuses on receipt.
    ``bitcast`` is exact, so riding the wire cannot perturb the bitwise
    decode contract; a single-field payload already IS one wire object.
    """
    if sum(f is not None for f in pay) <= 1:
        return pay
    return unfuse_payload(fuse_payload(pay), payload_recipe(pay))


def unfuse_payload(buf: jax.Array, recipe) -> Payload:
    """Inverse of :func:`fuse_payload`; tolerates extra leading (worker) dims
    on ``buf`` from the gather."""
    batch = buf.shape[:-2]
    fields: list = [None] * len(Payload._fields)
    start = 0
    for fi, shape, dt in recipe:
        width = int(np.prod(shape[1:], dtype=np.int64)) * dt.itemsize
        part = jax.lax.slice_in_dim(buf, start, start + width, axis=buf.ndim - 1)
        start += width
        if dt.itemsize == 1:
            fields[fi] = part.reshape(*batch, *shape).astype(dt)
        else:
            part = part.reshape(*batch, *shape, dt.itemsize)
            fields[fi] = jax.lax.bitcast_convert_type(part, dt)
    return Payload(*fields)


# ---------------------------------------------------------------------------
# Wire checksums (fault-injection harness — repro.core.participation)
# ---------------------------------------------------------------------------

# 8-byte tail on the fused wire: two uint32 words — the plain byte sum and a
# position-weighted byte sum (both mod 2^32).  The weighted word catches the
# transpositions/offset errors a plain sum misses; a single-byte XOR corrupt
# always flips at least the plain word.  Not cryptographic — an integrity
# check against the FaultPlan harness and garden-variety wire corruption.
CHECKSUM_BYTES = 8


def _checksum_words(flat: jax.Array) -> jax.Array:
    """``(..., L) uint8 -> (..., 2) uint32`` checksum words."""
    b = flat.astype(jnp.uint32)
    pos = jnp.arange(1, flat.shape[-1] + 1, dtype=jnp.uint32)
    s1 = jnp.sum(b, axis=-1, dtype=jnp.uint32)
    s2 = jnp.sum(b * pos, axis=-1, dtype=jnp.uint32)
    return jnp.stack([s1, s2], axis=-1)


def add_checksum(buf: jax.Array) -> jax.Array:
    """ONE worker's fused ``(lead, W)`` uint8 buffer -> the 1-D wire object
    ``(lead*W + CHECKSUM_BYTES,)``: payload bytes then the checksum tail.
    The receivers' :func:`verify_checksum` recomputes the words and excludes
    payloads that fail, instead of decoding corrupted bytes into the sum."""
    flat = buf.reshape(-1)
    tail = jax.lax.bitcast_convert_type(_checksum_words(flat), jnp.uint8)
    return jnp.concatenate([flat, tail.reshape(-1)])


def verify_checksum(wire: jax.Array):
    """Inverse of :func:`add_checksum` over any leading (worker) dims:
    ``(..., L+8) -> ((..., L) payload bytes, (...,) ok)``.  ``ok`` is False
    exactly when the recomputed words disagree with the tail — the payload
    must then be excluded (its bytes are NOT sanitised)."""
    flat = wire[..., :-CHECKSUM_BYTES]
    tail = wire[..., -CHECKSUM_BYTES:]
    got = jax.lax.bitcast_convert_type(
        tail.reshape(*wire.shape[:-1], 2, 4), jnp.uint32)
    ok = jnp.all(got == _checksum_words(flat), axis=-1)
    return flat, ok


def checksum_tail_bits_per_dim(layout: BucketLayout, chunk_bytes: int = 0) -> float:
    """Wire overhead per coordinate of the checksum tails when faults are
    armed: ONE :data:`CHECKSUM_BYTES` tail rides EVERY wire buffer — one per
    chunk of the :class:`ChunkedSchedule` (the monolithic wire is one chunk).
    Honest bits/dim accounting must count it; the compressors' own
    ``bits_per_dim`` never does (the tail belongs to the wire, not the
    operator)."""
    n_chunks = ChunkedSchedule.for_layout(layout, chunk_bytes).n_chunks
    return CHECKSUM_BYTES * 8.0 * n_chunks / max(layout.size, 1)


# ---------------------------------------------------------------------------
# The bucketed compressor adapter
# ---------------------------------------------------------------------------

class BucketedCompressor(Compressor):
    """A :class:`Compressor` over a :class:`BucketLayout`'s single flat buffer.

    Thin adapter: the per-operator behaviour lives in the operator's own
    ``*_bucketed`` hooks (operator-owned, like the memory rules); this class
    only binds the layout and keeps :mod:`repro.core.diana` free of any
    layout-vs-per-leaf switching beyond the config flag.  Holds no traced
    values, so instances are safely cached per ``(config, layout)``.
    """

    def __init__(self, base: Compressor, layout: BucketLayout):
        self.base = base
        self.layout = layout
        self.name = f"bucketed:{base.name}"
        self.unbiased = base.unbiased
        self.carries_state = base.carries_state
        self.use_kernel = base.use_kernel
        self.prefers_allreduce = base.prefers_allreduce

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        return self.base.compress_bucketed(self.layout, delta, key)

    def decode(self, payload: Payload, d: Optional[int] = None) -> jax.Array:
        return self.base.decode_bucketed(self.layout, payload)

    def decode_sum(self, gathered: Payload, n: int, d: Optional[int] = None) -> jax.Array:
        return self.base.decode_sum_bucketed(self.layout, gathered, n)

    def decode_sum_apply(self, gathered: Payload, n: int, d, h_server):
        return self.base.decode_sum_apply_bucketed(self.layout, gathered, n, h_server)

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        """Size-weighted mean of the per-leaf costs (honest accounting: the
        sparse operators' cost depends on each leaf's length)."""
        lay = self.layout
        return sum(
            self.base.bits_per_dim(s) * s for s in lay.sizes
        ) / max(lay.size, 1)

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        return self.base.memory_alpha(d)

    def compress_input(self, g: jax.Array, h: jax.Array) -> jax.Array:
        return self.base.compress_input(g, h)

    def next_memory(self, h, dhat, delta):
        if type(self.base).next_memory is not Compressor.next_memory:
            return self.base.next_memory(h, dhat, delta)  # e.g. EF residual
        if not self.carries_state:
            return h
        return h + self.base.bucketed_alpha(self.layout) * dhat

    def next_server_memory(self, h, dhat_mean):
        if type(self.base).next_server_memory is not Compressor.next_server_memory:
            return self.base.next_server_memory(h, dhat_mean)
        if not self.carries_state:
            return h
        return h + self.base.bucketed_alpha(self.layout) * dhat_mean

    def server_direction(self, h, dhat_mean):
        return self.base.server_direction(h, dhat_mean)


@functools.lru_cache(maxsize=None)
def bucketed_compressor(cfg, layout: BucketLayout) -> BucketedCompressor:
    """Cached ``(CompressionConfig, BucketLayout) -> BucketedCompressor`` —
    the bucketed analogue of the memoized ``CompressionConfig.make()``."""
    return BucketedCompressor(cfg.make(), layout)
