"""Compressor interface + the unified ``Payload`` wire format.

A :class:`Compressor` owns everything DIANA's Algorithm 1 needs to know about
one compression operator:

* the **wire format** — :meth:`compress` produces a :class:`Payload`, the one
  pytree-of-arrays container every transport (reference simulation, shard_map
  all-gather, Pallas kernels) moves and decodes;
* the **decode** — :meth:`decode` (one worker) and :meth:`decode_sum` (the
  server-side sum over gathered workers, overridable with a fused kernel);
* the **memory rule** — how the worker/server memories ``h_i`` / ``h`` evolve
  (:meth:`compress_input`, :meth:`next_memory`, :meth:`next_server_memory`,
  :meth:`server_direction`).  The base class implements the paper's
  ``h^{k+1} = h^k + alpha * dhat^k`` gated on :attr:`carries_state`; biased
  operators (top-k) override these hooks with error feedback.
* the **accounting** — :meth:`bits_per_dim` drives the communication-cost
  benchmarks and :func:`repro.core.compression.payload_bits_per_dim`.

All hooks operate on FLAT per-leaf f32 vectors; pytree plumbing, dtype casts
and sharding of the memories stay in :mod:`repro.core.diana`.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Payload", "Compressor", "payload_nbits"]


class Payload(NamedTuple):
    """The single wire format shared by every compressor and transport.

    A fixed-field NamedTuple (hence a jax pytree: jit/vmap/all_gather safe)
    where each compressor populates the fields its encoding needs and leaves
    the rest ``None`` (``None`` children flatten away, so gathered payloads
    carry no dead traffic):

    packed:   bit-packed codes — 2-bit ternary nibbles (ternary family) or
              sign+exponent codes (natural compression)
    scales:   per-block norm scales (ternary family)
    indices:  coordinate indices of a sparse payload (rand-k / top-k)
    values:   dense values (identity) or sparse coefficients (rand-k / top-k)
    """

    packed: Optional[jax.Array] = None
    scales: Optional[jax.Array] = None
    indices: Optional[jax.Array] = None
    values: Optional[jax.Array] = None

    def select(self, i) -> "Payload":
        """The ``i``-th worker's payload from a stacked/gathered payload."""
        return Payload(*(None if f is None else f[i] for f in self))


def payload_nbits(payload: Payload) -> int:
    """Container bits of one payload (upper bound on the logical wire cost)."""
    return sum(
        f.size * f.dtype.itemsize * 8 for f in payload if f is not None
    )


class Compressor:
    """Abstract compression operator behind the DIANA aggregation loop.

    Subclasses must implement :meth:`compress`, :meth:`decode` and
    :meth:`bits_per_dim`; everything else has a default.  Class attributes:

    name:           registry identifier
    unbiased:       ``E[decode(compress(x))] == x`` (enables the DIANA memory
                    loop and the paper's convergence theory)
    carries_state:  whether the worker memories ``h_i`` are live state (the
                    alpha-memory rule, or an error-feedback residual)
    use_kernel:     this instance routes its hot paths through Pallas kernels
                    (a capability the compressor itself advertises — consumers
                    never switch on an external flag)
    prefers_allreduce: the payload IS the dense vector and no state is
                    carried, so a distributed mean should lower to one fused
                    all-reduce (pmean) instead of gather + decode.  The
                    identity baseline sets this; the reference simulation
                    still sums sequentially, so identity (alone) is exempt
                    from the bitwise reference/distributed contract.
    """

    name: str = "abstract"
    unbiased: bool = True
    carries_state: bool = False
    use_kernel: bool = False
    prefers_allreduce: bool = False

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        """Encode a flat f32 vector ``delta`` into a :class:`Payload`."""
        raise NotImplementedError

    def decode(self, payload: Payload, d: int) -> jax.Array:
        """Decode ONE worker's payload back to a flat f32 vector of length d."""
        raise NotImplementedError

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        """``sum_i decode(payload_i)`` from a gathered payload (leading worker
        axis of size ``n`` on every field).

        Default: sequential accumulate in f32 — peak memory of one dense
        vector, and a deterministic summation order the distributed and
        reference paths share bitwise.  Kernel-backed compressors override
        this with a fused unpack+reduce.
        """
        acc = self.decode(gathered.select(0), d)
        for i in range(1, n):
            acc = acc + self.decode(gathered.select(i), d)
        return acc

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        """Logical wire cost per coordinate (``d`` = vector length, needed by
        sparse payloads whose relative cost depends on it)."""
        raise NotImplementedError

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        """Learning rate of the alpha-memory rule; 0 for memoryless."""
        return 0.0

    def compress_input(self, g: jax.Array, h: jax.Array) -> jax.Array:
        """What the worker encodes: the gradient difference ``g - h`` when the
        memory is live (Algorithm 1 line 5), else the gradient itself."""
        return g - h if self.carries_state else g

    def next_memory(self, h: jax.Array, dhat: jax.Array, delta: jax.Array) -> jax.Array:
        """Worker memory update ``h_i^{k+1}`` (Algorithm 1 line 6)."""
        if not self.carries_state:
            return h
        return h + self.memory_alpha(h.shape[-1]) * dhat

    def next_server_memory(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        """Server memory update ``h^{k+1}`` (Algorithm 1 line 9)."""
        if not self.carries_state:
            return h
        return h + self.memory_alpha(h.shape[-1]) * dhat_mean

    def server_direction(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        """The aggregated estimator ``ghat^k`` (Algorithm 1 line 8)."""
        return h + dhat_mean if self.carries_state else dhat_mean
