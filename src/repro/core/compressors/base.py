"""Compressor interface + the unified ``Payload`` wire format.

A :class:`Compressor` owns everything DIANA's Algorithm 1 needs to know about
one compression operator:

* the **wire format** — :meth:`compress` produces a :class:`Payload`, the one
  pytree-of-arrays container every transport (reference simulation, shard_map
  all-gather, Pallas kernels) moves and decodes;
* the **decode** — :meth:`decode` (one worker) and :meth:`decode_sum` (the
  server-side sum over gathered workers, overridable with a fused kernel);
* the **memory rule** — how the worker/server memories ``h_i`` / ``h`` evolve
  (:meth:`compress_input`, :meth:`next_memory`, :meth:`next_server_memory`,
  :meth:`server_direction`).  The base class implements the paper's
  ``h^{k+1} = h^k + alpha * dhat^k`` gated on :attr:`carries_state`; biased
  operators (top-k) override these hooks with error feedback.
* the **accounting** — :meth:`bits_per_dim` drives the communication-cost
  benchmarks and :func:`repro.core.compression.payload_bits_per_dim`.

All hooks operate on FLAT per-leaf f32 vectors; pytree plumbing, dtype casts
and sharding of the memories stay in :mod:`repro.core.diana`.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Payload", "Compressor", "payload_nbits", "index_dtype", "index_nbits"]


def index_dtype(d: int):
    """Narrowest unsigned integer dtype that can address ``d`` coordinates.

    Sparse payloads (rand-k / top-k) carry their coordinate indices in this
    dtype, so the wire cost of an index is 8/16/32 bits depending on the
    vector length instead of a flat 32.
    """
    if d <= (1 << 8):
        return jnp.uint8
    if d <= (1 << 16):
        return jnp.uint16
    return jnp.uint32


def index_nbits(d: int) -> int:
    """Wire bits of one coordinate index of a length-``d`` vector."""
    return jnp.dtype(index_dtype(d)).itemsize * 8


class Payload(NamedTuple):
    """The single wire format shared by every compressor and transport.

    A fixed-field NamedTuple (hence a jax pytree: jit/vmap/all_gather safe)
    where each compressor populates the fields its encoding needs and leaves
    the rest ``None`` (``None`` children flatten away, so gathered payloads
    carry no dead traffic):

    packed:   bit-packed codes — 2-bit ternary nibbles (ternary family) or
              sign+exponent codes (natural compression)
    scales:   per-block norm scales (ternary family)
    indices:  coordinate indices of a sparse payload (rand-k / top-k)
    values:   dense values (identity) or sparse coefficients (rand-k / top-k)
    """

    packed: Optional[jax.Array] = None
    scales: Optional[jax.Array] = None
    indices: Optional[jax.Array] = None
    values: Optional[jax.Array] = None

    def select(self, i) -> "Payload":
        """The ``i``-th worker's payload from a stacked/gathered payload."""
        return Payload(*(None if f is None else f[i] for f in self))

    def mask_workers(self, mask: jax.Array) -> "Payload":
        """Zero out non-participants in a GATHERED payload (leading worker
        axis on every field, ``mask`` a (n,) bool) so each excluded worker
        decodes to an EXACT zero vector and the unchanged ``decode_sum``
        recurrence sums only the participant set — the fixed-shape SPMD form
        of partial participation (repro.core.participation).

        One field per payload suffices, by the decode structure every
        registry operator shares: zero ``scales`` and the unpacked ternary
        signs multiply to zero; else zero ``values`` and the dense/scattered
        contribution is zero; else zero ``packed`` and natural compression's
        code 0 decodes to exactly 0.0.
        """

        def zero_rows(f):
            m = mask.reshape(mask.shape + (1,) * (f.ndim - mask.ndim))
            return jnp.where(m, f, jnp.zeros_like(f))

        if self.scales is not None:
            return self._replace(scales=zero_rows(self.scales))
        if self.values is not None:
            return self._replace(values=zero_rows(self.values))
        if self.packed is not None:
            return self._replace(packed=zero_rows(self.packed))
        return self


def payload_nbits(payload: Payload) -> int:
    """Container bits of one payload (upper bound on the logical wire cost)."""
    return sum(
        f.size * f.dtype.itemsize * 8 for f in payload if f is not None
    )


class Compressor:
    """Abstract compression operator behind the DIANA aggregation loop.

    Subclasses must implement :meth:`compress`, :meth:`decode` and
    :meth:`bits_per_dim`; everything else has a default.  Class attributes:

    name:           registry identifier
    unbiased:       ``E[decode(compress(x))] == x`` (enables the DIANA memory
                    loop and the paper's convergence theory)
    carries_state:  whether the worker memories ``h_i`` are live state (the
                    alpha-memory rule, or an error-feedback residual)
    use_kernel:     this instance routes its hot paths through Pallas kernels
                    (a capability the compressor itself advertises — consumers
                    never switch on an external flag)
    kernel_oracle:  ``"module::symbol"`` naming the pure-jnp interpret-mode
                    oracle its kernels are validated against (every concrete
                    operator must declare one — ``tools/check_kernels.py``
                    lints this and the tests import through it)
    prefers_allreduce: the payload IS the dense vector and no state is
                    carried, so a distributed mean should lower to one fused
                    all-reduce (pmean) instead of gather + decode.  The
                    identity baseline sets this; the reference simulation
                    still sums sequentially, so identity (alone) is exempt
                    from the bitwise reference/distributed contract.
    replicate_perleaf: the per-leaf encode must see a REPLICATED input under
                    partial-manual bodies with live auto inner axes: the
                    operator's selection lowers through ops (top_k's sort)
                    whose SPMD partitioning RET_CHECKs under manual
                    subgroups on old XLA (DESIGN.md §6).  The aggregation
                    loop pins such operators' compress input with an
                    explicit replication constraint (a no-op outside GSPMD
                    policies, so the reference path and nested-manual mode
                    are untouched — constraints never change values).
    """

    name: str = "abstract"
    unbiased: bool = True
    carries_state: bool = False
    use_kernel: bool = False
    kernel_oracle: Optional[str] = None
    prefers_allreduce: bool = False
    replicate_perleaf: bool = False

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        """Encode a flat f32 vector ``delta`` into a :class:`Payload`."""
        raise NotImplementedError

    def decode(self, payload: Payload, d: int) -> jax.Array:
        """Decode ONE worker's payload back to a flat f32 vector of length d."""
        raise NotImplementedError

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        """``sum_i decode(payload_i)`` from a gathered payload (leading worker
        axis of size ``n`` on every field).

        Default: sequential accumulate in f32 — peak memory of one dense
        vector, and a deterministic summation order the distributed and
        reference paths share bitwise.  Kernel-backed compressors override
        this with a fused unpack+reduce.
        """
        acc = self.decode(gathered.select(0), d)
        for i in range(1, n):
            acc = acc + self.decode(gathered.select(i), d)
        return acc

    def decode_sum_apply(
        self, gathered: Payload, n: int, d: int, h_server: jax.Array
    ):
        """The fused server tail: decode_sum, mean, direction and memory
        update in ONE hook — ``(ghat, new_h)`` with ``dm = decode_sum / n``,
        ``ghat = server_direction(h, dm)``, ``new_h = next_server_memory``.

        Default: the literal composition of the existing hooks (bitwise
        reference semantics).  Kernel-backed operators override this so the
        aggregated sum never round-trips HBM between decode and apply — the
        epilogue runs on the accumulator tile inside the decode kernel.
        """
        dm = self.decode_sum(gathered, n, d) / n
        return self.server_direction(h_server, dm), self.next_server_memory(
            h_server, dm
        )

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        """Logical wire cost per coordinate (``d`` = vector length, needed by
        sparse payloads whose relative cost depends on it)."""
        raise NotImplementedError

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        """Learning rate of the alpha-memory rule; 0 for memoryless."""
        return 0.0

    def compress_input(self, g: jax.Array, h: jax.Array) -> jax.Array:
        """What the worker encodes: the gradient difference ``g - h`` when the
        memory is live (Algorithm 1 line 5), else the gradient itself."""
        return g - h if self.carries_state else g

    def next_memory(self, h: jax.Array, dhat: jax.Array, delta: jax.Array) -> jax.Array:
        """Worker memory update ``h_i^{k+1}`` (Algorithm 1 line 6)."""
        if not self.carries_state:
            return h
        return h + self.memory_alpha(h.shape[-1]) * dhat

    def next_server_memory(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        """Server memory update ``h^{k+1}`` (Algorithm 1 line 9)."""
        if not self.carries_state:
            return h
        return h + self.memory_alpha(h.shape[-1]) * dhat_mean

    def server_direction(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        """The aggregated estimator ``ghat^k`` (Algorithm 1 line 8)."""
        return h + dhat_mean if self.carries_state else dhat_mean

    # ------------------------------------------------- bucketed (flat) hooks
    #
    # The bucketed pipeline (repro.core.bucket) runs the WHOLE model as one
    # flat buffer: one compress, one Payload, one all-gather, one decode_sum
    # per step.  These hooks define how an operator acts on that buffer given
    # its static `BucketLayout`.  The contract: the bucketed result is
    # BITWISE-equal to the per-leaf path, which the defaults guarantee by
    # re-deriving the per-leaf PRNG schedule (`split(key, n_leaves)`, segment
    # i draws with keys[i] — exactly what core.diana's per-leaf path does) and
    # reusing `compress`/`decode` per segment.  Operators override these with
    # fused single-call implementations that preserve the same draws and the
    # same f32 recurrences.

    def bucket_align(self) -> int:
        """Segment alignment of the flat layout: every leaf's segment is
        padded to a multiple of this.  Blocked operators return their block
        size so quantization blocks never straddle leaves (which keeps the
        per-block scales — and hence the whole wire format — identical to the
        per-leaf path); element-wise and sparse operators need no padding."""
        return 1

    def _segment_payloads(self, layout):
        """Static per-segment Payload shapes (via eval_shape on compress)."""
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return [
            jax.eval_shape(
                self.compress, jax.ShapeDtypeStruct((size,), jnp.float32), key
            )
            for size in layout.padded_sizes
        ]

    def compress_bucketed(self, layout, delta: jax.Array, key: jax.Array) -> Payload:
        """Encode the whole padded flat buffer ``delta`` into ONE Payload.

        Derives the per-leaf key schedule (``split(key, n_leaves)``) and
        delegates to :meth:`compress_bucketed_keys`; the chunked wire
        (repro.core.bucket.ChunkedSchedule) instead splits the MONOLITHIC
        schedule once and calls :meth:`compress_bucketed_keys` per chunk with
        its key slice, so chunking never re-splits keys."""
        keys = jax.random.split(key, layout.n_leaves)
        return self.compress_bucketed_keys(layout, delta, keys, key)

    def compress_bucketed_keys(
        self, layout, delta: jax.Array, keys: jax.Array,
        fallback_key: Optional[jax.Array] = None,
    ) -> Payload:
        """Encode ``delta`` given the explicit per-leaf key schedule ``keys``
        (one key per layout leaf, in leaf order).

        Generic fallback: per-segment :meth:`compress` with ``keys[i]``, every
        field concatenated along axis 0 (segment indices stay segment-local;
        :meth:`decode_bucketed` splits them back).  Correct for any operator,
        but per-segment work — fused overrides are where the
        single-kernel-launch win comes from.  ``fallback_key`` is the single
        whole-buffer key for overrides whose compiled kernels draw PRNG bits
        in-kernel (distribution-equal paths that cannot honour a per-leaf
        schedule); the chunked driver passes a per-chunk fold of the round
        key there.
        """
        del fallback_key  # the generic path honours the per-leaf schedule
        pays = [
            self.compress(seg, k)
            for seg, k in zip(layout.split_padded(delta), keys)
        ]
        fields = []
        for i in range(len(Payload._fields)):
            fs = [p[i] for p in pays]
            if any(f is None for f in fs):
                fields.append(None)
            else:
                fields.append(jnp.concatenate(fs, axis=0))
        return Payload(*fields)

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        """Decode ONE worker's bucketed payload to the padded flat buffer."""
        seg_shapes = self._segment_payloads(layout)
        offs = [0] * len(Payload._fields)
        outs = []
        for seg, size in zip(seg_shapes, layout.padded_sizes):
            parts = []
            for fi, f in enumerate(seg):
                if f is None:
                    parts.append(None)
                else:
                    n_i = f.shape[0]
                    parts.append(
                        jax.lax.slice_in_dim(payload[fi], offs[fi], offs[fi] + n_i, axis=0)
                    )
                    offs[fi] += n_i
            outs.append(self.decode(Payload(*parts), size))
        return jnp.concatenate(outs)

    def decode_sum_bucketed(self, layout, gathered: Payload, n: int) -> jax.Array:
        """``sum_i decode_bucketed(payload_i)`` over the gathered worker axis —
        the same sequential f32 recurrence as :meth:`decode_sum`, so the
        bucketed reference and distributed paths stay bitwise-aligned."""
        acc = self.decode_bucketed(layout, gathered.select(0))
        for i in range(1, n):
            acc = acc + self.decode_bucketed(layout, gathered.select(i))
        return acc

    def decode_sum_apply_bucketed(
        self, layout, gathered: Payload, n: int, h_server: jax.Array
    ):
        """Bucketed counterpart of :meth:`decode_sum_apply` on the padded flat
        buffer.  The default composes the bucketed hooks with the same memory
        dispatch as :class:`repro.core.bucket.BucketedCompressor`: an operator
        that overrides :meth:`next_server_memory` (error feedback) keeps its
        own rule, otherwise the alpha rule runs with :meth:`bucketed_alpha`
        (scalar or per-segment vector).  Kernel-backed operators override this
        with the fused decode+apply kernel."""
        dm = self.decode_sum_bucketed(layout, gathered, n) / n
        ghat = self.server_direction(h_server, dm)
        if type(self).next_server_memory is not Compressor.next_server_memory:
            return ghat, self.next_server_memory(h_server, dm)
        if not self.carries_state:
            return ghat, h_server
        return ghat, h_server + self.bucketed_alpha(layout) * dm

    def bucketed_alpha(self, layout):
        """Per-coordinate memory rate over the padded flat buffer.

        A scalar when the operator's alpha is d-independent (the common case,
        bitwise-identical to the per-leaf scalar multiply); a constant vector
        mapping each segment to ``memory_alpha(d_leaf)`` for operators like
        rand-k whose rate depends on the leaf length.
        """
        import numpy as np

        alphas = [self.memory_alpha(s) for s in layout.sizes]
        if len(set(alphas)) <= 1:
            return alphas[0] if alphas else 0.0
        return jnp.asarray(np.concatenate([
            np.full(ps, a, np.float32)
            for ps, a in zip(layout.padded_sizes, alphas)
        ]))
