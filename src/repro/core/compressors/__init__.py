"""Pluggable compression operators behind DIANA's aggregation loop.

``Payload`` is the single wire format; ``Compressor`` the interface; the
registry maps ``CompressionConfig.method`` strings (including the legacy
diana/qsgd/terngrad/dqgd/none aliases) to operator instances.
"""

from .base import Compressor, Payload, index_dtype, index_nbits, payload_nbits
from .identity import IdentityCompressor
from .natural import NaturalCompressor
from .randk import RandKCompressor
from .registry import (
    alias,
    available_methods,
    canonical_name,
    make_compressor,
    register,
)
from .ternary import TernaryCompressor
from .topk_ef import TopKEFCompressor

__all__ = [
    "Compressor", "Payload", "payload_nbits", "index_dtype", "index_nbits",
    "TernaryCompressor", "NaturalCompressor", "RandKCompressor",
    "TopKEFCompressor", "IdentityCompressor",
    "register", "alias", "make_compressor", "canonical_name", "available_methods",
]
