"""Top-k with error feedback — biased sparsification, residual-carried memory.

Transmits the ``k`` largest-magnitude coordinates of the error-corrected
gradient ``delta_i = g_i + e_i`` and keeps the untransmitted remainder as the
residual ``e_i^{k+1} = delta_i - dhat_i`` (EF-SGD / "memory-SGD", Stich et al.
2018).  Biased, so it lives OUTSIDE the paper's unbiased analysis — it reuses
the same ``h`` state slots as DIANA's memory but with the error-feedback
update rule, which is exactly why the memory semantics belong to the
compressor and not the aggregation loop.

Wire format: ``indices`` + ``values``, like rand-k but with NO ``d/k``
rescale (the selection is deterministic, rescaling would only add bias).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload

__all__ = ["TopKEFCompressor"]


class TopKEFCompressor(Compressor):
    name = "topk_ef"
    unbiased = False
    carries_state = True  # the EF residual

    def __init__(self, k: int):
        if k <= 0:
            raise ValueError(f"top-k needs k >= 1, got {k}")
        self.k = k

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        del key  # deterministic selection
        d = delta.shape[0]
        kk = min(self.k, d)
        _, idx = jax.lax.top_k(jnp.abs(delta), kk)
        idx = idx.astype(jnp.int32)
        return Payload(indices=idx, values=delta.astype(jnp.float32)[idx])

    def decode(self, payload: Payload, d: int) -> jax.Array:
        return jnp.zeros((d,), jnp.float32).at[payload.indices].add(payload.values)

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        if d is None:
            return 64.0
        return 64.0 * min(self.k, d) / d

    # ------------------------------------------------ error-feedback rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        return 1.0  # the residual is carried in full, not alpha-averaged

    def compress_input(self, g: jax.Array, h: jax.Array) -> jax.Array:
        return g + h  # error-corrected gradient

    def next_memory(self, h: jax.Array, dhat: jax.Array, delta: jax.Array) -> jax.Array:
        return delta - dhat  # what top-k dropped this round

    def next_server_memory(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        return h  # no server-side memory in EF

    def server_direction(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        return dhat_mean
