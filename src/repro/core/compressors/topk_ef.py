"""Top-k with error feedback — biased sparsification, residual-carried memory.

Transmits the ``k`` largest-magnitude coordinates of the error-corrected
gradient ``delta_i = g_i + e_i`` and keeps the untransmitted remainder as the
residual ``e_i^{k+1} = delta_i - dhat_i`` (EF-SGD / "memory-SGD", Stich et al.
2018).  Biased, so it lives OUTSIDE the paper's unbiased analysis — it reuses
the same ``h`` state slots as DIANA's memory but with the error-feedback
update rule, which is exactly why the memory semantics belong to the
compressor and not the aggregation loop.

Wire format: ``indices`` + ``values``, like rand-k but with NO ``d/k``
rescale (the selection is deterministic, rescaling would only add bias).
Indices use the narrowest unsigned dtype covering ``d`` (8/16/32 bits).

Kernel capability: selection stays in lax (the sort partitioning story is the
whole reason ``_select_topk_sortfree`` exists); with ``use_kernel=True`` the
value gather and the scatter-add ``decode_sum`` run through the shared sparse
Pallas kernels with a unit scale vector (``x * 1.0 == x`` exactly, so the
payloads and decodes stay bitwise-equal to the fallback).  The server tail is
the MEAN rule — EF has no server memory — so ``decode_sum_apply`` fuses only
the divide.  Interpret-contract only; auto resolves to off (see
:mod:`repro.kernels.sparse`).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload, index_dtype, index_nbits

__all__ = ["TopKEFCompressor"]


def _select_topk_sortfree(absx: jax.Array, kk: int) -> jax.Array:
    """Indices of the ``kk`` largest entries of a non-negative f32 vector,
    WITHOUT lax.sort/top_k — reductions, cumsums and one scatter only, all of
    which partition cleanly under partial-manual bodies where XLA's sort
    partitioner fatally RET_CHECKs (old XLA + live auto axes, DESIGN.md §6).

    Exact-set contract with ``lax.top_k(absx, kk)``: non-negative f32 values
    order identically to their uint32 bit patterns, so a 33-step bisection
    over the bit space finds exactly the kk-th largest VALUE (the count
    function only changes at data values); everything strictly above it is
    taken, and ties at the threshold are taken in ascending index order —
    the same tie-breaking lax.top_k's stable sort applies.  Only the output
    ORDER differs (ascending index vs descending value), which scatter-add
    decoding cannot observe.  Assumes no NaNs (a NaN gradient has already
    lost; lax.top_k's NaN ordering is garbage too).
    """
    d = absx.shape[0]
    bits = jax.lax.bitcast_convert_type(absx.astype(jnp.float32), jnp.uint32)

    def bisect(_, lohi):
        lo, hi = lohi  # invariant: count(bits >= lo) >= kk > count(bits >= hi)
        mid = lo + (hi - lo) // 2
        ok = jnp.sum((bits >= mid).astype(jnp.int32)) >= kk
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    thr, _ = jax.lax.fori_loop(
        0, 33, bisect, (jnp.uint32(0), jnp.uint32(0xFFFFFFFF)))
    gt = bits > thr
    eq = bits == thr
    take_eq = kk - jnp.sum(gt.astype(jnp.int32))
    sel = gt | (eq & (jnp.cumsum(eq.astype(jnp.int32)) <= take_eq))
    pos = jnp.cumsum(sel.astype(jnp.int32)) - 1
    # Ascending-index enumeration of the selected coordinates: unselected
    # entries scatter into the kk-th slot of a (kk+1,) scratch and fall off.
    tgt = jnp.where(sel, pos, kk)
    idx = jnp.zeros((kk + 1,), jnp.int32).at[tgt].set(
        jnp.arange(d, dtype=jnp.int32))
    return idx[:kk]


class TopKEFCompressor(Compressor):
    name = "topk_ef"
    unbiased = False
    carries_state = True  # the EF residual
    kernel_oracle = "repro.kernels.ref::ref_sparse_decode_sum"
    replicate_perleaf = True  # top_k's sort RET_CHECKs old XLA's partitioner
                              # on sharded operands under manual subgroups

    def __init__(self, k: int, *, use_kernel: Optional[bool] = None):
        if k <= 0:
            raise ValueError(f"top-k needs k >= 1, got {k}")
        self.k = k
        # Sparse kernels are interpret-contract only: auto resolves to off.
        self.use_kernel = bool(use_kernel) if use_kernel is not None else False

    def _gather(self, x: jax.Array, idx: jax.Array) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops as _kops

            return _kops.sparse_gather_op(x, idx)
        return x[idx]

    def _ones(self, kk: int) -> jax.Array:
        return jnp.ones((kk,), jnp.float32)

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        del key  # deterministic selection
        d = delta.shape[0]
        kk = min(self.k, d)
        absd = jnp.abs(delta)
        from repro.models.sharding import GSPMDPolicy, current_policy

        if isinstance(current_policy(), GSPMDPolicy):
            # Inside a partial-manual trainer body lax.top_k cannot be used:
            # XLA's sort partitioner fatally RET_CHECKs under manual
            # subgroups with live auto axes (old XLA, DESIGN.md §6).  The
            # sort-free threshold selection picks the IDENTICAL coordinate
            # set (ties included — see _select_topk_sortfree), so the decoded
            # dhat, the EF residual and every downstream bit are unchanged;
            # only the wire ordering of the index/value pairs differs
            # (ascending index instead of descending value), which nothing
            # decodes order-dependently (scatter-add over unique indices).
            idx = _select_topk_sortfree(absd, kk)
        else:
            _, idx = jax.lax.top_k(absd, kk)
        idx = idx.astype(index_dtype(d))
        return Payload(indices=idx, values=self._gather(delta.astype(jnp.float32), idx))

    def decode(self, payload: Payload, d: int) -> jax.Array:
        return jnp.zeros((d,), jnp.float32).at[payload.indices].add(payload.values)

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        if not self.use_kernel:
            return super().decode_sum(gathered, n, d)
        from repro.kernels import ops as _kops

        kk = gathered.values.shape[-1]
        return _kops.sparse_decode_sum_op(
            gathered.indices, gathered.values, self._ones(kk), d=d
        )

    def decode_sum_apply(self, gathered: Payload, n: int, d: int, h_server):
        if not self.use_kernel:
            return super().decode_sum_apply(gathered, n, d, h_server)
        from repro.kernels import ops as _kops

        kk = gathered.values.shape[-1]
        ghat = _kops.sparse_decode_sum_mean_op(
            gathered.indices, gathered.values, self._ones(kk), d=d
        )
        return ghat, h_server  # EF: server memory is a no-op

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        if d is None:
            return 64.0
        return float(32 + index_nbits(d)) * min(self.k, d) / d

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed_keys(self, layout, delta: jax.Array,
                               keys: jax.Array, fallback_key=None) -> Payload:
        """Per-segment top-k (deterministic, cheap local selections) fused
        into ONE global-coordinate payload; the error-feedback memory hooks
        are elementwise and run on the flat buffer unchanged."""
        del keys, fallback_key  # deterministic selection
        x = delta.astype(jnp.float32)
        parts = []
        for off, d in zip(layout.offsets, layout.sizes):
            seg = jax.lax.slice_in_dim(x, off, off + d)
            _, idx = jax.lax.top_k(jnp.abs(seg), min(self.k, d))
            parts.append(jnp.int32(off) + idx.astype(jnp.int32))
        gidx = jnp.concatenate(parts).astype(index_dtype(layout.padded_size))
        return Payload(indices=gidx, values=self._gather(x, gidx))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return jnp.zeros(
            (layout.padded_size,), jnp.float32
        ).at[payload.indices].add(payload.values)

    def decode_sum_bucketed(self, layout, gathered: Payload, n: int) -> jax.Array:
        if not self.use_kernel:
            return super().decode_sum_bucketed(layout, gathered, n)
        return self.decode_sum(gathered, n, layout.padded_size)

    def decode_sum_apply_bucketed(self, layout, gathered, n, h_server):
        if not self.use_kernel:
            return super().decode_sum_apply_bucketed(layout, gathered, n, h_server)
        return self.decode_sum_apply(gathered, n, layout.padded_size, h_server)

    # ------------------------------------------------ error-feedback rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        return 1.0  # the residual is carried in full, not alpha-averaged

    def compress_input(self, g: jax.Array, h: jax.Array) -> jax.Array:
        return g + h  # error-corrected gradient

    def next_memory(self, h: jax.Array, dhat: jax.Array, delta: jax.Array) -> jax.Array:
        return delta - dhat  # what top-k dropped this round

    def next_server_memory(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        return h  # no server-side memory in EF

    def server_direction(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        return dhat_mean
