"""Top-k with error feedback — biased sparsification, residual-carried memory.

Transmits the ``k`` largest-magnitude coordinates of the error-corrected
gradient ``delta_i = g_i + e_i`` and keeps the untransmitted remainder as the
residual ``e_i^{k+1} = delta_i - dhat_i`` (EF-SGD / "memory-SGD", Stich et al.
2018).  Biased, so it lives OUTSIDE the paper's unbiased analysis — it reuses
the same ``h`` state slots as DIANA's memory but with the error-feedback
update rule, which is exactly why the memory semantics belong to the
compressor and not the aggregation loop.

Wire format: ``indices`` + ``values``, like rand-k but with NO ``d/k``
rescale (the selection is deterministic, rescaling would only add bias).
Indices use the narrowest unsigned dtype covering ``d`` (8/16/32 bits).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload, index_dtype, index_nbits

__all__ = ["TopKEFCompressor"]


class TopKEFCompressor(Compressor):
    name = "topk_ef"
    unbiased = False
    carries_state = True  # the EF residual

    def __init__(self, k: int):
        if k <= 0:
            raise ValueError(f"top-k needs k >= 1, got {k}")
        self.k = k

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        del key  # deterministic selection
        d = delta.shape[0]
        kk = min(self.k, d)
        _, idx = jax.lax.top_k(jnp.abs(delta), kk)
        idx = idx.astype(index_dtype(d))
        return Payload(indices=idx, values=delta.astype(jnp.float32)[idx])

    def decode(self, payload: Payload, d: int) -> jax.Array:
        return jnp.zeros((d,), jnp.float32).at[payload.indices].add(payload.values)

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        if d is None:
            return 64.0
        return float(32 + index_nbits(d)) * min(self.k, d) / d

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed(self, layout, delta: jax.Array, key: jax.Array) -> Payload:
        """Per-segment top-k (deterministic, cheap local selections) fused
        into ONE global-coordinate payload; the error-feedback memory hooks
        are elementwise and run on the flat buffer unchanged."""
        del key
        x = delta.astype(jnp.float32)
        parts = []
        for off, d in zip(layout.offsets, layout.sizes):
            seg = jax.lax.slice_in_dim(x, off, off + d)
            _, idx = jax.lax.top_k(jnp.abs(seg), min(self.k, d))
            parts.append(jnp.int32(off) + idx.astype(jnp.int32))
        gidx = jnp.concatenate(parts).astype(index_dtype(layout.padded_size))
        return Payload(indices=gidx, values=x[gidx])

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return jnp.zeros(
            (layout.padded_size,), jnp.float32
        ).at[payload.indices].add(payload.values)

    # ------------------------------------------------ error-feedback rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        return 1.0  # the residual is carried in full, not alpha-averaged

    def compress_input(self, g: jax.Array, h: jax.Array) -> jax.Array:
        return g + h  # error-corrected gradient

    def next_memory(self, h: jax.Array, dhat: jax.Array, delta: jax.Array) -> jax.Array:
        return delta - dhat  # what top-k dropped this round

    def next_server_memory(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        return h  # no server-side memory in EF

    def server_direction(self, h: jax.Array, dhat_mean: jax.Array) -> jax.Array:
        return dhat_mean
