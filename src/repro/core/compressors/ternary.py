"""Ternary block p-quantization (paper Def. 1/2) — DIANA's native operator.

Wire format: 2-bit sign codes (4/byte, :mod:`repro.core.packing`) + one f32
``||.||_p`` scale per block — ``2 + 32/B`` bits/dim.

Kernel capability: with ``use_kernel=True`` the instance advertises and uses
the Pallas hot paths — ``quantize_pack`` (fused quantize + bit-pack, one
HBM->VMEM pass) on encode and ``unpack_reduce`` (streaming decode+accumulate
over workers, DESIGN.md §2) on :meth:`decode_sum`.  The pure-jnp fallbacks
remain the oracles; ``tests/test_compressors.py`` asserts the kernel
``decode_sum`` is bitwise-equal to the fallback loop under ``interpret=True``.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..packing import pack2bit, unpack2bit
from ..quantization import (
    alpha_p,
    num_blocks,
    pad_to_blocks,
    quantize_blocks,
    quantize_blocks_from_uniform,
    uniform_from_bits,
)
from .base import Compressor, Payload

__all__ = ["TernaryCompressor"]


class TernaryCompressor(Compressor):
    """Block p-quantization with optional DIANA memory and Pallas kernels.

    memory=True  -> the paper's DIANA (compress gradient differences,
                    alpha-memory with the Corollary-1 default alpha_p/2)
    memory=False -> Algorithm 2: QSGD (p=2) / TernGrad (p=inf) / DQGD.
    """

    name = "ternary"
    unbiased = True
    kernel_oracle = "repro.kernels.ref::ref_quantize_pack"

    def __init__(
        self,
        *,
        p: float = math.inf,
        block_size: int = 2048,
        alpha: Optional[float] = None,
        memory: bool = True,
        use_kernel: Optional[bool] = None,
    ):
        if block_size % 4:
            raise ValueError("block_size must be a multiple of 4 for 2-bit packing")
        self.p = p
        self.block_size = block_size
        self.alpha = alpha
        self.carries_state = memory
        # Capability, not an external switch: kernels are advertised by the
        # compressor itself.  None = auto (compiled Mosaic on TPU; the slow
        # interpret=True path is opted into explicitly on CPU).  The kernels
        # require VPU-lane-aligned blocks, so auto only engages when the
        # block size qualifies — small research block sizes stay on jnp.
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu" and block_size % 128 == 0
        self.use_kernel = use_kernel

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        if self.use_kernel:
            from repro.kernels import ops as _kops

            blocks = pad_to_blocks(delta.astype(jnp.float32), self.block_size)
            if _kops.default_interpret():
                bits = jax.random.bits(key, blocks.shape, dtype=jnp.uint32)
                packed, scales = _kops.quantize_pack_op(blocks, bits, p=self.p)
            else:
                # Compiled TPU path: the Bernoulli bits are drawn INSIDE the
                # kernel (pltpu.prng_random_bits), so the uint32 bits operand
                # — 4 bytes/dim of pure HBM input traffic — never exists.
                packed, scales = _kops.quantize_pack_prng_op(blocks, key, p=self.p)
            return Payload(packed=packed, scales=scales[:, 0])
        q = quantize_blocks(delta, key, p=self.p, block_size=self.block_size)
        return Payload(packed=pack2bit(q.signs), scales=q.scales)

    def decode(self, payload: Payload, d: int) -> jax.Array:
        signs = unpack2bit(payload.packed).astype(jnp.float32)      # (m, B)
        dense = signs * payload.scales[:, None].astype(jnp.float32)
        return dense.reshape(-1)[:d]

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        """Fused one-pass accumulate over workers (kernel), or the statically
        unrolled loop (fallback — also required inside nested-manual
        shard_map bodies where dynamic slicing over the gathered worker dim
        trips the SPMD partitioner, DESIGN.md §6).  Both run the identical
        f32 ``acc += signs_i * scale_i`` recurrence, so they are
        bitwise-equal and interchangeable step to step."""
        from repro.models.sharding import shard

        packed, scales = gathered.packed, gathered.scales           # (n,m,B/4), (n,m)
        if self.use_kernel:
            from repro.kernels import ops as _kops

            acc = _kops.unpack_reduce_op(packed, scales[..., None])  # (m, B)
            acc = shard(acc, "model", None)
        else:
            m, bs4 = packed.shape[-2], packed.shape[-1]
            acc = shard(jnp.zeros((m, bs4 * 4), jnp.float32), "model", None)
            for i in range(n):
                signs = unpack2bit(packed[i]).astype(jnp.float32)   # (m, B)
                acc = acc + signs * scales[i][:, None].astype(jnp.float32)
        return acc.reshape(-1)[:d]

    def decode_sum_apply(self, gathered: Payload, n: int, d: int, h_server):
        """Fused decode_sum + server update: ONE ``unpack_reduce_apply`` (or
        ``_mean``) launch whose epilogue runs DIANA's memory rule on the
        accumulator tile — the aggregated ghat never round-trips HBM between
        decode and apply.  Bitwise-equal to the hook composition (same
        accumulate recurrence, same jitted FMA contraction of ``h + a*dm``)."""
        if not self.use_kernel:
            return super().decode_sum_apply(gathered, n, d, h_server)
        from repro.kernels import ops as _kops
        from repro.models.sharding import shard

        packed, scales = gathered.packed, gathered.scales
        if self.carries_state:
            ghat, newh = _kops.unpack_reduce_apply_op(
                packed, scales[..., None], h_server,
                alpha=self.memory_alpha(d),
            )
            return shard(ghat, "model"), shard(newh, "model")
        acc = _kops.unpack_reduce_mean_op(packed, scales[..., None])
        ghat = shard(acc, "model", None).reshape(-1)[:d]
        return ghat, h_server

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        return 2.0 + 32.0 / self.block_size

    # ------------------------------------------------- bucketed (flat) path

    def bucket_align(self) -> int:
        """Segments align to the quantization block, so every block of the
        flat buffer belongs to exactly one leaf and the per-block scales are
        identical to the per-leaf path's (bitwise wire-format equality)."""
        return self.block_size

    def _batched_bits(self, keys: jax.Array, seg_rows) -> list:
        """Per-segment uint32 Bernoulli bit matrices, drawn in row-count
        batches: segments with the same block-row count ``m`` are vmapped
        over their stacked keys in ONE ``jax.random.bits`` call.  Threefry is
        counter-mode, so the batched draw is bit-for-bit the per-key calls —
        it just amortises the per-call hash setup, which dominates the
        bucketed compress at small model sizes (the same per-leaf-PRNG
        overhead PR 6 removed from rand-k's subset draws)."""
        out = [None] * len(seg_rows)
        groups: dict = {}
        for i, m in enumerate(seg_rows):
            groups.setdefault(m, []).append(i)
        for m, idxs in groups.items():
            if len(idxs) == 1:
                i = idxs[0]
                out[i] = jax.random.bits(
                    keys[i], (m, self.block_size), dtype=jnp.uint32)
                continue
            stacked = jnp.stack([keys[i] for i in idxs])
            draws = jax.vmap(
                lambda k: jax.random.bits(k, (m, self.block_size),
                                          dtype=jnp.uint32)
            )(stacked)
            for j, i in enumerate(idxs):
                out[i] = draws[j]
        return out

    def compress_bucketed_keys(self, layout, delta: jax.Array,
                               keys: jax.Array, fallback_key=None) -> Payload:
        """ONE fused quantize+pack over the (chunk of the) block matrix.

        The per-leaf PRNG schedule is preserved exactly: segment ``i`` draws
        its bits/uniforms from ``keys[i]`` over its own padded block rows —
        the same draws the per-leaf path makes — and the single kernel launch
        (or vectorized jnp quantization) consumes the concatenation.  On
        compiled TPU the bits are instead drawn in-kernel from
        ``fallback_key`` (one PRNG stream for the whole buffer):
        distribution-equal, bitwise only within that mode.
        """
        blocks = delta.astype(jnp.float32).reshape(-1, self.block_size)
        seg_rows = [ps // self.block_size for ps in layout.padded_sizes]
        if self.use_kernel:
            from repro.kernels import ops as _kops

            if _kops.default_interpret():
                bits = jnp.concatenate(self._batched_bits(keys, seg_rows))
                packed, scales = _kops.quantize_pack_op(blocks, bits, p=self.p)
            else:
                if fallback_key is None:
                    fallback_key = keys[0]
                packed, scales = _kops.quantize_pack_prng_op(
                    blocks, fallback_key, p=self.p)
            return Payload(packed=packed, scales=scales[:, 0])
        # jnp path: quantize per segment and concatenate only the 2-bit wire
        # format (16x smaller than the f32 intermediates) — XLA then fuses
        # each segment's quantize+pack like the per-leaf path does, instead
        # of materialising whole-model f32 buffers.  Per-block independence
        # makes this bitwise-identical to one fused call.  Only the PRNG
        # draws are batched (``_batched_bits``): a fully fused whole-buffer
        # quantize measured SLOWER than the per-segment fusions.
        seg_bits = self._batched_bits(keys, seg_rows)
        packed_parts, scale_parts = [], []
        row = 0
        for bits, m in zip(seg_bits, seg_rows):
            seg = jax.lax.slice_in_dim(blocks, row, row + m)
            row += m
            q = quantize_blocks_from_uniform(seg, uniform_from_bits(bits), p=self.p)
            packed_parts.append(pack2bit(q.signs))
            scale_parts.append(q.scales)
        return Payload(packed=jnp.concatenate(packed_parts),
                       scales=jnp.concatenate(scale_parts))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return self.decode(payload, layout.padded_size)

    def decode_sum_bucketed(self, layout, gathered: Payload, n: int) -> jax.Array:
        """ONE ``unpack_reduce`` launch (or one unrolled accumulate) over the
        whole model — the per-step decode cost the ISSUE's motivation counts."""
        return self.decode_sum(gathered, n, layout.padded_size)

    def decode_sum_apply_bucketed(self, layout, gathered, n, h_server):
        """The bucketed flat buffer is block-aligned, so the per-leaf fused
        kernel applies verbatim; alpha is block-size-determined and therefore
        uniform across segments (``bucketed_alpha`` is the same scalar)."""
        return self.decode_sum_apply(gathered, n, layout.padded_size, h_server)

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        if not self.carries_state:
            return 0.0
        if self.alpha is not None:
            return self.alpha
        return alpha_p(self.p, self.block_size) / 2.0  # Corollary 1
