"""Rand-k sparsification — unbiased random coordinate subsampling.

Picks ``k`` coordinates uniformly without replacement and rescales by ``d/k``,
giving the unbiased estimator ``(d/k) * sum_{j in S} x_j e_j`` with variance
bound ``omega = d/k - 1``.  Wire format: ``indices`` (int32) + ``values``
(f32) — ``64k/d`` bits/dim.

The values travel UNscaled; the ``d/k`` correction is applied at decode where
``d`` is known, so the same payload is valid for any transport.  Default
memory rate ``alpha = 1/(1 + omega) = k/d`` (per leaf) plugs the operator into
DIANA's memory loop as in Horvath et al. 2019 (arXiv:1904.05115).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload

__all__ = ["RandKCompressor"]


class RandKCompressor(Compressor):
    name = "randk"
    unbiased = True

    def __init__(self, k: int, *, alpha: Optional[float] = None, memory: bool = True):
        if k <= 0:
            raise ValueError(f"rand-k needs k >= 1, got {k}")
        self.k = k
        self.alpha = alpha
        self.carries_state = memory

    def _k(self, d: int) -> int:
        return min(self.k, d)

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        d = delta.shape[0]
        idx = jax.random.choice(key, d, (self._k(d),), replace=False)
        idx = idx.astype(jnp.int32)
        return Payload(indices=idx, values=delta.astype(jnp.float32)[idx])

    def decode(self, payload: Payload, d: int) -> jax.Array:
        kk = payload.values.shape[-1]
        scaled = payload.values * jnp.float32(d / kk)
        return jnp.zeros((d,), jnp.float32).at[payload.indices].add(scaled)

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        if d is None:
            return 64.0  # per transmitted coordinate (index + value)
        return 64.0 * self._k(d) / d

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        if not self.carries_state:
            return 0.0
        if self.alpha is not None:
            return self.alpha
        if d is None:
            return 1.0
        return self._k(d) / d  # 1 / (1 + omega), omega = d/k - 1
