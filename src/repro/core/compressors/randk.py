"""Rand-k sparsification — unbiased random coordinate subsampling.

Picks ``k`` coordinates uniformly without replacement and rescales by ``d/k``,
giving the unbiased estimator ``(d/k) * sum_{j in S} x_j e_j`` with variance
bound ``omega = d/k - 1``.  Wire format: ``indices`` (the narrowest unsigned
integer dtype that covers ``d`` — 8/16/32 bits) + ``values`` (f32), i.e.
``(32 + index_bits(d)) * k / d`` bits/dim.

The values travel UNscaled; the ``d/k`` correction is applied at decode where
``d`` is known, so the same payload is valid for any transport.  Default
memory rate ``alpha = 1/(1 + omega) = k/d`` (per leaf) plugs the operator into
DIANA's memory loop as in Horvath et al. 2019 (arXiv:1904.05115).

Bucketed path: one payload for the whole model — per-segment index draws with
the per-leaf key schedule, offset into global coordinates, decoded by a
SINGLE scatter-add with a static per-entry ``d_leaf/k_leaf`` scale vector
(bitwise the same f32 products and disjoint adds as the per-leaf decodes).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import Compressor, Payload, index_dtype, index_nbits

__all__ = ["RandKCompressor"]


class RandKCompressor(Compressor):
    name = "randk"
    unbiased = True

    def __init__(self, k: int, *, alpha: Optional[float] = None, memory: bool = True):
        if k <= 0:
            raise ValueError(f"rand-k needs k >= 1, got {k}")
        self.k = k
        self.alpha = alpha
        self.carries_state = memory

    def _k(self, d: int) -> int:
        return min(self.k, d)

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        d = delta.shape[0]
        idx = jax.random.choice(key, d, (self._k(d),), replace=False)
        idx = idx.astype(index_dtype(d))
        return Payload(indices=idx, values=delta.astype(jnp.float32)[idx])

    def decode(self, payload: Payload, d: int) -> jax.Array:
        kk = payload.values.shape[-1]
        scaled = payload.values * jnp.float32(d / kk)
        return jnp.zeros((d,), jnp.float32).at[payload.indices].add(scaled)

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        if d is None:
            return 64.0  # per transmitted coordinate (32-bit index + value bound)
        return float(32 + index_nbits(d)) * self._k(d) / d

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed(self, layout, delta: jax.Array, key: jax.Array) -> Payload:
        keys = jax.random.split(key, layout.n_leaves)
        parts = []
        for k, off, d in zip(keys, layout.offsets, layout.sizes):
            idx = jax.random.choice(k, d, (self._k(d),), replace=False)
            parts.append(jnp.int32(off) + idx.astype(jnp.int32))
        gidx = jnp.concatenate(parts).astype(index_dtype(layout.padded_size))
        return Payload(indices=gidx, values=delta.astype(jnp.float32)[gidx])

    def _bucket_scales(self, layout) -> jax.Array:
        """Static per-entry decode scale: ``d_leaf / k_leaf`` for each kept
        coordinate — the same f32 factor the per-leaf decode multiplies by."""
        return jnp.asarray(np.concatenate([
            np.full(self._k(d), np.float32(d / self._k(d)), np.float32)
            for d in layout.sizes
        ]))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        scaled = payload.values * self._bucket_scales(layout)
        return jnp.zeros(
            (layout.padded_size,), jnp.float32
        ).at[payload.indices].add(scaled)

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        if not self.carries_state:
            return 0.0
        if self.alpha is not None:
            return self.alpha
        if d is None:
            return 1.0
        return self._k(d) / d  # 1 / (1 + omega), omega = d/k - 1
