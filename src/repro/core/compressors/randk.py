"""Rand-k sparsification — unbiased random coordinate subsampling.

Picks ``k`` coordinates uniformly without replacement and rescales by ``d/k``,
giving the unbiased estimator ``(d/k) * sum_{j in S} x_j e_j`` with variance
bound ``omega = d/k - 1``.  Wire format: ``indices`` (the narrowest unsigned
integer dtype that covers ``d`` — 8/16/32 bits) + ``values`` (f32), i.e.
``(32 + index_bits(d)) * k / d`` bits/dim.

The values travel UNscaled; the ``d/k`` correction is applied at decode where
``d`` is known, so the same payload is valid for any transport.  Default
memory rate ``alpha = 1/(1 + omega) = k/d`` (per leaf) plugs the operator into
DIANA's memory loop as in Horvath et al. 2019 (arXiv:1904.05115).

Subset selection is ``top_k`` over iid uint32 tags (:func:`_uniform_subset`)
— any tie-free random total order induces a uniform k-subset, so the
estimator is unchanged, but ``top_k``'s partial-sort lowering is ~2.4x
cheaper than ``jax.random.choice``'s argsort-of-permutation.  This is what
fixed the bucketed rand-k regression: index derivation was the per-leaf cost
BOTH paths re-pay (the schedule is the bitwise contract), and shrinking it
exposes the bucketed path's structural advantage (one gather, one scatter,
one concat for the whole model instead of one per leaf).

Bucketed path: one payload for the whole model — per-segment index draws with
the per-leaf key schedule, offset into global coordinates, decoded by a
SINGLE scatter-add with a static per-entry ``d_leaf/k_leaf`` scale vector
(bitwise the same f32 products and disjoint adds as the per-leaf decodes).

Kernel capability: selection stays in lax (it owns the PRNG schedule — see
:mod:`repro.kernels.sparse` for the fusion-boundary rationale); with
``use_kernel=True`` the value gather and the scatter-add ``decode_sum`` (plus
the fused ``/n`` in the memoryless mean) run as Pallas kernels, while the
DIANA memory tail composes outside the kernel from the materialised sum (the
FMA-contraction contract, kernels/sparse.py).  They are interpret-contract
only (portable Mosaic scatter is future work), so auto resolves to OFF.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import Compressor, Payload, index_dtype, index_nbits

__all__ = ["RandKCompressor"]


def _uniform_subset(key: jax.Array, d: int, k: int) -> jax.Array:
    """A uniform random k-subset of ``range(d)`` as the indices of the ``k``
    largest of ``d`` iid uint32 tags (int32 indices, order randomized by the
    tags).  Equivalent in distribution to ``choice(replace=False)`` — ties
    occur w.p. < d^2 / 2^33 and only ever locally reorder the selection —
    at a fraction of its argsort-based cost."""
    tags = jax.random.bits(key, (d,), dtype=jnp.uint32)
    _, idx = jax.lax.top_k(tags, k)
    return idx


class RandKCompressor(Compressor):
    name = "randk"
    unbiased = True
    kernel_oracle = "repro.kernels.ref::ref_sparse_decode_sum"

    def __init__(
        self,
        k: int,
        *,
        alpha: Optional[float] = None,
        memory: bool = True,
        use_kernel: Optional[bool] = None,
    ):
        if k <= 0:
            raise ValueError(f"rand-k needs k >= 1, got {k}")
        self.k = k
        self.alpha = alpha
        self.carries_state = memory
        # Sparse kernels are interpret-contract only: auto resolves to off.
        self.use_kernel = bool(use_kernel) if use_kernel is not None else False

    def _k(self, d: int) -> int:
        return min(self.k, d)

    def _gather(self, delta: jax.Array, idx: jax.Array) -> jax.Array:
        if self.use_kernel:
            from repro.kernels import ops as _kops

            return _kops.sparse_gather_op(delta.astype(jnp.float32), idx)
        return delta.astype(jnp.float32)[idx]

    # ---------------------------------------------------------------- wire

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        d = delta.shape[0]
        idx = _uniform_subset(key, d, self._k(d)).astype(index_dtype(d))
        return Payload(indices=idx, values=self._gather(delta, idx))

    def decode(self, payload: Payload, d: int) -> jax.Array:
        kk = payload.values.shape[-1]
        scaled = payload.values * jnp.float32(d / kk)
        return jnp.zeros((d,), jnp.float32).at[payload.indices].add(scaled)

    def _scale(self, d: int, kk: int) -> jax.Array:
        # Vector operand form of the scalar d/k correction: a full() vector
        # multiplies bitwise-identically to the scalar broadcast.
        return jnp.full((kk,), jnp.float32(d / kk))

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        if not self.use_kernel:
            return super().decode_sum(gathered, n, d)
        from repro.kernels import ops as _kops

        kk = gathered.values.shape[-1]
        return _kops.sparse_decode_sum_op(
            gathered.indices, gathered.values, self._scale(d, kk), d=d
        )

    def decode_sum_apply(self, gathered: Payload, n: int, d: int, h_server):
        if not self.use_kernel or self.carries_state:
            # With memory, the base composition runs over the KERNEL
            # decode_sum (super() dispatches back through this class): the
            # ``h + alpha*dm`` tail must consume a materialised sum so its
            # fusion — and hence FMA contraction — is the fallback's own
            # (see kernels/sparse.py).
            return super().decode_sum_apply(gathered, n, d, h_server)
        from repro.kernels import ops as _kops

        kk = gathered.values.shape[-1]
        ghat = _kops.sparse_decode_sum_mean_op(
            gathered.indices, gathered.values, self._scale(d, kk), d=d
        )
        return ghat, h_server

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        if d is None:
            return 64.0  # per transmitted coordinate (32-bit index + value bound)
        return float(32 + index_nbits(d)) * self._k(d) / d

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed_keys(self, layout, delta: jax.Array,
                               keys: jax.Array, fallback_key=None) -> Payload:
        del fallback_key  # subset draws honour the per-leaf schedule
        parts = []
        for k, off, d in zip(keys, layout.offsets, layout.sizes):
            idx = _uniform_subset(k, d, self._k(d))
            parts.append(jnp.int32(off) + idx)
        gidx = jnp.concatenate(parts).astype(index_dtype(layout.padded_size))
        return Payload(indices=gidx, values=self._gather(delta, gidx))

    def _bucket_scales(self, layout) -> jax.Array:
        """Static per-entry decode scale: ``d_leaf / k_leaf`` for each kept
        coordinate — the same f32 factor the per-leaf decode multiplies by."""
        return jnp.asarray(np.concatenate([
            np.full(self._k(d), np.float32(d / self._k(d)), np.float32)
            for d in layout.sizes
        ]))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        scaled = payload.values * self._bucket_scales(layout)
        return jnp.zeros(
            (layout.padded_size,), jnp.float32
        ).at[payload.indices].add(scaled)

    def decode_sum_bucketed(self, layout, gathered: Payload, n: int) -> jax.Array:
        if not self.use_kernel:
            return super().decode_sum_bucketed(layout, gathered, n)
        from repro.kernels import ops as _kops

        return _kops.sparse_decode_sum_op(
            gathered.indices, gathered.values, self._bucket_scales(layout),
            d=layout.padded_size,
        )

    def decode_sum_apply_bucketed(self, layout, gathered, n, h_server):
        if not self.use_kernel or self.carries_state:
            # Memory case: base composition over the kernel decode_sum_bucketed
            # (same rationale as decode_sum_apply).
            return super().decode_sum_apply_bucketed(layout, gathered, n, h_server)
        from repro.kernels import ops as _kops

        ghat = _kops.sparse_decode_sum_mean_op(
            gathered.indices, gathered.values, self._bucket_scales(layout),
            d=layout.padded_size,
        )
        return ghat, h_server

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        if not self.carries_state:
            return 0.0
        if self.alpha is not None:
            return self.alpha
        if d is None:
            return 1.0
        return self._k(d) / d  # 1 / (1 + omega), omega = d/k - 1
