"""Natural compression — unbiased power-of-two exponent rounding (9 bits/dim).

``C_nat(x)`` keeps the sign and rounds ``|x|`` to one of its two enclosing
powers of two, up with probability ``(|x| - 2^(e-1)) / 2^(e-1)`` — exactly the
mantissa-dropping scheme of Horvath et al. 2019 ("Natural Compression for
Distributed Deep Learning"): unbiased, variance bound ``omega = 1/8``, and a
wire cost of sign + 8-bit exponent = 9 bits/dim regardless of vector length.

Wire format: one signed exponent code per coordinate in ``Payload.packed``
(int16 container; the logical payload is the 9-bit sign+exponent).  Code 0 is
an exact zero; otherwise ``code = sign * (exponent + _BIAS)``.

With its default alpha ``1/(1 + omega) = 8/9`` it drops straight into DIANA's
memory loop (the variance-reduction composition of Horvath et al.'s follow-up,
arXiv:1904.05115), converging linearly to the exact optimum in batch mode.

Kernel capability: with ``use_kernel=True`` the encode routes through
``nat_pack`` — the same stochastic exponent rounding computed from the float's
exponent/mantissa BITS instead of ``frexp`` (bitwise-equal given the same
``jax.random.bits`` draw; on compiled TPU the ``nat_pack_prng`` variant draws
the bits in-kernel) — and the server decode through the streaming
``nat_decode_sum(+apply)`` accumulator, which fuses DIANA's memory update into
the last grid step.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..quantization import uniform_from_bits
from .base import Compressor, Payload

__all__ = ["NaturalCompressor"]

# Exponent bias for the int16 code: f32 frexp exponents live in [-148, 128],
# so code magnitudes stay within [1, _BIAS + 128] << int16 range.
_BIAS = 160
OMEGA_NAT = 1.0 / 8.0


class NaturalCompressor(Compressor):
    name = "natural"
    unbiased = True
    kernel_oracle = "repro.kernels.ref::ref_nat_pack"

    def __init__(
        self,
        *,
        alpha: Optional[float] = None,
        memory: bool = True,
        use_kernel: Optional[bool] = None,
    ):
        self.alpha = alpha
        self.carries_state = memory
        # Capability auto-resolution: the natural kernels are Mosaic-shaped
        # (lane-aligned tiles, elementwise bodies), so auto engages on TPU
        # like the ternary family; interpret=True stays an explicit opt-in.
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self.use_kernel = use_kernel

    # ---------------------------------------------------------------- wire

    @staticmethod
    def _encode(x: jax.Array, u: jax.Array) -> Payload:
        """PRNG-free encode body given the uniform draws (shared with the
        bucketed path, which concatenates per-segment draws)."""
        mant, expo = jnp.frexp(x)                     # x = mant * 2^expo, |mant| in [0.5, 1)
        # |x| in [2^(e-1), 2^e): round up to 2^e w.p. 2|mant| - 1 (unbiased)
        p_up = 2.0 * jnp.abs(mant) - 1.0
        chosen = expo - 1 + (u < p_up).astype(expo.dtype)
        sign = jnp.sign(x).astype(jnp.int16)
        code = sign * (chosen.astype(jnp.int16) + jnp.int16(_BIAS))
        return Payload(packed=jnp.where(x == 0.0, jnp.int16(0), code))

    def _draw_bits(self, key: jax.Array, shape) -> jax.Array:
        return jax.random.bits(key, shape, dtype=jnp.uint32)

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        x = delta.astype(jnp.float32)
        if self.use_kernel:
            from repro.kernels import ops as _kops

            if _kops.default_interpret():
                bits = self._draw_bits(key, x.shape)
                return Payload(packed=_kops.nat_pack_op(x, bits))
            # Compiled TPU: bits drawn in-kernel — no (d,) uint32 operand.
            return Payload(packed=_kops.nat_pack_prng_op(x, key))
        bits = self._draw_bits(key, x.shape)
        return self._encode(x, uniform_from_bits(bits))

    def decode(self, payload: Payload, d: int) -> jax.Array:
        code = payload.packed
        mag = jnp.exp2((jnp.abs(code) - _BIAS).astype(jnp.float32))
        return jnp.where(
            code == 0, 0.0, jnp.sign(code).astype(jnp.float32) * mag
        )[:d]

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        """Streaming decode+accumulate over workers (kernel) or the base
        sequential loop — identical f32 recurrence, bitwise-interchangeable."""
        if not self.use_kernel:
            return super().decode_sum(gathered, n, d)
        from repro.kernels import ops as _kops

        return _kops.nat_decode_sum_op(gathered.packed)[:d]

    def decode_sum_apply(self, gathered: Payload, n: int, d: int, h_server):
        """Fused decode_sum + DIANA server update in one kernel launch: the
        memory epilogue runs on the accumulator tile at the last grid step."""
        if not self.use_kernel:
            return super().decode_sum_apply(gathered, n, d, h_server)
        from repro.kernels import ops as _kops

        if self.carries_state:
            return _kops.nat_decode_sum_apply_op(
                gathered.packed, h_server, alpha=self.memory_alpha(d)
            )
        return _kops.nat_decode_sum_mean_op(gathered.packed)[:d], h_server

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        return 9.0  # sign + 8-bit exponent (int16 is only the container)

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed_keys(self, layout, delta: jax.Array,
                               keys: jax.Array, fallback_key=None) -> Payload:
        """ONE vectorized encode over the whole buffer; per-segment bits
        drawn with the per-leaf key schedule so codes match the per-leaf path
        bitwise (alignment is 1: segments are unpadded and contiguous)."""
        x = delta.astype(jnp.float32)
        if self.use_kernel:
            from repro.kernels import ops as _kops

            if not _kops.default_interpret():
                # One whole-buffer in-kernel PRNG stream from fallback_key
                # (distribution-equal, the documented compiled-TPU exception).
                if fallback_key is None:
                    fallback_key = keys[0]
                return Payload(packed=_kops.nat_pack_prng_op(x, fallback_key))
        bits = jnp.concatenate([
            self._draw_bits(k, (s,))
            for k, s in zip(keys, layout.padded_sizes)
        ])
        if self.use_kernel:
            from repro.kernels import ops as _kops

            return Payload(packed=_kops.nat_pack_op(x, bits))
        return self._encode(x, uniform_from_bits(bits))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return self.decode(payload, layout.padded_size)

    def decode_sum_bucketed(self, layout, gathered: Payload, n: int) -> jax.Array:
        return self.decode_sum(gathered, n, layout.padded_size)

    def decode_sum_apply_bucketed(self, layout, gathered, n, h_server):
        """Alpha is d-independent for natural compression, so the per-leaf
        fused kernel serves the flat buffer unchanged."""
        return self.decode_sum_apply(gathered, n, layout.padded_size, h_server)

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        if not self.carries_state:
            return 0.0
        return self.alpha if self.alpha is not None else 1.0 / (1.0 + OMEGA_NAT)
