"""Natural compression — unbiased power-of-two exponent rounding (9 bits/dim).

``C_nat(x)`` keeps the sign and rounds ``|x|`` to one of its two enclosing
powers of two, up with probability ``(|x| - 2^(e-1)) / 2^(e-1)`` — exactly the
mantissa-dropping scheme of Horvath et al. 2019 ("Natural Compression for
Distributed Deep Learning"): unbiased, variance bound ``omega = 1/8``, and a
wire cost of sign + 8-bit exponent = 9 bits/dim regardless of vector length.

Wire format: one signed exponent code per coordinate in ``Payload.packed``
(int16 container; the logical payload is the 9-bit sign+exponent).  Code 0 is
an exact zero; otherwise ``code = sign * (exponent + _BIAS)``.

With its default alpha ``1/(1 + omega) = 8/9`` it drops straight into DIANA's
memory loop (the variance-reduction composition of Horvath et al.'s follow-up,
arXiv:1904.05115), converging linearly to the exact optimum in batch mode.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload

__all__ = ["NaturalCompressor"]

# Exponent bias for the int16 code: f32 frexp exponents live in [-148, 128],
# so code magnitudes stay within [1, _BIAS + 128] << int16 range.
_BIAS = 160
OMEGA_NAT = 1.0 / 8.0


class NaturalCompressor(Compressor):
    name = "natural"
    unbiased = True

    def __init__(self, *, alpha: Optional[float] = None, memory: bool = True):
        self.alpha = alpha
        self.carries_state = memory

    # ---------------------------------------------------------------- wire

    @staticmethod
    def _encode(x: jax.Array, u: jax.Array) -> Payload:
        """PRNG-free encode body given the uniform draws (shared with the
        bucketed path, which concatenates per-segment draws)."""
        mant, expo = jnp.frexp(x)                     # x = mant * 2^expo, |mant| in [0.5, 1)
        # |x| in [2^(e-1), 2^e): round up to 2^e w.p. 2|mant| - 1 (unbiased)
        p_up = 2.0 * jnp.abs(mant) - 1.0
        chosen = expo - 1 + (u < p_up).astype(expo.dtype)
        sign = jnp.sign(x).astype(jnp.int16)
        code = sign * (chosen.astype(jnp.int16) + jnp.int16(_BIAS))
        return Payload(packed=jnp.where(x == 0.0, jnp.int16(0), code))

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        x = delta.astype(jnp.float32)
        return self._encode(x, jax.random.uniform(key, x.shape, dtype=jnp.float32))

    def decode(self, payload: Payload, d: int) -> jax.Array:
        code = payload.packed
        mag = jnp.exp2((jnp.abs(code) - _BIAS).astype(jnp.float32))
        return jnp.where(
            code == 0, 0.0, jnp.sign(code).astype(jnp.float32) * mag
        )[:d]

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        return 9.0  # sign + 8-bit exponent (int16 is only the container)

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed(self, layout, delta: jax.Array, key: jax.Array) -> Payload:
        """ONE vectorized encode over the whole buffer; per-segment uniforms
        drawn with the per-leaf key schedule so codes match the per-leaf path
        bitwise (alignment is 1: segments are unpadded and contiguous)."""
        keys = jax.random.split(key, layout.n_leaves)
        u = jnp.concatenate([
            jax.random.uniform(k, (s,), dtype=jnp.float32)
            for k, s in zip(keys, layout.padded_sizes)
        ])
        return self._encode(delta.astype(jnp.float32), u)

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return self.decode(payload, layout.padded_size)

    # -------------------------------------------------------- memory rule

    def memory_alpha(self, d: Optional[int] = None) -> float:
        if not self.carries_state:
            return 0.0
        return self.alpha if self.alpha is not None else 1.0 / (1.0 + OMEGA_NAT)
