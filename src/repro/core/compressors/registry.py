"""Compressor registry: canonical names, legacy aliases, config -> instance.

Canonical operators:

    ternary   2 + 32/B bits/dim          unbiased   alpha-memory (DIANA)
    natural   9 bits/dim                 unbiased   alpha-memory (omega = 1/8)
    randk     (32+idx(d))k/d bits/dim    unbiased   alpha-memory (alpha = k/d)
    topk_ef   (32+idx(d))k/d bits/dim    biased     error-feedback residual
    identity  32 bits/dim                exact      stateless

(idx(d) = 8/16/32 — indices ride in the narrowest unsigned dtype covering d.)

Legacy ``CompressionConfig.method`` strings stay valid as aliases resolving to
a canonical operator plus overrides (the paper's Sec. 3 special cases):

    diana    -> ternary with memory            (Algorithm 1)
    qsgd     -> ternary p=2,   memory off      (Algorithm 2)
    terngrad -> ternary p=inf, memory off      (Algorithm 2)
    dqgd     -> ternary p=cfg, memory off      (Khirirat et al. 2018)
    none     -> identity

Registering a new operator is one :func:`register` call; it is immediately
reachable from ``CompressionConfig(method=...)``, the trainer CLI and the
benchmarks — and usable as a DOWNLINK operator for the compressed server
broadcast (``CompressionConfig(down_method=...)``, DESIGN.md §Bidirectional)
with no extra code: the memory hooks serve both directions.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Tuple

from .base import Compressor
from .identity import IdentityCompressor
from .natural import NaturalCompressor
from .randk import RandKCompressor
from .ternary import TernaryCompressor
from .topk_ef import TopKEFCompressor

__all__ = ["register", "alias", "make_compressor", "canonical_name", "available_methods"]

# canonical name -> factory(cfg, **alias_overrides) -> Compressor
_FACTORIES: Dict[str, Callable[..., Compressor]] = {}
# alias -> (canonical name, overrides)
_ALIASES: Dict[str, Tuple[str, dict]] = {}


def register(name: str):
    """Register a compressor factory ``f(cfg, **overrides) -> Compressor``."""

    def deco(factory):
        _FACTORIES[name] = factory
        return factory

    return deco


def alias(name: str, canonical: str, **overrides):
    """Map a legacy/alternate method string onto a canonical operator."""
    _ALIASES[name] = (canonical, overrides)


def canonical_name(method: str) -> str:
    """Resolve a method string to its canonical registry name (KeyError if
    unknown) — used by config validation."""
    if method in _FACTORIES:
        return method
    if method in _ALIASES:
        return _ALIASES[method][0]
    raise KeyError(
        f"unknown compression method {method!r}; choose from {available_methods()}"
    )


def available_methods() -> Tuple[str, ...]:
    return tuple(sorted(set(_FACTORIES) | set(_ALIASES)))


def make_compressor(cfg) -> Compressor:
    """Build the compressor a :class:`~repro.core.compression.CompressionConfig`
    names (``cfg`` only needs the config's field surface, keeping this module
    import-cycle free)."""
    if cfg.method in _ALIASES:
        name, overrides = _ALIASES[cfg.method]
    else:
        name, overrides = cfg.method, {}
    if name not in _FACTORIES:
        raise KeyError(
            f"unknown compression method {cfg.method!r}; choose from {available_methods()}"
        )
    return _FACTORIES[name](cfg, **overrides)


# ---------------------------------------------------------------------------
# Built-in operators
# ---------------------------------------------------------------------------

@register("ternary")
def _ternary(cfg, *, p=None, memory=True):
    return TernaryCompressor(
        p=cfg.p if p is None else p,
        block_size=cfg.block_size,
        alpha=cfg.alpha,
        memory=memory,
        use_kernel=cfg.use_kernel,
    )


@register("natural")
def _natural(cfg, *, memory=True):
    return NaturalCompressor(alpha=cfg.alpha, memory=memory, use_kernel=cfg.use_kernel)


@register("randk")
def _randk(cfg, *, memory=True):
    return RandKCompressor(
        cfg.k, alpha=cfg.alpha, memory=memory, use_kernel=cfg.use_kernel
    )


@register("topk_ef")
def _topk_ef(cfg):
    return TopKEFCompressor(cfg.k, use_kernel=cfg.use_kernel)


@register("identity")
def _identity(cfg):
    return IdentityCompressor(use_kernel=cfg.use_kernel)


alias("diana", "ternary", memory=True)
alias("qsgd", "ternary", p=2.0, memory=False)
alias("terngrad", "ternary", p=math.inf, memory=False)
alias("dqgd", "ternary", memory=False)
alias("none", "identity")
alias("rand-k", "randk")
alias("top-k-ef", "topk_ef")
