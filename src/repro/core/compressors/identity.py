"""Identity "compressor" — uncompressed f32 baseline (method "none").

Ships the dense vector in ``Payload.values``.  Running the baseline through
the same compress -> gather -> decode_sum pipeline as every real operator
keeps the aggregation loop branch-free and makes the 32-bits/dim row of the
trade-off benchmarks an honest apples-to-apples measurement.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload

__all__ = ["IdentityCompressor"]


class IdentityCompressor(Compressor):
    name = "identity"
    unbiased = True
    carries_state = False
    prefers_allreduce = True  # dense payload: one pmean beats gather+decode

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        del key
        return Payload(values=delta.astype(jnp.float32))

    def decode(self, payload: Payload, d: int) -> jax.Array:
        return payload.values[:d]

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        return 32.0

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed(self, layout, delta: jax.Array, key: jax.Array) -> Payload:
        del key
        return Payload(values=delta.astype(jnp.float32))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return payload.values
