"""Identity "compressor" — uncompressed f32 baseline (method "none").

Ships the dense vector in ``Payload.values``.  Running the baseline through
the same compress -> gather -> decode_sum pipeline as every real operator
keeps the aggregation loop branch-free and makes the 32-bits/dim row of the
trade-off benchmarks an honest apples-to-apples measurement.

Kernel capability: with ``use_kernel=True`` the payload passes through
``dense_copy`` and the server mean through the streaming
``dense_decode_sum(_mean)`` accumulator — trivially bitwise-equal, but it
means the full registry satisfies the one capability matrix
(``tools/check_kernels.py``) with no special cases, and the identity rows of
the roofline benchmark measure the same kernel plumbing as the real
operators.  Interpret-contract only; auto resolves to off.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .base import Compressor, Payload

__all__ = ["IdentityCompressor"]


class IdentityCompressor(Compressor):
    name = "identity"
    unbiased = True
    carries_state = False
    kernel_oracle = "repro.kernels.ref::ref_dense_decode_sum"
    prefers_allreduce = True  # dense payload: one pmean beats gather+decode

    def __init__(self, *, use_kernel: Optional[bool] = None):
        # Dense kernels are interpret-contract only: auto resolves to off.
        self.use_kernel = bool(use_kernel) if use_kernel is not None else False

    def _values(self, delta: jax.Array) -> jax.Array:
        x = delta.astype(jnp.float32)
        if self.use_kernel:
            from repro.kernels import ops as _kops

            return _kops.dense_copy_op(x)
        return x

    def compress(self, delta: jax.Array, key: jax.Array) -> Payload:
        del key
        return Payload(values=self._values(delta))

    def decode(self, payload: Payload, d: int) -> jax.Array:
        return payload.values[:d]

    def decode_sum(self, gathered: Payload, n: int, d: int) -> jax.Array:
        if not self.use_kernel:
            return super().decode_sum(gathered, n, d)
        from repro.kernels import ops as _kops

        return _kops.dense_decode_sum_op(gathered.values[:, :d])

    def decode_sum_apply(self, gathered: Payload, n: int, d: int, h_server):
        if not self.use_kernel:
            return super().decode_sum_apply(gathered, n, d, h_server)
        from repro.kernels import ops as _kops

        return _kops.dense_decode_sum_mean_op(gathered.values[:, :d]), h_server

    def bits_per_dim(self, d: Optional[int] = None) -> float:
        return 32.0

    # ------------------------------------------------- bucketed (flat) path

    def compress_bucketed_keys(self, layout, delta: jax.Array,
                               keys: jax.Array, fallback_key=None) -> Payload:
        del keys, fallback_key  # deterministic cast/copy
        return Payload(values=self._values(delta))

    def decode_bucketed(self, layout, payload: Payload) -> jax.Array:
        return payload.values

    def decode_sum_bucketed(self, layout, gathered: Payload, n: int) -> jax.Array:
        if not self.use_kernel:
            return super().decode_sum_bucketed(layout, gathered, n)
        return self.decode_sum(gathered, n, layout.padded_size)

    def decode_sum_apply_bucketed(self, layout, gathered, n, h_server):
        if not self.use_kernel:
            return super().decode_sum_apply_bucketed(layout, gathered, n, h_server)
        return self.decode_sum_apply(gathered, n, layout.padded_size, h_server)
