"""DIANA (Algorithm 1) — compressed gradient-difference aggregation.

Two implementations, one semantics:

* :func:`aggregate_shardmap` — the production path, called *inside* a
  ``shard_map`` whose manual axes are the DIANA worker axes.  Each worker
  encodes its compressor input, all-gathers the :class:`Payload` wire format
  (the TPU analogue of the paper's MPI Gather + Broadcast — replicated
  deterministic decode replaces the server), and every device reconstructs the
  identical aggregated estimator ``ghat = h^k + mean_i dhat_i``.

* :func:`reference_step` — a single-process n-worker simulation used by unit
  tests, the convex-experiment benchmarks and the paper-figure reproductions.
  ``aggregate_shardmap`` is tested to agree with it bit-for-bit under a shared
  PRNG schedule: both paths run the SAME compressor hooks, and the mean
  accumulates through the same :meth:`Compressor.decode_sum` f32 recurrence.

Every operator-specific decision — what is encoded (gradient vs gradient
difference vs error-corrected gradient), how the memories evolve, how the
gathered payload decodes — lives behind the :class:`Compressor` interface
(:mod:`repro.core.compressors`); this module only owns the pytree plumbing,
the worker collective and the memory-state layout.  For the paper's operator
the hooks are Algorithm 1 lines 5-9:
    h_i^{k+1} = h_i^k + alpha * dhat_i^k
    h^{k+1}   = h^k   + alpha * mean_i dhat_i^k
    ghat^k    = h^k + mean_i dhat_i^k
"""

from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .compression import CompressionConfig
from .compressors import Compressor, Payload

__all__ = [
    "DianaState",
    "init_state",
    "aggregate_shardmap",
    "reference_init",
    "reference_step",
    "tree_zeros_like",
]


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


def _is_payload(t) -> bool:
    return isinstance(t, Payload)


class DianaState(NamedTuple):
    """Compressor state carried by the training loop.

    Memories are stored FLAT (one 1-D leaf per param leaf, sharded evenly over
    the 'model' axis) — the same layout compression operates in, so the
    entire compress -> gather -> decode -> h-update path is layout-local; the
    only relayouts per step are grads->flat and ghat->param-shape (both over
    the fast intra-pod ICI; see DESIGN.md §Perf notes).

    h_worker: pytree of (n_workers, d_leaf) f32/bf16 — axis 0 sharded over the
              worker mesh axes (each worker holds only its own memory).  The
              paper's h_i for alpha-memory operators; the error-feedback
              residual e_i for top-k EF; inert zeros for memoryless ones.
    h_server: pytree of (d_leaf,) — replicated over worker axes — the paper's
              server-side ``h^k = mean_i h_i^k``.
    """

    h_worker: Any
    h_server: Any


def init_state(params, cfg: CompressionConfig, n_workers: int) -> DianaState:
    """h_i^0 = 0 (the paper's experimental choice) for all operators."""
    h_w = jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_workers, p.size), cfg.h_dtype), params
    )
    h_s = jax.tree_util.tree_map(lambda p: jnp.zeros((p.size,), cfg.h_dtype), params)
    return DianaState(h_worker=h_w, h_server=h_s)


# ---------------------------------------------------------------------------
# Distributed aggregation (inside shard_map over worker axes)
# ---------------------------------------------------------------------------

def _gather_payloads(payload_tree, axis_names):
    """All-gather every array field of every per-leaf :class:`Payload`.

    The gathered buffers are explicitly re-constrained to stay sharded over
    'model' on the post-worker dim — ``all_gather`` output sharding does not
    propagate the auto axes by itself and would otherwise replicate the
    payload n times per device.
    """
    from repro.models.sharding import shard

    def gather_field(a):
        out = (
            jax.lax.all_gather(a, axis_names, tiled=False)
            if axis_names else a[None]
        )
        return shard(out, None, "model", *(None,) * (out.ndim - 2))

    def gather_leaf(pay: Payload) -> Payload:
        return Payload(*(None if f is None else gather_field(f) for f in pay))

    return jax.tree_util.tree_map(gather_leaf, payload_tree, is_leaf=_is_payload)


def _gathered_mean(payload_tree, like, n_workers: int, axis_names, comp: Compressor):
    """mean_i decode(payload_i) without materialising n dense copies.

    All-gathers the compressed payload (cheap: n * bits_per_dim * d / 8 bytes)
    and decodes through the compressor's :meth:`decode_sum` — the fused Pallas
    unpack+reduce for kernel-backed operators, a sequential f32 accumulate
    otherwise — so peak memory stays at one dense gradient regardless of n.
    """
    gathered = _gather_payloads(payload_tree, axis_names)

    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    pay_leaves = jax.tree_util.tree_leaves(gathered, is_leaf=_is_payload)

    outs = []
    for pay, l in zip(pay_leaves, like_leaves):
        total = comp.decode_sum(pay, n_workers, l.size)
        outs.append((total / n_workers).reshape(l.shape).astype(l.dtype))
    return jax.tree_util.tree_unflatten(treedef, outs)


def _aggregate_local(grads_local, h_worker, h_server, key, cfg, axis_names, n_workers):
    """The core Algorithm-1 round on LOCAL arrays (no sharding decisions).

    grads_local leaves may have any shape — they are flattened locally; the
    h leaves are flat ``(1, d_local)`` / ``(d_local,)``.  ``axis_names`` are
    the (manual) worker axes the packed payload is gathered over.  All
    operator behaviour dispatches through the configured compressor's hooks.
    """
    comp = cfg.make()

    g_flat = jax.tree_util.tree_map(
        lambda g: g.reshape(-1).astype(jnp.float32), grads_local
    )
    h_local = jax.tree_util.tree_map(
        lambda h: h[0].astype(jnp.float32), h_worker
    )

    delta = jax.tree_util.tree_map(comp.compress_input, g_flat, h_local)

    leaves, treedef = jax.tree_util.tree_flatten(delta)
    keys = jax.random.split(key, len(leaves))
    payloads = [comp.compress(leaf, k) for leaf, k in zip(leaves, keys)]
    payload_tree = jax.tree_util.tree_unflatten(treedef, payloads)
    # The worker's own estimate, for its memory update — decoded from the
    # payload (bitwise the transmitted value); dead-code-eliminated under jit
    # for operators whose hooks ignore it.
    dhat_own = jax.tree_util.tree_unflatten(
        treedef, [comp.decode(p, leaf.size) for p, leaf in zip(payloads, leaves)]
    )

    dhat_mean = _gathered_mean(payload_tree, g_flat, n_workers, axis_names, comp)

    new_h_local = jax.tree_util.tree_map(
        lambda h, dh, dl: comp.next_memory(h, dh, dl).astype(cfg.h_dtype),
        h_local, dhat_own, delta,
    )
    new_hw = jax.tree_util.tree_map(lambda h: h[None], new_h_local)
    new_h_server = jax.tree_util.tree_map(
        lambda h, dm: comp.next_server_memory(h.astype(jnp.float32), dm).astype(cfg.h_dtype),
        h_server, dhat_mean,
    )
    ghat_flat = jax.tree_util.tree_map(
        lambda h, dm: comp.server_direction(h.astype(jnp.float32), dm),
        h_server, dhat_mean,
    )

    ghat = jax.tree_util.tree_map(
        lambda f, g: f.reshape(g.shape).astype(g.dtype), ghat_flat, grads_local
    )
    return ghat, new_hw, new_h_server


def aggregate_shardmap(
    grads_local,
    state: DianaState,
    key: jax.Array,
    cfg: CompressionConfig,
    *,
    axis_names: Sequence[str],
    n_workers: int,
    inner_axes: Sequence[str] = (),
    grad_specs=None,
    h_specs=None,
    mesh=None,
):
    """One DIANA aggregation round inside a shard_map body.

    grads_local — this worker's local gradient pytree (g_i^k).
    state.h_worker leaves arrive with local leading dim 1 (own memory only).
    key          — already folded with the worker index (deterministic stream).

    When ``inner_axes`` (the non-worker mesh axes, e.g. ('model',) or
    ('data','model')) are given together with per-leaf PartitionSpecs, the
    whole round runs inside a NESTED fully-manual shard_map: each inner
    device encodes / decodes ITS OWN shard of every gradient leaf and the
    payload all-gather runs over the (outer-manual) worker axes.  No
    relayout, no partitioner decisions — XLA's SPMD partitioner crashes on
    several of them under manual subgroups (DESIGN.md §6).  The h memory is
    stored in this shard-local flat layout, which is self-consistent step to
    step (its global ordering is internal state, never interpreted).

    Returns ``(ghat, new_state)`` with ``ghat`` identical on all workers and
    shaped/sharded like ``grads_local``.
    """
    axis_names = tuple(axis_names)
    inner_axes = tuple(inner_axes)

    comp = cfg.make()
    if comp.prefers_allreduce:
        # dense stateless payload: the gathered mean IS a fused all-reduce
        ghat = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis_names) if axis_names else g,
            grads_local,
        )
        return ghat, state

    if not inner_axes or grad_specs is None:
        # single-device / tests: everything already local
        ghat, new_hw, new_hs = _aggregate_local(
            grads_local, state.h_worker, state.h_server, key, cfg,
            axis_names, n_workers,
        )
        return ghat, DianaState(h_worker=new_hw, h_server=new_hs)

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map as _shard_map
    from repro.models.sharding import NoopPolicy, sharding_policy

    amesh = None
    try:
        amesh = jax.sharding.get_abstract_mesh()
    except Exception:
        pass
    if amesh is None or amesh.empty:
        amesh = mesh  # plain-jit caller (no outer shard_map): concrete mesh
    assert amesh is not None, "aggregate_shardmap needs a mesh for the nested map"

    def body(grads, h_w, h_s, k):
        with sharding_policy(NoopPolicy()):
            return _aggregate_local(grads, h_w, h_s, k, cfg, axis_names, n_workers)

    hw_specs = jax.tree_util.tree_map(lambda s: P(None, *s), h_specs)
    in_specs = (grad_specs, hw_specs, h_specs, P())
    out_specs = (grad_specs, hw_specs, h_specs)
    ghat, new_hw, new_hs = _shard_map(
        body, mesh=amesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=set(inner_axes), check_vma=False,
    )(grads_local, state.h_worker, state.h_server, key)
    return ghat, DianaState(h_worker=new_hw, h_server=new_hs)


# ---------------------------------------------------------------------------
# Single-process n-worker reference (tests, convex experiments, figures)
# ---------------------------------------------------------------------------

class ReferenceState(NamedTuple):
    h_worker: Any  # (n, d) per leaf — flat, mirroring DianaState
    h_server: Any  # (d,) per leaf — flat
    v: Any         # momentum buffer, like params


def reference_init(params, cfg: CompressionConfig, n_workers: int) -> ReferenceState:
    return ReferenceState(
        h_worker=jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_workers, p.size), jnp.float32), params
        ),
        h_server=jax.tree_util.tree_map(
            lambda p: jnp.zeros((p.size,), jnp.float32), params
        ),
        v=tree_zeros_like(params, jnp.float32),
    )


def reference_step(
    grads_per_worker,
    state: ReferenceState,
    key: jax.Array,
    cfg: CompressionConfig,
    *,
    beta: float = 0.0,
):
    """Aggregate stacked per-worker grads (n, ...) exactly as Algorithm 1.

    Bit-for-bit aligned with :func:`aggregate_shardmap`: worker ``i`` draws
    from ``fold_in(key, i)`` through the same per-leaf compress path, and the
    mean runs through the same :meth:`Compressor.decode_sum` sequential f32
    recurrence as the distributed decode — tests assert exact equality
    between the two.

    Returns (v, new_state): ``v = beta*v + ghat`` — caller does the prox step.
    """
    comp = cfg.make()
    n = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]

    payload_trees = []
    new_h_rows = []
    for w in range(n):
        gw = jax.tree_util.tree_map(
            lambda g: g[w].astype(jnp.float32).reshape(-1), grads_per_worker
        )
        hw = jax.tree_util.tree_map(
            lambda h: h[w].astype(jnp.float32), state.h_worker
        )
        delta = jax.tree_util.tree_map(comp.compress_input, gw, hw)

        leaves, treedef = jax.tree_util.tree_flatten(delta)
        keys = jax.random.split(jax.random.fold_in(key, w), len(leaves))
        payloads = [comp.compress(leaf, k) for leaf, k in zip(leaves, keys)]
        dhat_w = jax.tree_util.tree_unflatten(
            treedef, [comp.decode(p, leaf.size) for p, leaf in zip(payloads, leaves)]
        )
        payload_trees.append(jax.tree_util.tree_unflatten(treedef, payloads))
        new_h_rows.append(jax.tree_util.tree_map(
            comp.next_memory, hw, dhat_w, delta
        ))

    # Stack per-worker payloads into the gathered layout (leading worker axis)
    # and decode through the same summation path as the distributed server.
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payload_trees)
    like_leaves, treedef = jax.tree_util.tree_flatten(
        jax.tree_util.tree_map(
            lambda g: g[0].astype(jnp.float32).reshape(-1), grads_per_worker
        )
    )
    pay_leaves = jax.tree_util.tree_leaves(stacked, is_leaf=_is_payload)
    dhat_mean = jax.tree_util.tree_unflatten(treedef, [
        comp.decode_sum(pay, n, l.size) / n
        for pay, l in zip(pay_leaves, like_leaves)
    ])

    ghat_flat = jax.tree_util.tree_map(
        comp.server_direction, state.h_server, dhat_mean
    )
    new_state = state._replace(
        h_worker=jax.tree_util.tree_map(lambda *rows: jnp.stack(rows), *new_h_rows),
        h_server=jax.tree_util.tree_map(
            comp.next_server_memory, state.h_server, dhat_mean
        ),
    )
    ghat = jax.tree_util.tree_map(
        lambda f, g: f.reshape(g.shape[1:]), ghat_flat, grads_per_worker
    )

    v = jax.tree_util.tree_map(lambda v0, g: beta * v0 + g, state.v, ghat)
    return v, new_state._replace(v=v)
