"""DIANA (Algorithm 1) — compressed gradient-difference aggregation.

Two implementations, one semantics:

* :func:`aggregate_shardmap` — the production path, called *inside* a
  ``shard_map`` whose manual axes are the DIANA worker axes.  Each worker
  encodes its compressor input, all-gathers the :class:`Payload` wire format
  (the TPU analogue of the paper's MPI Gather + Broadcast — replicated
  deterministic decode replaces the server), and every device reconstructs the
  identical aggregated estimator ``ghat = h^k + mean_i dhat_i``.

* :func:`reference_step` — a single-process n-worker simulation used by unit
  tests, the convex-experiment benchmarks and the paper-figure reproductions.
  ``aggregate_shardmap`` is tested to agree with it bit-for-bit under a shared
  PRNG schedule: both paths run the SAME compressor hooks, and the mean
  accumulates through the same :meth:`Compressor.decode_sum` f32 recurrence.

Every operator-specific decision — what is encoded (gradient vs gradient
difference vs error-corrected gradient), how the memories evolve, how the
gathered payload decodes — lives behind the :class:`Compressor` interface
(:mod:`repro.core.compressors`); this module only owns the pytree plumbing,
the worker collective and the memory-state layout.  For the paper's operator
the hooks are Algorithm 1 lines 5-9:
    h_i^{k+1} = h_i^k + alpha * dhat_i^k
    h^{k+1}   = h^k   + alpha * mean_i dhat_i^k
    ghat^k    = h^k + mean_i dhat_i^k
"""

from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .bucket import (
    BucketLayout,
    ChunkedSchedule,
    add_checksum,
    bucketed_compressor,
    fuse_payload,
    payload_recipe,
    unfuse_payload,
    verify_checksum,
    wire_roundtrip,
)
from .compression import CompressionConfig
from .compressors import Compressor, Payload
from .participation import (
    PART_FOLD,
    ParticipationSpec,
    apply_faults,
    direction_scale,
    step_ctx,
)
from .policy import CompressionPolicy, partition_for
from .vr import VRState, control_variate, init_vr, reference_coins, refresh, vr_coin

__all__ = [
    "DianaState",
    "CHUNK_FOLD",
    "DOWN_FOLD",
    "GROUP_FOLD",
    "init_state",
    "init_downlink",
    "downlink_round",
    "aggregate_shardmap",
    "reference_init",
    "reference_step",
    "tree_zeros_like",
    "bucket_layout",
]

# Folded into the UN-worker-folded step key for the downlink draws; disjoint
# from the compression schedule (which folds worker indices then splits over
# leaves) and from the VR coin fold (applied to worker-folded keys), so the
# broadcast's PRNG stream is identical on every worker and never collides
# with an uplink draw.  DESIGN.md §Bidirectional.
DOWN_FOLD = 0x444E  # 'DN'

# Grouped policies: group ``g`` draws from ``fold_in(worker_key, GROUP_FOLD+g)``
# (and the downlink from ``fold_in(down_key, GROUP_FOLD+g)``) — applied AFTER
# the worker fold in both the distributed and reference paths, so the two stay
# bitwise-aligned, and disjoint from VR_FOLD/DOWN_FOLD and from any worker
# index.  UNIFORM policies never fold this: the single-rule path IS the
# pre-policy flat path, draw for draw (DESIGN.md §Policy).
GROUP_FOLD = 0x4750  # 'GP'

# Chunked wire (repro.core.bucket.ChunkedSchedule): chunk ``c`` of a round
# never re-splits keys — it compresses with the SLICE of the monolithic
# per-leaf schedule ``split(key, n_leaves)[bounds[c]:bounds[c+1]]``, which is
# what keeps chunked == monolithic bitwise.  CHUNK_FOLD exists only for the
# compiled-TPU in-kernel-PRNG encodes, which draw one stream per kernel
# launch and cannot honour a per-leaf schedule: chunk ``c`` there draws from
# ``fold_in(key, CHUNK_FOLD + c)`` — distribution-equal, bitwise only within
# a fixed chunking, the same documented exception as that mode's
# bucketed-vs-perleaf story (DESIGN.md §Topology: the PRNG chunk-fold rule).
CHUNK_FOLD = 0x434B  # 'CK'


def _split_spec(spec):
    """Normalize the ``cfg`` argument every entry point takes: returns
    ``(policy, flat_cfg)`` where exactly one is non-None.  A uniform policy
    collapses to its flat config — by construction the identical pre-policy
    code path (the back-compat law); grouped policies return themselves and
    dispatch through the grouped driver."""
    if isinstance(spec, CompressionPolicy):
        if spec.is_uniform:
            return None, spec.flat_config()
        return spec, None
    return None, spec


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


# ---------------------------------------------------------------------------
# Elastic participation plumbing (DESIGN.md §Elasticity)
# ---------------------------------------------------------------------------

def _resolve_participation(policy, cfg):
    """The active :class:`ParticipationSpec`, or None — a trivial spec keeps
    the aggregation on the exact pre-elastic code path, bit for bit."""
    spec = policy.participation if policy is not None else cfg.participation
    if spec is None or spec.is_trivial:
        return None
    return spec


def _where_rows(cond, new, old):
    """Fixed-shape advance/freeze select: ``cond`` is a () or (n,) bool
    broadcast from the left over each leaf's trailing dims.  An explicit
    select — NEVER "add zero" — so frozen state is bitwise-untouched
    (``x + 0.0`` maps ``-0.0`` to ``+0.0``)."""

    def sel(a, b):
        c = cond.reshape(cond.shape + (1,) * (b.ndim - cond.ndim))
        return jnp.where(c, a, b)

    return jax.tree_util.tree_map(sel, new, old)


def _reinit_zero(reinit, h):
    """Zero the ``h`` rows of workers whose churn ``join`` fires this step
    (``reinit`` a () or (n,) bool) — applied BEFORE aggregation, and kept
    even on a degraded step (the freeze selects back to the post-reinit
    state, so a re-joining worker's fresh row survives)."""
    return _where_rows(reinit, tree_zeros_like(h), h)


def _participant_gate(part, valid=None):
    """THE participant-selection rule of one aggregation round: the (n,)
    bool of workers whose ``h_worker``/EF row advances — scheduled
    participants (the PART_FOLD mask), on a non-degraded step, whose wire
    checksum (when faults are armed) verified.  Shared by the per-leaf and
    bucketed reference paths (and mirrored scalar-wise by the distributed
    rounds), so participant selection cannot fork between layouts."""
    gate = part.mask & part.ok
    if valid is not None:
        gate = gate & valid
    return gate


def _masked_server_tail(comp, h_f, total, n_workers, part, m_eff):
    """The sampled-sum server epilogue on ONE flat f32 buffer/leaf:
    the direction uses the RESCALED participant sum (unbiasedness), the
    server memory the UNRESCALED ``sum/n`` (which preserves the invariant
    ``h = mean_i h_i`` — only participants' ``h_i`` advanced), and BOTH
    freeze on a degraded step (``ghat = 0``: skip-update)."""
    scale = direction_scale(part.spec, m_eff, part.ok)
    ghat = jnp.where(part.ok, comp.server_direction(h_f, total * scale),
                     jnp.zeros_like(h_f))
    new_h = jnp.where(part.ok, comp.next_server_memory(h_f, total / n_workers),
                      h_f)
    return ghat, new_h


def _is_payload(t) -> bool:
    return isinstance(t, Payload)


class DianaState(NamedTuple):
    """Compressor state carried by the training loop.

    Memories are stored FLAT — the same layout compression operates in, so
    the entire compress -> gather -> decode -> h-update path is layout-local;
    the only relayouts per step are grads->flat and ghat->param-shape (both
    over the fast intra-pod ICI; see DESIGN.md §Perf notes).  Two layouts:

    * per-leaf (``cfg.bucketed=False``): one 1-D leaf per param leaf —
      h_worker a pytree of ``(n_workers, d_leaf)``, h_server of ``(d_leaf,)``.
    * bucketed (``cfg.bucketed=True``): the whole model in ONE buffer of
      length ``Dp`` (the :class:`~repro.core.bucket.BucketLayout` padded
      size) — h_worker a single ``(n_workers, Dp)`` array, h_server ``(Dp,)``,
      updated by one vectorized elementwise op per step.

    h_worker axis 0 is sharded over the worker mesh axes (each worker holds
    only its own memory): the paper's h_i for alpha-memory operators, the
    error-feedback residual e_i for top-k EF, inert zeros for memoryless
    ones.  h_server is replicated over worker axes — the paper's server-side
    ``h^k = mean_i h_i^k``.

    vr is the optional VR-DIANA slot (:class:`~repro.core.vr.VRState`,
    ``cfg.vr``): per-worker L-SVRG (snapshot, mu) pairs, stored in PARAMETER
    layout (leaves ``(n_workers, *shape)``, worker dim sharded like
    h_worker) regardless of ``cfg.bucketed`` — VR algebra runs before any
    flattening.  ``None`` flattens away, so pre-VR code, checkpoints and
    shardings are untouched when VR is off.

    h_down is the optional DOWNLINK memory (``cfg.down_method``): the
    server-broadcast analogue of h_server — the alpha-memory of an unbiased
    downlink operator, or the error-feedback residual of top-k — REPLICATED
    over the worker axes (server and every worker evolve the identical copy
    deterministically).  Stored flat in the DOWNLINK operator's own layout:
    a pytree of ``(d_leaf,)`` leaves per-leaf, or one ``(Dp_down,)`` buffer
    when the downlink is bucketed.  ``None`` flattens away, so uplink-only
    states, checkpoints and shardings stay byte-identical.
    """

    h_worker: Any
    h_server: Any
    vr: Any = None
    h_down: Any = None


def bucket_layout(cfg: CompressionConfig, tree) -> BucketLayout:
    """The flat-buffer layout of ``tree`` under ``cfg``'s operator (segment
    alignment is the operator's ``bucket_align()``)."""
    return BucketLayout.for_tree(tree, align=cfg.make().bucket_align())


def init_downlink(params, cfg: CompressionConfig, dtype=None, dcfg=None):
    """``h_down^0 = 0`` in the DOWNLINK operator's own layout (``None`` when
    no downlink is configured) — one replicated copy, no worker dim.
    ``dcfg`` overrides the derived ``cfg.down_config()`` (the grouped driver
    passes each rule's standalone downlink config)."""
    dcfg = cfg.down_config() if dcfg is None else dcfg
    if dcfg is None:
        return None
    dtype = cfg.h_dtype if dtype is None else dtype
    if dcfg.bucketed:
        return jnp.zeros((bucket_layout(dcfg, params).padded_size,), dtype)
    return jax.tree_util.tree_map(lambda p: jnp.zeros((p.size,), dtype), params)


def _init_grouped(params, policy: CompressionPolicy, n_workers: int, dtype=None):
    """Per-group memory trees for a grouped policy: dicts keyed by group name
    (``g<rule:02d>_<label>`` — sorted dict order == rule order), each entry in
    that group's own layout: one ``(n, Dp_g)`` / ``(Dp_g,)`` buffer for a
    bucketed group, lists of flat per-leaf memories otherwise.  Returns
    ``(h_worker, h_server, h_down)`` (``h_down`` None when no rule has a
    downlink)."""
    part = partition_for(policy, params)
    groups = part.split(params)
    dtype = policy.h_dtype if dtype is None else dtype
    h_w, h_s, h_d = {}, {}, {}
    for g, gname in enumerate(part.group_names):
        cfg_g, leaves = part.configs[g], groups[g]
        if cfg_g.bucketed:
            dp = bucket_layout(cfg_g, leaves).padded_size
            h_w[gname] = jnp.zeros((n_workers, dp), dtype)
            h_s[gname] = jnp.zeros((dp,), dtype)
        else:
            h_w[gname] = [jnp.zeros((n_workers, l.size), dtype) for l in leaves]
            h_s[gname] = [jnp.zeros((l.size,), dtype) for l in leaves]
        dcfg = part.down_configs[g]
        if dcfg is not None:
            h_d[gname] = init_downlink(leaves, cfg_g, dtype=dtype, dcfg=dcfg)
    return h_w, h_s, (h_d if h_d else None)


def init_state(params, cfg, n_workers: int) -> DianaState:
    """h_i^0 = 0 (the paper's experimental choice) for all operators; the VR
    slot (``cfg.vr``) starts at ``w_i^0 = x^0`` with zero ``mu`` (see
    :func:`repro.core.vr.init_vr` for how callers warm-start ``mu``); the
    downlink memory (``cfg.down_method``) starts at ``h_down^0 = 0``.

    ``cfg`` may be a flat :class:`CompressionConfig` OR a
    :class:`~repro.core.policy.CompressionPolicy`: uniform policies produce
    the byte-identical legacy layout; grouped policies store the memories per
    group (:func:`_init_grouped`)."""
    policy, cfg = _split_spec(cfg)
    if policy is not None:
        vr = init_vr(params, n_workers) if policy.vr else None
        h_w, h_s, h_down = _init_grouped(params, policy, n_workers)
        return DianaState(h_worker=h_w, h_server=h_s, vr=vr, h_down=h_down)
    vr = init_vr(params, n_workers) if cfg.vr else None
    h_down = init_downlink(params, cfg)
    if cfg.bucketed:
        dp = bucket_layout(cfg, params).padded_size
        return DianaState(
            h_worker=jnp.zeros((n_workers, dp), cfg.h_dtype),
            h_server=jnp.zeros((dp,), cfg.h_dtype),
            vr=vr,
            h_down=h_down,
        )
    h_w = jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_workers, p.size), cfg.h_dtype), params
    )
    h_s = jax.tree_util.tree_map(lambda p: jnp.zeros((p.size,), cfg.h_dtype), params)
    return DianaState(h_worker=h_w, h_server=h_s, vr=vr, h_down=h_down)


# ---------------------------------------------------------------------------
# Distributed aggregation (inside shard_map over worker axes)
# ---------------------------------------------------------------------------

def _gather_field(a, axis_names, groups=None):
    """All-gather ONE payload field over the worker axes.

    The gathered buffer is explicitly re-constrained to stay sharded over
    'model' on the post-worker dim — ``all_gather`` output sharding does not
    propagate the auto axes by itself and would otherwise replicate the
    payload n times per device.  ``groups`` (hierarchical topology) restricts
    the gather to ``axis_index_groups`` subsets of ONE worker axis — e.g. the
    inter-node leader exchange, whose rows arrive in node order.
    """
    from repro.models.sharding import shard

    if groups is not None:
        assert len(axis_names) == 1, (
            "grouped gathers (hierarchical topology) need ONE worker axis")
        out = jax.lax.all_gather(a, axis_names[0], tiled=False,
                                 axis_index_groups=groups)
    else:
        out = (
            jax.lax.all_gather(a, axis_names, tiled=False)
            if axis_names else a[None]
        )
    return shard(out, None, "model", *(None,) * (out.ndim - 2))


def _gather_payloads(payload_tree, axis_names):
    """All-gather every array field of every per-leaf :class:`Payload`."""

    def gather_leaf(pay: Payload) -> Payload:
        return Payload(*(
            None if f is None else _gather_field(f, axis_names) for f in pay
        ))

    return jax.tree_util.tree_map(gather_leaf, payload_tree, is_leaf=_is_payload)


def _gathered_sum(payload_tree, like, n_workers: int, axis_names,
                  comp: Compressor, mask=None):
    """sum_i decode(payload_i) without materialising n dense copies.

    All-gathers the compressed payload (cheap: n * bits_per_dim * d / 8 bytes)
    and decodes through the compressor's :meth:`decode_sum` — the fused Pallas
    unpack+reduce for kernel-backed operators, a sequential f32 accumulate
    otherwise — so peak memory stays at one dense gradient regardless of n.
    With a participation ``mask``, non-participants' payload rows are zeroed
    first (:meth:`Payload.mask_workers`) so they contribute an exact 0 to the
    unchanged recurrence.
    """
    gathered = _gather_payloads(payload_tree, axis_names)

    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    pay_leaves = jax.tree_util.tree_leaves(gathered, is_leaf=_is_payload)

    outs = []
    for pay, l in zip(pay_leaves, like_leaves):
        if mask is not None:
            pay = pay.mask_workers(mask)
        outs.append(comp.decode_sum(pay, n_workers, l.size))
    return jax.tree_util.tree_unflatten(treedef, outs)


def _gathered_mean(payload_tree, like, n_workers: int, axis_names, comp: Compressor):
    """mean_i decode(payload_i), shaped/typed like ``like``."""
    totals = _gathered_sum(payload_tree, like, n_workers, axis_names, comp)
    return jax.tree_util.tree_map(
        lambda t, l: (t / n_workers).reshape(l.shape).astype(l.dtype),
        totals, like,
    )


def _aggregate_local(grads_local, h_worker, h_server, key, cfg, axis_names,
                     n_workers, part=None):
    """The core Algorithm-1 round on LOCAL arrays (no sharding decisions).

    grads_local leaves may have any shape — they are flattened locally; the
    h leaves are flat ``(1, d_local)`` / ``(d_local,)``.  ``axis_names`` are
    the (manual) worker axes the packed payload is gathered over.  All
    operator behaviour dispatches through the configured compressor's hooks.
    With a participation ctx (``part``), the round is the sampled-sum
    generalisation: every worker still encodes (fixed-shape SPMD), but
    non-participants' gathered payloads decode to exact zeros, the server
    tail rescales, and excluded/frozen state is kept by explicit selects
    (DESIGN.md §Elasticity).
    """
    comp = cfg.make()

    g_flat = jax.tree_util.tree_map(
        lambda g: g.reshape(-1).astype(jnp.float32), grads_local
    )
    h_local = jax.tree_util.tree_map(
        lambda h: h[0].astype(jnp.float32), h_worker
    )
    if part is not None:
        h_local = _reinit_zero(part.reinit_own, h_local)

    delta = jax.tree_util.tree_map(comp.compress_input, g_flat, h_local)
    if comp.replicate_perleaf:
        # Pin the encode input replicated: sort-selection operators (top-k)
        # RET_CHECK old XLA's partitioner on sharded operands under manual
        # subgroups.  No-op outside GSPMD policies (nested-manual/reference).
        from repro.models.sharding import shard_replicated

        delta = jax.tree_util.tree_map(shard_replicated, delta)

    leaves, treedef = jax.tree_util.tree_flatten(delta)
    keys = jax.random.split(key, len(leaves))
    payloads = [comp.compress(leaf, k) for leaf, k in zip(leaves, keys)]
    payload_tree = jax.tree_util.tree_unflatten(treedef, payloads)
    # The worker's own estimate, for its memory update — decoded from the
    # payload (bitwise the transmitted value); dead-code-eliminated under jit
    # for operators whose hooks ignore it.
    dhat_own = jax.tree_util.tree_unflatten(
        treedef, [comp.decode(p, leaf.size) for p, leaf in zip(payloads, leaves)]
    )

    if part is None:
        dhat_mean = _gathered_mean(payload_tree, g_flat, n_workers, axis_names, comp)

        new_h_local = jax.tree_util.tree_map(
            lambda h, dh, dl: comp.next_memory(h, dh, dl).astype(cfg.h_dtype),
            h_local, dhat_own, delta,
        )
        new_hw = jax.tree_util.tree_map(lambda h: h[None], new_h_local)
        new_h_server = jax.tree_util.tree_map(
            lambda h, dm: comp.next_server_memory(h.astype(jnp.float32), dm).astype(cfg.h_dtype),
            h_server, dhat_mean,
        )
        ghat_flat = jax.tree_util.tree_map(
            lambda h, dm: comp.server_direction(h.astype(jnp.float32), dm),
            h_server, dhat_mean,
        )
    else:
        # Sampled sum: per-leaf payloads carry no wire checksum, so the
        # effective set is the scheduled mask itself.
        totals = _gathered_sum(payload_tree, g_flat, n_workers, axis_names,
                               comp, mask=part.mask)
        hs_leaves, hs_def = jax.tree_util.tree_flatten(h_server)
        served = [
            _masked_server_tail(comp, h.astype(jnp.float32), t, n_workers,
                                part, part.mask)
            for h, t in zip(hs_leaves, jax.tree_util.tree_leaves(totals))
        ]
        ghat_flat = jax.tree_util.tree_unflatten(hs_def, [g for g, _ in served])
        new_h_server = jax.tree_util.tree_unflatten(
            hs_def, [h.astype(cfg.h_dtype) for _, h in served])
        advance = part.m_own & part.ok
        new_h_local = _where_rows(
            advance,
            jax.tree_util.tree_map(comp.next_memory, h_local, dhat_own, delta),
            h_local,
        )
        new_hw = jax.tree_util.tree_map(
            lambda h: h.astype(cfg.h_dtype)[None], new_h_local)

    # Reshape only — ghat stays f32; the caller casts to the gradient dtypes
    # AFTER the (optional) downlink round, so the downlink compresses the
    # same f32 server direction the reference path sees.
    ghat = jax.tree_util.tree_map(
        lambda f, g: f.reshape(g.shape), ghat_flat, grads_local
    )
    return ghat, new_hw, new_h_server


def _gather_fused(payload: Payload, axis_names, groups=None):
    """All-gather ONE fused uint8 buffer instead of one collective per field.

    Every populated Payload field is byte-cast into a single contiguous
    buffer (:func:`repro.core.bucket.fuse_payload` — exact, bitcast only),
    gathered once over the worker axes, and split back locally — so the whole
    DIANA round really costs one collective, which the trace test in
    ``tests/test_bucket.py`` counts.
    """
    populated = [i for i, f in enumerate(payload) if f is not None]
    if len(populated) == 1:
        # one field IS one collective — skip the byte-cast round-trip, which
        # XLA CPU lowers as slow elementwise loops on full-size payloads
        # (e.g. natural's whole-model int16 codes)
        i = populated[0]
        fields = [None] * len(Payload._fields)
        fields[i] = _gather_field(payload[i], axis_names, groups)
        return Payload(*fields)

    buf = fuse_payload(payload)
    recipe = payload_recipe(payload)
    return unfuse_payload(_gather_field(buf, axis_names, groups), recipe)


# ---------------------------------------------------------------------------
# Two-level (hierarchical) topology + the chunked wire schedule
# ---------------------------------------------------------------------------

def _node_groups(n_workers: int, node_size: int):
    """Intra-node ``axis_index_groups``: consecutive ``node_size`` workers
    form one node (worker w lives on node ``w // node_size``)."""
    return [[b * node_size + r for r in range(node_size)]
            for b in range(n_workers // node_size)]


def _internode_groups(n_workers: int, node_size: int):
    """Inter-node ``axis_index_groups``: one worker of intra-node rank ``r``
    per node, in ascending node order — so every worker's gathered leader
    payloads arrive stacked node 0, 1, ... exactly like the reference
    mirror's node rows.  (Payloads are node-replicated — same delta, same
    node-folded key — so any rank's copy is THE node payload.)"""
    return [[b * node_size + r for b in range(n_workers // node_size)]
            for r in range(node_size)]


def _ordered_node_sum(rows, s: int):
    """The ascending ordered f32 sum over one node's ``s`` worker rows, then
    ``/ s`` — an EXPLICIT recurrence (never psum/pmean, whose reduction order
    the backend owns) shared bit for bit with the reference mirror's
    node pooling."""
    acc = rows[0]
    for i in range(1, s):
        acc = acc + rows[i]
    return acc / s


def _intranode_mean(g_flat, axis_names, n_workers: int, node_size: int):
    """Level 1 of the hierarchical round: the UNcompressed mean of the flat
    gradient buffer over this worker's node (cheap ICI bandwidth), leaving
    every worker holding its node's pooled gradient — the node gradient
    DIANA then compresses once per node instead of once per worker."""
    rows = jax.lax.all_gather(
        g_flat, axis_names[0], tiled=False,
        axis_index_groups=_node_groups(n_workers, node_size))
    return _ordered_node_sum([rows[i] for i in range(node_size)], node_size)


def _node_pool_tree(grads_per_worker, node_size: int):
    """Reference mirror of :func:`_intranode_mean`: pool stacked per-worker
    grads ``(n, ...)`` to per-node means ``(n_nodes, ...)`` with the same
    cast-to-f32 + ascending ordered sum + ``/ s`` recurrence per leaf."""

    def pool(x):
        x = x.astype(jnp.float32)
        xr = x.reshape(-1, node_size, *x.shape[1:])
        return _ordered_node_sum([xr[:, i] for i in range(node_size)],
                                 node_size)

    return jax.tree_util.tree_map(pool, grads_per_worker)


def _hier_node_size(cfg) -> int:
    """The active node size: >1 exactly when the two-level round runs."""
    return cfg.node_size if cfg.topology == "hierarchical" else 1


def _chunk_payloads(cfg, sched: ChunkedSchedule, delta, key):
    """Compress one worker's delta buffer chunk by chunk.

    THE chunk PRNG rule: the monolithic per-leaf schedule is split ONCE and
    sliced per chunk (:meth:`ChunkedSchedule.chunk_keys`), so every leaf
    draws exactly its monolithic bits and sum-of-chunks == monolithic
    bitwise.  ``fold_in(key, CHUNK_FOLD + c)`` feeds only the compiled-TPU
    in-kernel-PRNG encodes (distribution-equal mode — see CHUNK_FOLD).
    """
    base = cfg.make()
    keys = jax.random.split(key, sched.layout.n_leaves)
    return [
        base.compress_bucketed_keys(
            cl, dseg, sched.chunk_keys(keys, c),
            jax.random.fold_in(key, CHUNK_FOLD + c))
        for c, (cl, dseg) in enumerate(
            zip(sched.chunk_layouts, sched.split(delta)))
    ]


def _chunk_decode_own(cfg, sched: ChunkedSchedule, pays):
    """This worker's own dhat over the whole buffer: per-chunk decodes
    concatenated (per-coordinate, so bitwise the monolithic decode)."""
    return jnp.concatenate([
        bucketed_compressor(cfg, cl).decode(pay, cl.padded_size)
        for cl, pay in zip(sched.chunk_layouts, pays)
    ])


def _aggregate_bucketed(grads_local, h_worker, h_server, key, cfg, axis_names,
                        n_workers, part=None, faults=None, step=None):
    """Algorithm-1 round on the WHOLE model as one flat buffer.

    The single-vector formulation of the paper: grads flatten once into the
    static :class:`~repro.core.bucket.BucketLayout`, then the round is ONE
    ``compress`` (one kernel launch for kernel-backed operators), ONE fused
    all-gather, ONE ``decode_sum``, and vectorized elementwise memory
    updates on the flat ``h`` buffers.  Bitwise-equal to
    :func:`_aggregate_local` (the bucketed hooks reproduce the per-leaf PRNG
    schedule and f32 recurrences — see repro.core.bucket).

    With a participation ctx (``part``) the server tail is the sampled-sum
    generalisation (see :func:`_aggregate_local`).  With ``faults`` armed,
    the payload ALWAYS fuses into one uint8 wire buffer, an 8-byte checksum
    is appended (:func:`repro.core.bucket.add_checksum`), the worker's own
    scheduled faults are injected, and the gathered wires verify on every
    receiver — invalid payloads are excluded from the sum exactly like
    non-participants, and the sender's ``h_i`` freezes (the verdict is
    replicated, so the sender knows its payload was discarded).  Under the
    CHUNKED schedule each chunk is its own checksummed wire; a worker is
    excluded whole (valid = AND over its chunk verdicts) so the invariant
    ``h == mean h_i`` never sees a half-applied payload.

    With ``cfg.topology == "hierarchical"`` the round is the Bagua-style
    two-level exchange: the flat gradient buffer first averages UNcompressed
    over this worker's node (:func:`_intranode_mean` — ordered recurrence,
    intra-node ``axis_index_groups``), then the compressed DIANA round runs
    BETWEEN node leaders (``n_eff = n_nodes`` payloads via the inter-node
    groups) with the h-memory kept per node (every worker of a node stores
    the identical node row, so the invariant ``h == mean(h_nodes)`` holds
    exactly).  ``key`` must then be folded with the NODE index, not the
    worker index — aggregate_shardmap documents the caller contract.
    """
    layout = bucket_layout(cfg, grads_local)
    comp = bucketed_compressor(cfg, layout)
    dp = layout.padded_size

    g_flat = layout.flatten(grads_local)                 # (Dp,) f32
    node_size = _hier_node_size(cfg)
    n_eff, groups = n_workers, None
    if node_size > 1:
        assert part is None and faults is None, (
            "hierarchical topology composes with neither participation nor "
            "fault injection — aggregate_shardmap gates this")
        g_flat = _intranode_mean(g_flat, axis_names, n_workers, node_size)
        n_eff = n_workers // node_size
        groups = _internode_groups(n_workers, node_size)

    h_local = h_worker[0].astype(jnp.float32)            # (Dp,)
    if part is not None:
        h_local = jnp.where(part.reinit_own, jnp.zeros_like(h_local), h_local)
    delta = comp.compress_input(g_flat, h_local)

    sched = ChunkedSchedule.for_layout(layout, cfg.chunk_bytes)
    if sched.n_chunks > 1:
        return _aggregate_bucketed_chunked(
            layout, comp, sched, delta, h_local, h_server, key, cfg,
            axis_names, n_eff, groups, n_workers,
            part=part, faults=faults, step=step)

    payload = comp.compress(delta, key)                  # ONE Payload
    dhat_own = comp.decode(payload, dp)

    if part is None and faults is None:
        gathered = _gather_fused(payload, axis_names, groups)  # ONE collective
        # Fused server tail: decode_sum + mean + direction + memory update in
        # one hook — ONE kernel launch for kernel-backed operators (the
        # epilogue runs on the accumulator tile), the bitwise-identical hook
        # composition otherwise.
        ghat_flat, new_hs_f = comp.decode_sum_apply(
            gathered, n_eff, dp, h_server.astype(jnp.float32)
        )
        new_hw = comp.next_memory(h_local, dhat_own, delta).astype(cfg.h_dtype)[None]
        new_hs = new_hs_f.astype(cfg.h_dtype)
        # f32 leaves — the caller casts to the gradient dtypes after the
        # (optional) downlink round, like the per-leaf path.
        ghat = layout.unflatten(ghat_flat, cast=False)
        return ghat, new_hw, new_hs

    valid = None
    if faults is not None:
        buf = fuse_payload(payload)                      # always fuse: the
        # checksum covers the WHOLE wire object, single-field shortcut or not
        wire = apply_faults(add_checksum(buf), faults, step, part.widx)
        flat, valid = verify_checksum(_gather_field(wire, axis_names))
        gathered = unfuse_payload(flat.reshape(-1, *buf.shape),
                                  payload_recipe(payload))
    else:
        gathered = _gather_fused(payload, axis_names)

    m_eff = part.mask if valid is None else part.mask & valid
    total = comp.decode_sum(gathered.mask_workers(m_eff), n_workers, dp)
    ghat_flat, new_hs_f = _masked_server_tail(
        comp, h_server.astype(jnp.float32), total, n_workers, part, m_eff)
    gate = part.m_own & part.ok
    if valid is not None:
        gate = gate & jnp.any(valid & (jnp.arange(n_workers) == part.widx))
    new_h_local = jnp.where(gate, comp.next_memory(h_local, dhat_own, delta),
                            h_local)
    return (layout.unflatten(ghat_flat, cast=False),
            new_h_local.astype(cfg.h_dtype)[None],
            new_hs_f.astype(cfg.h_dtype))


def _chunk_wire_meta(bufs):
    """Per-chunk fused-wire geometry: each chunk's byte offset into the
    round's concatenated payload body, and the body total — the window
    :func:`repro.core.participation.apply_faults` maps corrupt events
    through."""
    sizes = [int(b.size) for b in bufs]
    offs, acc = [], 0
    for s in sizes:
        offs.append(acc)
        acc += s
    return offs, acc


def _aggregate_bucketed_chunked(layout, comp, sched, delta, h_local, h_server,
                                key, cfg, axis_names, n_eff, groups, n_workers,
                                part=None, faults=None, step=None):
    """The chunked (double-buffered) wire of :func:`_aggregate_bucketed`.

    The fused buffer is split into whole-leaf chunks
    (:class:`~repro.core.bucket.ChunkedSchedule`) and the round is
    software-pipelined: chunk ``c+1``'s all-gather is ISSUED before chunk
    ``c``'s ``decode_sum(+apply)``, so with async collectives the transfer of
    one chunk overlaps the decode of the previous one (the jaxpr-level
    ordering ``tests/test_bucket.py`` proves structurally).  Per-chunk
    results concatenate to bitwise the monolithic round: chunks are
    whole-leaf, keys are slices of the monolithic schedule, and every
    decode/apply recurrence is per-coordinate.  Worker-side memory updates
    stay monolithic — only the wire is chunked.
    """
    cls_ = sched.chunk_layouts
    comps = [bucketed_compressor(cfg, cl) for cl in cls_]
    pays = _chunk_payloads(cfg, sched, delta, key)
    dhat_own = _chunk_decode_own(cfg, sched, pays)
    h_s = h_server.astype(jnp.float32)
    hs_chunks = sched.split(h_s)
    C = sched.n_chunks

    if part is None and faults is None:
        # Double-buffered pipeline: gather c+1 in flight while c decodes.
        gathered = [None] * C
        gathered[0] = _gather_fused(pays[0], axis_names, groups)
        ghat_parts, hs_parts = [], []
        for c in range(C):
            if c + 1 < C:
                gathered[c + 1] = _gather_fused(pays[c + 1], axis_names, groups)
            g_c, h_c = comps[c].decode_sum_apply(
                gathered[c], n_eff, cls_[c].padded_size, hs_chunks[c])
            ghat_parts.append(g_c)
            hs_parts.append(h_c)
        ghat_flat = jnp.concatenate(ghat_parts)
        new_hs_f = jnp.concatenate(hs_parts)
        new_hw = comp.next_memory(h_local, dhat_own, delta).astype(cfg.h_dtype)[None]
        return (layout.unflatten(ghat_flat, cast=False), new_hw,
                new_hs_f.astype(cfg.h_dtype))

    valid = None
    if faults is not None:
        # Per-chunk wires, each with its own checksum tail; corrupt events
        # address the concatenated body (so they land in exactly one chunk),
        # drop/delay break every tail.  All gathers are issued before any
        # verify/decode — the collectives still overlap the decode work.
        bufs = [fuse_payload(p) for p in pays]
        offs, body_total = _chunk_wire_meta(bufs)
        wires = [
            apply_faults(add_checksum(bufs[c]), faults, step, part.widx,
                         byte_offset=offs[c], body_total=body_total)
            for c in range(C)
        ]
        gw = [_gather_field(w, axis_names) for w in wires]
        gathereds, valids = [], []
        for c in range(C):
            flat, v_c = verify_checksum(gw[c])
            valids.append(v_c)
            gathereds.append(unfuse_payload(flat.reshape(-1, *bufs[c].shape),
                                            payload_recipe(pays[c])))
        # Whole-worker exclusion: ANY corrupted chunk discards the worker's
        # round (a half-applied payload would break h == mean h_i).
        valid = valids[0]
        for v_c in valids[1:]:
            valid = valid & v_c
    else:
        gathereds = [None] * C
        gathereds[0] = _gather_fused(pays[0], axis_names, groups)
        for c in range(1, C):
            gathereds[c] = _gather_fused(pays[c], axis_names, groups)

    m_eff = part.mask if valid is None else part.mask & valid
    total = jnp.concatenate([
        comps[c].decode_sum(gathereds[c].mask_workers(m_eff), n_workers,
                            cls_[c].padded_size)
        for c in range(C)
    ])
    ghat_flat, new_hs_f = _masked_server_tail(
        comp, h_s, total, n_workers, part, m_eff)
    gate = part.m_own & part.ok
    if valid is not None:
        gate = gate & jnp.any(valid & (jnp.arange(n_workers) == part.widx))
    new_h_local = jnp.where(gate, comp.next_memory(h_local, dhat_own, delta),
                            h_local)
    return (layout.unflatten(ghat_flat, cast=False),
            new_h_local.astype(cfg.h_dtype)[None],
            new_hs_f.astype(cfg.h_dtype))


# ---------------------------------------------------------------------------
# Downlink: the compressed server broadcast (DESIGN.md §Bidirectional)
# ---------------------------------------------------------------------------

def downlink_round(ghat, h_down, down_key: jax.Array, cfg: CompressionConfig,
                   *, h_dtype=None, dcfg=None):
    """Pass the aggregated direction ``ghat`` through the DOWNLINK compressor.

    The gradient-difference trick DIANA applies uplink, applied to the server
    broadcast: the (replicated, deterministic) server encodes
    ``delta = compress_input(ghat, h_down)`` — ``ghat - h_down`` for
    alpha-memory operators, the error-compensated ``ghat + e`` for top-k EF —
    puts the payload on the broadcast (fused into ONE uint8 wire object in
    the bucketed layout — :func:`wire_roundtrip`, bitcast-exact; per-leaf
    payloads stay unfused, mirroring the uplink), and every receiver
    reconstructs
    ``server_direction(h_down, decode(payload))`` and advances the shared
    memory with ``next_memory``.  Because ``ghat``, ``h_down`` and
    ``down_key`` are identical on all workers, the broadcast needs no
    collective here — replicated determinism plays the server, exactly as the
    uplink's replicated decode does (DESIGN.md §3).

    Runs AFTER ``server_direction`` on the param-shaped ``ghat`` tree and
    makes its own layout decision (``cfg.down_config().bucketed``), so it
    composes with every uplink operator, both uplink layouts, and VR.
    ``down_key`` must be the step key folded with :data:`DOWN_FOLD` BEFORE
    any worker fold — the broadcast draws are worker-independent.

    Returns ``(ghat_hat, new_h_down)`` with ``ghat_hat`` shaped and typed
    like ``ghat``.  ``dcfg`` overrides the derived ``cfg.down_config()`` —
    grouped policies pass each rule's standalone downlink config (which may
    carry its own block size / norm power, inexpressible on a flat config).
    """
    dcfg = cfg.down_config() if dcfg is None else dcfg
    assert dcfg is not None, "downlink_round needs cfg.down_method"
    h_dtype = cfg.h_dtype if h_dtype is None else h_dtype

    if dcfg.bucketed:
        layout = bucket_layout(dcfg, ghat)
        comp = bucketed_compressor(dcfg, layout)
        g = layout.flatten(ghat)
        h = h_down.astype(jnp.float32)
        delta = comp.compress_input(g, h)
        sched = ChunkedSchedule.for_layout(layout, dcfg.chunk_bytes)
        if sched.n_chunks > 1:
            # Chunked broadcast wire: each chunk rides its own fused uint8
            # wire object (the same schedule as the uplink), decodes
            # per-coordinate and concatenates — bitwise the monolithic
            # broadcast.
            pays = [wire_roundtrip(p)
                    for p in _chunk_payloads(dcfg, sched, delta, down_key)]
            dhat = _chunk_decode_own(dcfg, sched, pays)
        else:
            pay = wire_roundtrip(comp.compress(delta, down_key))
            dhat = comp.decode(pay, layout.padded_size)
        ghat_hat = layout.unflatten(comp.server_direction(h, dhat), cast=True)
        new_h = comp.next_memory(h, dhat, delta).astype(h_dtype)
        return ghat_hat, new_h

    comp = dcfg.make()
    g_flat = jax.tree_util.tree_map(
        lambda x: x.reshape(-1).astype(jnp.float32), ghat
    )
    h = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), h_down)
    delta = jax.tree_util.tree_map(comp.compress_input, g_flat, h)
    if comp.replicate_perleaf:
        # Same partitioner pin as the uplink per-leaf encode (see
        # _aggregate_local) — the broadcast encode runs in the same
        # partial-manual body.
        from repro.models.sharding import shard_replicated

        delta = jax.tree_util.tree_map(shard_replicated, delta)
    leaves, treedef = jax.tree_util.tree_flatten(delta)
    keys = jax.random.split(down_key, len(leaves))
    # Per-leaf payloads stay UNfused, mirroring the uplink (only the bucketed
    # layout builds the single wire buffer): the fuse bitcasts RET_CHECK old
    # XLA's partitioner under partial-manual bodies with live auto inner
    # axes — exactly the meshes resolve_bucketed downgrades to this layout.
    pays = [comp.compress(leaf, k) for leaf, k in zip(leaves, keys)]
    dhat = jax.tree_util.tree_unflatten(
        treedef, [comp.decode(p, leaf.size) for p, leaf in zip(pays, leaves)]
    )
    ghat_hat = jax.tree_util.tree_map(
        lambda hh, dh, g: comp.server_direction(hh, dh).reshape(g.shape).astype(g.dtype),
        h, dhat, ghat,
    )
    new_h = jax.tree_util.tree_map(
        lambda hh, dh, dl: comp.next_memory(hh, dh, dl).astype(h_dtype),
        h, dhat, delta,
    )
    return ghat_hat, new_h


def aggregate_shardmap(
    grads_local,
    state: DianaState,
    key: jax.Array,
    cfg: CompressionConfig,
    *,
    axis_names: Sequence[str],
    n_workers: int,
    inner_axes: Sequence[str] = (),
    grad_specs=None,
    h_specs=None,
    mesh=None,
    vr_aux=None,
    params_local=None,
    vr_force_refresh=None,
    down_key=None,
    part_key=None,
    step=None,
    worker_index=None,
    faults=None,
):
    """One DIANA aggregation round inside a shard_map body.

    grads_local — this worker's local gradient pytree (g_i^k).
    state.h_worker leaves arrive with local leading dim 1 (own memory only).
    key          — already folded with the worker index (deterministic stream).

    With ``state.vr`` present (``cfg.vr``) the round is VR-DIANA
    (repro.core.vr): the compressor consumes the control-variated estimator
    ``k_i = g_i - grad f_{ij}(w_i) + mu_i`` instead of ``g_i``, and the
    (snapshot, mu) pair refreshes with the worker's Bernoulli(``cfg.vr_p``)
    coin drawn from ``fold_in(key, VR_FOLD)``.  Callers must then supply

    * ``vr_aux = (grads_at_snapshot, mu_candidate)`` — this worker's
      gradient at its snapshot ``w_i`` on the SAME minibatch, and the value
      ``mu_i`` takes on refresh (the full local gradient at ``x^k`` in the
      finite-sum setting; the minibatch gradient in the streaming trainer);
      both parameter-shaped local trees (no leading worker dim);
    * ``params_local`` — the current iterate ``x^k`` (the refreshed snapshot);
    * optionally ``vr_force_refresh`` — a traced bool OR-ed into the coin
      (the trainer forces a refresh at step 0 to populate a zeros-init mu).

    The VR algebra runs on parameter-shaped trees BEFORE any layout
    decision, so it composes with every operator in both the per-leaf and
    bucketed layouts, and ``ghat`` is cast back to the gradients' dtypes.

    With ``state.h_down`` present (``cfg.down_method``) the round is
    BIDIRECTIONAL: the aggregated direction is itself passed through the
    downlink compressor (:func:`downlink_round`) before being returned, and
    callers must supply ``down_key = fold_in(key, DOWN_FOLD)`` computed from
    the step key BEFORE the worker fold (the broadcast draws are identical on
    every worker — repro.launch.train does this).

    With ``cfg.bucketed`` the round runs on the whole-model flat buffer
    (:func:`_aggregate_bucketed`: one compress, one fused all-gather, one
    decode_sum) and ``state`` must carry the bucketed single-buffer layout
    from :func:`init_state`; callers on toolchains where that cannot lower
    (live auto inner axes on old XLA) must downgrade the config first —
    ``repro.launch.train.resolve_bucketed`` owns that decision.

    When ``inner_axes`` (the non-worker mesh axes, e.g. ('model',) or
    ('data','model')) are given together with per-leaf PartitionSpecs, the
    whole round runs inside a NESTED fully-manual shard_map: each inner
    device encodes / decodes ITS OWN shard of every gradient leaf and the
    payload all-gather runs over the (outer-manual) worker axes.  No
    relayout, no partitioner decisions — XLA's SPMD partitioner crashes on
    several of them under manual subgroups (DESIGN.md §6).  The h memory is
    stored in this shard-local flat layout, which is self-consistent step to
    step (its global ordering is internal state, never interpreted).

    With a non-trivial ``participation`` spec on the config/policy the round
    is ELASTIC (DESIGN.md §Elasticity): callers must supply

    * ``part_key = fold_in(step_key, PART_FOLD)`` — derived BEFORE the
      worker fold, like ``down_key`` (the (n,) mask is identical on every
      worker);
    * ``worker_index`` — this worker's linear index (a traced scalar is
      fine: own-bit extraction is an elementwise one-hot reduce);
    * ``step`` — the scalar step counter, required when the spec has a churn
      schedule (and always with ``faults``).

    ``faults`` (a :class:`~repro.core.participation.FaultPlan`, may be
    empty) arms the wire checksum; it requires the flat BUCKETED layout
    (the checksum rides the fused uint8 wire buffer).

    Returns ``(ghat, new_state)`` with ``ghat`` identical on all workers and
    shaped/sharded like ``grads_local``.
    """
    axis_names = tuple(axis_names)
    inner_axes = tuple(inner_axes)
    policy, cfg = _split_spec(cfg)
    vr_p = policy.vr_p if policy is not None else cfg.vr_p

    spec = _resolve_participation(policy, cfg)
    if spec is None and faults is not None:
        spec = ParticipationSpec()  # checksum-only mode: all-true mask,
        # exclusion algebra driven purely by checksum verdicts
    part = None
    if spec is not None:
        assert part_key is not None, (
            "elastic aggregation needs part_key = fold_in(step_key, "
            "PART_FOLD) derived BEFORE the worker fold (identical on all "
            "workers)")
        assert worker_index is not None, (
            "elastic aggregation needs worker_index (this worker's linear "
            "index on the worker mesh axes)")
        if spec.churn or faults is not None:
            assert step is not None, (
                "a churn schedule / fault plan needs the scalar step counter")
        part = step_ctx(spec, part_key, n_workers,
                        0 if step is None else step, worker_index)
    if faults is not None:
        assert policy is None and cfg.bucketed, (
            "fault injection rides the bucketed fused wire buffer — use a "
            "flat cfg with bucketed=True")
    if policy is not None and policy.topology == "hierarchical":
        raise NotImplementedError(
            "hierarchical topology currently runs only on flat (uniform) "
            "bucketed configs — grouped policies keep topology='flat'")
    if cfg is not None and _hier_node_size(cfg) > 1:
        # Two-level rounds compose with neither elasticity nor VR (the node
        # mean is an uncompressed barrier over healthy in-node workers), and
        # the group partition is a single worker axis by construction.
        # Callers must fold ``key`` with the NODE index (widx // node_size),
        # not the worker index — the inter-node exchange is one DIANA round
        # over node leaders and the reference scans over nodes.
        assert spec is None and faults is None and state.vr is None, (
            "topology='hierarchical' composes with neither participation/"
            "faults nor VR")
        assert len(axis_names) == 1, (
            "topology='hierarchical' needs a single worker mesh axis (the "
            "node groups are index windows on one axis)")
        assert n_workers % cfg.node_size == 0, (
            f"node_size={cfg.node_size} must divide n_workers={n_workers}")

    grads_in = grads_local
    new_vr = state.vr
    if state.vr is not None:
        assert vr_p is not None, (
            "VR aggregation needs a concrete snapshot probability — resolve "
            "cfg.vr_p (repro.core.vr.resolve_vr_p) before building the step")
        assert vr_aux is not None and params_local is not None, (
            "VR aggregation needs vr_aux=(grads_at_snapshot, mu_candidate) "
            "and params_local")
        g_snap, mu_cand = vr_aux
        mu_own = jax.tree_util.tree_map(
            lambda m: m[0].astype(jnp.float32), state.vr.mu
        )
        grads_in = control_variate(grads_local, g_snap, mu_own)
        coins = vr_coin(key, vr_p)[None]
        if vr_force_refresh is not None:
            coins = coins | jnp.asarray(vr_force_refresh, bool)
        if part is not None:
            # Frozen-memory rule: a non-participant's (snapshot, mu) must not
            # advance, and nothing advances on a degraded step.  Gated on the
            # SCHEDULED mask only — never the checksum verdict: a corrupted
            # wire is receiver-side, the local snapshot refresh already
            # happened (repro.core.vr).
            coins = coins & (part.m_own & part.ok)
        new_vr = refresh(
            state.vr, coins, params_local,
            jax.tree_util.tree_map(lambda g: g[None], mu_cand),
        )

    if policy is not None:
        ghat, new_hw, new_hs, new_h_down = _aggregate_grouped(
            grads_in, state, key, policy,
            axis_names=axis_names, n_workers=n_workers, inner_axes=inner_axes,
            grad_specs=grad_specs, h_specs=h_specs, mesh=mesh,
            down_key=down_key, part=part,
        )
    else:
        ghat, new_hw, new_hs = _dispatch_round(
            grads_in, state, key, cfg,
            axis_names=axis_names, n_workers=n_workers, inner_axes=inner_axes,
            grad_specs=grad_specs, h_specs=h_specs, mesh=mesh,
            part=part, faults=faults, step=step,
        )
        new_h_down = state.h_down
        if state.h_down is not None:
            assert down_key is not None, (
                "bidirectional aggregation needs down_key = fold_in(step_key, "
                "DOWN_FOLD) derived BEFORE the worker fold (identical on all "
                "workers)")
            ghat, new_h_down = downlink_round(ghat, state.h_down, down_key, cfg)
            if part is not None:
                # Degraded step: nothing to broadcast — the downlink memory
                # freezes and ghat stays zero.  (On non-degraded steps every
                # worker — participant or not — advances the replicated
                # h_down: the broadcast is modelled as received by all.)
                new_h_down = _where_rows(part.ok, new_h_down, state.h_down)
                ghat = jax.tree_util.tree_map(
                    lambda g: jnp.where(part.ok, g, jnp.zeros_like(g)), ghat)
    # The round (and the downlink, when on) ran in f32 — the bits the
    # reference path produces; restore the caller's gradient dtypes here so
    # the optimizer state layout is independent of the vr/downlink flags.
    ghat = jax.tree_util.tree_map(
        lambda f, g: f.astype(g.dtype), ghat, grads_local
    )
    return ghat, DianaState(h_worker=new_hw, h_server=new_hs, vr=new_vr,
                            h_down=new_h_down)


def _pspec_leaf(s) -> bool:
    from jax.sharding import PartitionSpec as P

    return isinstance(s, P)


def _aggregate_grouped(
    grads_local, state, key, policy: CompressionPolicy, *,
    axis_names, n_workers, inner_axes, grad_specs, h_specs, mesh, down_key,
    part=None,
):
    """One aggregation round of a GROUPED policy inside the shard_map body.

    The partition (cached, pure function of (policy, tree structure)) splits
    the gradient tree into per-rule groups; each group then runs the SAME
    sub-round the flat path runs — the pmean fast path for identity groups,
    :func:`_aggregate_bucketed` on the group's own
    :class:`~repro.core.bucket.BucketLayout` (one compress, one fused
    all-gather, one decode_sum PER GROUP), or the per-leaf round — with the
    group-folded key ``fold_in(worker_key, GROUP_FOLD+g)``, so mixed operators
    share one aggregation step.  Groups with a ``down`` spec pass their slice
    of the server direction through their own downlink compressor before the
    merge.  Returns ``(ghat, h_worker, h_server, h_down)`` with the state
    trees as group-name dicts (matching :func:`_init_grouped`).

    Participation is POLICY-level: the one ctx (``part``, resolved by the
    caller from the pre-group-fold PART_FOLD stream) applies to every group
    — a worker is in or out of the whole step, never of one group — so the
    mask draw count is independent of the group structure.
    """
    part_ = partition_for(policy, grads_local)
    g_groups = part_.split(grads_local)
    spec_groups = (part_.split(grad_specs, is_leaf=_pspec_leaf)
                   if grad_specs is not None else None)
    hspec_groups = (part_.split(h_specs, is_leaf=_pspec_leaf)
                    if h_specs is not None else None)

    ghat_groups = []
    new_hw, new_hs, new_hd = {}, {}, {}
    for g, gname in enumerate(part_.group_names):
        cfg_g = part_.configs[g]
        comp = cfg_g.make()
        gkey = jax.random.fold_in(key, GROUP_FOLD + g)
        hw_g, hs_g = state.h_worker[gname], state.h_server[gname]
        if comp.prefers_allreduce and part is None:
            # identity's pmean fast path only without participation: the
            # masked round must gather + mask + decode_sum (the reference
            # recurrence), which also brings identity under the bitwise
            # contract whenever a mask is live
            ghat_g = [
                jax.lax.pmean(gr, axis_names) if axis_names else gr
                for gr in g_groups[g]
            ]
        elif cfg_g.bucketed:
            ghat_g, hw_g, hs_g = _aggregate_bucketed(
                g_groups[g], hw_g, hs_g, gkey, cfg_g, axis_names, n_workers,
                part=part)
        else:
            ghat_g, hw_g, hs_g = _perleaf_round(
                g_groups[g], hw_g, hs_g, gkey, cfg_g,
                axis_names=axis_names, n_workers=n_workers,
                inner_axes=inner_axes,
                grad_specs=spec_groups[g] if spec_groups is not None else None,
                h_specs=hspec_groups[g] if hspec_groups is not None else None,
                mesh=mesh, part=part)
        dcfg = part_.down_configs[g]
        if dcfg is not None:
            assert down_key is not None, (
                "a policy with downlink rules needs down_key = "
                "fold_in(step_key, DOWN_FOLD) derived BEFORE the worker fold")
            ghat_g, hd_g = downlink_round(
                ghat_g, state.h_down[gname],
                jax.random.fold_in(down_key, GROUP_FOLD + g), cfg_g,
                dcfg=dcfg, h_dtype=policy.h_dtype)
            if part is not None:
                hd_g = _where_rows(part.ok, hd_g, state.h_down[gname])
                ghat_g = jax.tree_util.tree_map(
                    lambda x: jnp.where(part.ok, x, jnp.zeros_like(x)), ghat_g)
            new_hd[gname] = hd_g
        ghat_groups.append(ghat_g)
        new_hw[gname] = hw_g
        new_hs[gname] = hs_g
    ghat = part_.merge(ghat_groups)
    return ghat, new_hw, new_hs, (new_hd if new_hd else None)


def _dispatch_round(
    grads_local, state, key, cfg, *,
    axis_names, n_workers, inner_axes, grad_specs, h_specs, mesh,
    part=None, faults=None, step=None,
):
    """Route one (possibly control-variated) gradient tree through the
    layout-appropriate Algorithm-1 round; returns ``(ghat, new_hw, new_hs)``."""
    comp = cfg.make()
    if comp.prefers_allreduce and part is None:
        # dense stateless payload: the gathered mean IS a fused all-reduce.
        # Under participation the masked gather+decode_sum path runs instead
        # — identity then joins the bitwise reference contract.
        ghat = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis_names) if axis_names else g,
            grads_local,
        )
        return ghat, state.h_worker, state.h_server

    if cfg.bucketed:
        # The flat buffer is ONE global object, so the bucketed round always
        # runs with the inner (non-worker) axes auto: GSPMD relayouts the
        # leaf shards into/out of the buffer over fast intra-pod ICI, and the
        # nested fully-manual mode (whose point is per-leaf shard-local
        # encode/decode) does not apply — a shard-local sub-layout is future
        # work, tracked in DESIGN.md §Perf.
        return _aggregate_bucketed(
            grads_local, state.h_worker, state.h_server, key, cfg,
            axis_names, n_workers, part=part, faults=faults, step=step,
        )

    return _perleaf_round(
        grads_local, state.h_worker, state.h_server, key, cfg,
        axis_names=axis_names, n_workers=n_workers, inner_axes=inner_axes,
        grad_specs=grad_specs, h_specs=h_specs, mesh=mesh, part=part,
    )


def _perleaf_round(grads_local, h_worker, h_server, key, cfg, *,
                   axis_names, n_workers, inner_axes, grad_specs, h_specs,
                   mesh, part=None):
    """The per-leaf Algorithm-1 round, nested-manual where the toolchain and
    caller-provided specs allow (DESIGN.md §6), local otherwise.  Shared by
    the flat path and by each per-leaf GROUP of a grouped policy (whose trees
    are leaf lists — any pytree works)."""
    if not inner_axes or grad_specs is None or part is not None:
        # single-device / tests: everything already local.  Participation
        # also takes this branch: the ctx's traced mask arrays cannot ride
        # the nested-manual body's closure, and under GSPMD auto inner axes
        # the local round is correct (the nested-manual mode is a perf
        # specialisation, not a semantics change).
        return _aggregate_local(
            grads_local, h_worker, h_server, key, cfg, axis_names, n_workers,
            part=part,
        )

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map as _shard_map
    from repro.models.sharding import NoopPolicy, sharding_policy

    amesh = None
    try:
        amesh = jax.sharding.get_abstract_mesh()
    except Exception:
        pass
    if amesh is None or amesh.empty:
        amesh = mesh  # plain-jit caller (no outer shard_map): concrete mesh
    assert amesh is not None, "aggregate_shardmap needs a mesh for the nested map"

    def body(grads, h_w, h_s, k):
        with sharding_policy(NoopPolicy()):
            return _aggregate_local(grads, h_w, h_s, k, cfg, axis_names, n_workers)

    hw_specs = jax.tree_util.tree_map(lambda s: P(None, *s), h_specs,
                                      is_leaf=_pspec_leaf)
    in_specs = (grad_specs, hw_specs, h_specs, P())
    out_specs = (grad_specs, hw_specs, h_specs)
    return _shard_map(
        body, mesh=amesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=set(inner_axes), check_vma=False,
    )(grads_local, h_worker, h_server, key)


# ---------------------------------------------------------------------------
# Single-process n-worker reference (tests, convex experiments, figures)
# ---------------------------------------------------------------------------

class ReferenceState(NamedTuple):
    h_worker: Any  # (n, d) per leaf — flat, mirroring DianaState (or ONE
                   # (n, Dp) buffer in bucketed mode)
    h_server: Any  # (d,) per leaf — flat (or (Dp,) bucketed)
    v: Any         # momentum buffer, like params
    vr: Any = None # optional VR-DIANA slot, mirroring DianaState.vr
    h_down: Any = None  # optional downlink memory, mirroring DianaState.h_down


def reference_init(params, cfg, n_workers: int) -> ReferenceState:
    policy, cfg = _split_spec(cfg)
    if policy is not None:
        vr = init_vr(params, n_workers) if policy.vr else None
        h_w, h_s, h_down = _init_grouped(params, policy, n_workers,
                                         dtype=jnp.float32)
        return ReferenceState(h_worker=h_w, h_server=h_s,
                              v=tree_zeros_like(params, jnp.float32),
                              vr=vr, h_down=h_down)
    vr = init_vr(params, n_workers) if cfg.vr else None
    h_down = init_downlink(params, cfg, dtype=jnp.float32)
    if cfg.bucketed:
        dp = bucket_layout(cfg, params).padded_size
        return ReferenceState(
            h_worker=jnp.zeros((n_workers, dp), jnp.float32),
            h_server=jnp.zeros((dp,), jnp.float32),
            v=tree_zeros_like(params, jnp.float32),
            vr=vr,
            h_down=h_down,
        )
    return ReferenceState(
        h_worker=jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_workers, p.size), jnp.float32), params
        ),
        h_server=jax.tree_util.tree_map(
            lambda p: jnp.zeros((p.size,), jnp.float32), params
        ),
        v=tree_zeros_like(params, jnp.float32),
        vr=vr,
        h_down=h_down,
    )


def reference_step(
    grads_per_worker,
    state: ReferenceState,
    key: jax.Array,
    cfg: CompressionConfig,
    *,
    beta: float = 0.0,
    vr_aux=None,
    params=None,
    vr_force_refresh=None,
    step=None,
    faults=None,
):
    """Aggregate stacked per-worker grads (n, ...) exactly as Algorithm 1.

    Bit-for-bit aligned with :func:`aggregate_shardmap`: worker ``i`` draws
    from ``fold_in(key, i)`` through the same compress path (per-leaf or
    bucketed, by ``cfg.bucketed``), and the mean runs through the same
    :meth:`Compressor.decode_sum` sequential f32 recurrence as the
    distributed decode — tests assert exact equality between the two, and
    between the two layouts.

    With ``state.vr`` present (``cfg.vr``) this is VR-DIANA: the stacked
    gradients are control-variated against the per-worker (snapshot, mu)
    state before compression, and the snapshots refresh on per-worker
    Bernoulli(``cfg.vr_p``) coins — the SAME draws and where-selects as the
    distributed path (repro.core.vr's PRNG schedule contract), so bitwise
    equality extends to VR runs.  ``vr_aux = (grads_at_snapshot,
    mu_candidate)`` stacks the distributed per-worker aux trees
    (``(n, *shape)`` leaves) and ``params`` is the current iterate.

    With ``state.h_down`` present (``cfg.down_method``) the aggregated
    direction additionally passes through the downlink compressor
    (:func:`downlink_round`) before the momentum accumulate — the same
    code and the same ``fold_in(key, DOWN_FOLD)`` stream as the distributed
    path, so bitwise equality extends to bidirectional runs.

    The bucketed path scans over workers (``lax.scan``: one traced body
    regardless of n).  The per-leaf cross-check path deliberately keeps the
    unrolled Python loop: its callers (the convex experiments and the paper
    figures) drive it EAGERLY step by step, where an un-jitted scan would
    re-trace its body on every call — the unrolled ops dispatch faster, and
    under jit both forms compile to the same per-worker program.

    With a non-trivial ``participation`` spec the round is ELASTIC: the
    (n,) mask draws from ``fold_in(key, PART_FOLD)`` — the identical stream
    the distributed path receives as ``part_key`` — and ``step`` (default 0)
    drives the churn schedule.  ``faults`` arms the wire checksum exactly as
    in :func:`aggregate_shardmap` (flat bucketed configs only).

    Returns (v, new_state): ``v = beta*v + ghat`` — caller does the prox step.
    """
    policy, cfg = _split_spec(cfg)
    vr_p = policy.vr_p if policy is not None else cfg.vr_p

    spec = _resolve_participation(policy, cfg)
    if spec is None and faults is not None:
        spec = ParticipationSpec()
    part = None
    if spec is not None:
        if spec.churn or faults is not None:
            assert step is not None, (
                "a churn schedule / fault plan needs the step= kwarg")
        nw = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]
        part = step_ctx(spec, jax.random.fold_in(key, PART_FOLD), nw,
                        0 if step is None else step)
    if faults is not None:
        assert policy is None and cfg.bucketed, (
            "fault injection rides the bucketed fused wire buffer — use a "
            "flat cfg with bucketed=True")
    if policy is not None and policy.topology == "hierarchical":
        raise NotImplementedError(
            "hierarchical topology currently runs only on flat (uniform) "
            "bucketed configs — grouped policies keep topology='flat'")
    if cfg is not None and _hier_node_size(cfg) > 1:
        # Mirror of the aggregate_shardmap gate: two-level rounds compose
        # with neither elasticity nor VR, and worker count must tile into
        # whole nodes.  The scan inside _reference_agg_bucketed then runs
        # over nodes with fold_in(key, node) — the node key the distributed
        # callers fold.
        assert spec is None and faults is None and state.vr is None, (
            "topology='hierarchical' composes with neither participation/"
            "faults nor VR")
        nw = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]
        assert nw % cfg.node_size == 0, (
            f"node_size={cfg.node_size} must divide n_workers={nw}")

    new_vr = state.vr
    if state.vr is not None:
        assert vr_p is not None, (
            "VR reference step needs a concrete cfg.vr_p "
            "(repro.core.vr.resolve_vr_p)")
        assert vr_aux is not None and params is not None, (
            "VR reference step needs vr_aux=(grads_at_snapshot, mu_candidate) "
            "and params")
        g_snap, mu_cand = vr_aux
        grads_per_worker = control_variate(grads_per_worker, g_snap, state.vr.mu)
        nw = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]
        coins = reference_coins(key, vr_p, nw)
        if vr_force_refresh is not None:
            coins = coins | jnp.asarray(vr_force_refresh, bool)
        if part is not None:
            # Snapshots refresh only for participants on a non-degraded step
            # — the scheduled mask, never the wire-checksum verdict (the
            # distributed coins are drawn before the gather).
            coins = coins & _participant_gate(part)
        new_vr = refresh(state.vr, coins, params, mu_cand)

    if policy is not None:
        ghat, new_hw, new_hs, new_hd = _reference_grouped(
            grads_per_worker, state, key, policy, part=part)
        v = jax.tree_util.tree_map(lambda v0, g: beta * v0 + g, state.v, ghat)
        return v, state._replace(h_worker=new_hw, h_server=new_hs, v=v,
                                 vr=new_vr, h_down=new_hd)

    if cfg.bucketed:
        ghat, new_hw, new_hs = _reference_agg_bucketed(
            grads_per_worker, state.h_worker, state.h_server, key, cfg,
            part=part, faults=faults, step=step)
    else:
        ghat, new_hw, new_hs = _reference_agg_perleaf(
            grads_per_worker, state.h_worker, state.h_server, key, cfg,
            part=part)
    new_state = state._replace(h_worker=new_hw, h_server=new_hs)
    return _reference_finish(ghat, state, new_state, new_vr, key, cfg, beta,
                             part=part)


def _reference_grouped(grads_per_worker, state, key, policy: CompressionPolicy,
                       part=None):
    """The reference-path mirror of :func:`_aggregate_grouped`: the same
    partition, the same per-group sub-rounds, the same
    ``fold_in(worker_key, GROUP_FOLD+g)`` draws (the group fold is applied
    AFTER the worker fold on both paths) and the same per-group downlink
    streams ``fold_in(fold_in(key, DOWN_FOLD), GROUP_FOLD+g)`` — so grouped
    distributed and reference runs stay bitwise-aligned for every
    non-identity operator (identity keeps its documented pmean exemption —
    which, like the distributed side, is suspended whenever a participation
    ctx is live, because a masked round must run the gather+decode_sum
    recurrence).  The ONE policy-level ``part`` ctx applies to every group."""
    part_ = partition_for(policy, grads_per_worker)
    g_groups = part_.split(grads_per_worker)
    ghat_groups = []
    new_hw, new_hs, new_hd = {}, {}, {}
    for g, gname in enumerate(part_.group_names):
        cfg_g = part_.configs[g]
        hw_g, hs_g = state.h_worker[gname], state.h_server[gname]
        agg = (_reference_agg_bucketed if cfg_g.bucketed
               else _reference_agg_perleaf)
        ghat_g, hw_g, hs_g = agg(g_groups[g], hw_g, hs_g, key, cfg_g,
                                 gfold=GROUP_FOLD + g, part=part)
        dcfg = part_.down_configs[g]
        if dcfg is not None:
            ghat_g, hd_g = downlink_round(
                ghat_g, state.h_down[gname],
                jax.random.fold_in(jax.random.fold_in(key, DOWN_FOLD),
                                   GROUP_FOLD + g),
                cfg_g, dcfg=dcfg, h_dtype=jnp.float32)
            if part is not None:
                hd_g = _where_rows(part.ok, hd_g, state.h_down[gname])
                ghat_g = jax.tree_util.tree_map(
                    lambda x: jnp.where(part.ok, x, jnp.zeros_like(x)), ghat_g)
            new_hd[gname] = hd_g
        ghat_groups.append(ghat_g)
        new_hw[gname] = hw_g
        new_hs[gname] = hs_g
    return part_.merge(ghat_groups), new_hw, new_hs, (new_hd if new_hd else None)


def _worker_key(key, w, gfold):
    """The per-worker compression key: ``fold_in(key, w)``, then the group
    fold for grouped policies — matching the distributed side, where the
    worker fold happens at the caller and the group fold in
    :func:`_aggregate_grouped`."""
    k = jax.random.fold_in(key, w)
    if gfold is not None:
        k = jax.random.fold_in(k, gfold)
    return k


def _reference_agg_perleaf(grads_per_worker, h_worker, h_server, key, cfg,
                           gfold=None, part=None):
    """The per-leaf reference AGGREGATION on any pytree of stacked per-worker
    grads (full trees on the flat path, leaf lists per policy group);
    returns ``(ghat, new_h_worker, new_h_server)``.  With a participation
    ctx the round is the sampled-sum generalisation of
    :func:`_aggregate_local`: churn-join rows re-init first, every worker
    still encodes, non-participants' stacked payload rows decode to exact
    zeros (:meth:`Payload.mask_workers`), the server tail runs
    :func:`_masked_server_tail` and only :func:`_participant_gate` rows
    advance their memory."""
    comp = cfg.make()
    n = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]
    if part is not None:
        h_worker = _reinit_zero(part.reinit, h_worker)

    payload_trees = []
    new_h_rows = []
    for w in range(n):
        gw = jax.tree_util.tree_map(
            lambda g: g[w].astype(jnp.float32).reshape(-1), grads_per_worker
        )
        hw = jax.tree_util.tree_map(
            lambda h: h[w].astype(jnp.float32), h_worker
        )
        delta = jax.tree_util.tree_map(comp.compress_input, gw, hw)

        leaves, treedef = jax.tree_util.tree_flatten(delta)
        keys = jax.random.split(_worker_key(key, w, gfold), len(leaves))
        payloads = [comp.compress(leaf, k) for leaf, k in zip(leaves, keys)]
        dhat_w = jax.tree_util.tree_unflatten(
            treedef, [comp.decode(p, leaf.size) for p, leaf in zip(payloads, leaves)]
        )
        payload_trees.append(jax.tree_util.tree_unflatten(treedef, payloads))
        new_h_rows.append(jax.tree_util.tree_map(
            comp.next_memory, hw, dhat_w, delta
        ))

    # Stack per-worker payloads into the gathered layout (leading worker axis)
    # and decode through the same summation path as the distributed server.
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payload_trees)
    like_leaves, treedef = jax.tree_util.tree_flatten(
        jax.tree_util.tree_map(
            lambda g: g[0].astype(jnp.float32).reshape(-1), grads_per_worker
        )
    )
    pay_leaves = jax.tree_util.tree_leaves(stacked, is_leaf=_is_payload)
    hs_leaves = jax.tree_util.tree_leaves(h_server)
    if part is None:
        served = [
            comp.decode_sum_apply(pay, n, l.size, hs)
            for pay, l, hs in zip(pay_leaves, like_leaves, hs_leaves)
        ]
        new_hw = jax.tree_util.tree_map(
            lambda *rows: jnp.stack(rows), *new_h_rows)
    else:
        # Sampled sum — the same decode_sum + _masked_server_tail composition
        # as the distributed masked round (per-leaf payloads carry no wire
        # checksum, so the effective set is the scheduled mask).
        served = [
            _masked_server_tail(
                comp, hs.astype(jnp.float32),
                comp.decode_sum(pay.mask_workers(part.mask), n, l.size),
                n, part, part.mask)
            for pay, l, hs in zip(pay_leaves, like_leaves, hs_leaves)
        ]
        new_hw = _where_rows(
            _participant_gate(part),
            jax.tree_util.tree_map(lambda *rows: jnp.stack(rows), *new_h_rows),
            h_worker,
        )
    ghat_flat = jax.tree_util.tree_unflatten(treedef, [g for g, _ in served])
    new_hs = jax.tree_util.tree_unflatten(treedef, [h for _, h in served])
    ghat = jax.tree_util.tree_map(
        lambda f, g: f.reshape(g.shape[1:]), ghat_flat, grads_per_worker
    )
    return ghat, new_hw, new_hs


def _reference_finish(ghat, state, new_state, new_vr, key, cfg, beta,
                      part=None):
    """Shared reference tail: the downlink round (when configured) on the
    param-shaped ``ghat`` — the SAME :func:`downlink_round` the distributed
    path runs, with the same ``fold_in(key, DOWN_FOLD)`` stream — then the
    momentum accumulate ``v = beta*v + ghat``.  On a degraded elastic step
    the downlink memory freezes and ``ghat`` re-zeros (the broadcast carries
    nothing), mirroring the distributed flat tail."""
    new_h_down = state.h_down
    if state.h_down is not None:
        ghat, new_h_down = downlink_round(
            ghat, state.h_down, jax.random.fold_in(key, DOWN_FOLD), cfg,
            h_dtype=jnp.float32,
        )
        if part is not None:
            new_h_down = _where_rows(part.ok, new_h_down, state.h_down)
            ghat = jax.tree_util.tree_map(
                lambda g: jnp.where(part.ok, g, jnp.zeros_like(g)), ghat)
    v = jax.tree_util.tree_map(lambda v0, g: beta * v0 + g, state.v, ghat)
    return v, new_state._replace(v=v, vr=new_vr, h_down=new_h_down)


def _reference_agg_bucketed(grads_per_worker, h_worker, h_server, key, cfg,
                            gfold=None, part=None, faults=None, step=None):
    """The bucketed reference AGGREGATION (uplink only — downlink and
    momentum live in the callers' shared tails): scan over workers, each
    round ONE compress on the flattened model (or policy group); ONE fused
    decode_sum+apply over the scan-stacked payload.  The worker loop stays a
    ``lax.scan`` on purpose: an eagerly-unrolled loop compiles each
    ``compress`` in its own context, and XLA is free to reassociate the p=2
    block-norm reduction differently there — 1-ulp scale drift against the
    per-leaf reference (same compile-context sensitivity as the FMA
    contraction note in kernels/sparse.py).  Bitwise-equal to the per-leaf
    reference (same draws, same recurrences) and to the distributed bucketed
    path.

    Hierarchical topology mirrors the two-level distributed round: grads
    pool to node means first (:func:`_node_pool_tree` — the identical
    ordered recurrence the shardmap path uses), the scan then runs over
    NODES with the node-leader h rows, and the returned worker memory
    re-duplicates each node row over its workers so ``h == mean(h_i)``
    holds over workers and nodes alike.  The chunked schedule mirrors the
    chunked wire: the scan stacks a tuple of per-chunk payloads (same
    monolithic key slices, see CHUNK_FOLD note), each decode_sum(+apply)
    runs per chunk against the matching ``h_server`` slice, and the
    results concatenate — bitwise the monolithic round."""
    node_size = _hier_node_size(cfg)
    if node_size > 1:
        assert part is None and faults is None, (
            "hierarchical topology composes with neither participation nor "
            "fault injection (reference_step gates this)")
        grads_per_worker = _node_pool_tree(grads_per_worker, node_size)
        # Rows within a node are identical by construction (see the
        # re-duplication below), so the leader rows ARE the node memories.
        h_worker = h_worker[::node_size]
    layout = bucket_layout(cfg, jax.tree_util.tree_map(
        lambda g: g[0], grads_per_worker
    ))
    comp = bucketed_compressor(cfg, layout)
    dp = layout.padded_size
    n = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]
    if part is not None:
        h_worker = _reinit_zero(part.reinit, h_worker)

    sched = ChunkedSchedule.for_layout(layout, cfg.chunk_bytes)
    chunked = sched.n_chunks > 1
    cls_ = sched.chunk_layouts
    comps = [bucketed_compressor(cfg, cl) for cl in cls_] if chunked else []
    base = cfg.make()

    def worker_round(_, xs):
        w, g_row, h_row = xs
        flat_g = layout.flatten(g_row)
        delta = comp.compress_input(flat_g, h_row)
        wkey = _worker_key(key, w, gfold)
        if chunked:
            keys = jax.random.split(wkey, layout.n_leaves)
            payload = tuple(
                base.compress_bucketed_keys(
                    cl, dseg, sched.chunk_keys(keys, c),
                    jax.random.fold_in(wkey, CHUNK_FOLD + c))
                for c, (cl, dseg) in enumerate(zip(cls_, sched.split(delta))))
            dhat_w = jnp.concatenate([
                comps[c].decode(payload[c], cls_[c].padded_size)
                for c in range(sched.n_chunks)])
        else:
            payload = comp.compress(delta, wkey)
            dhat_w = comp.decode(payload, dp)
        return None, (payload, comp.next_memory(h_row, dhat_w, delta))

    _, (stacked, new_h) = jax.lax.scan(
        worker_round, None,
        (jnp.arange(n), grads_per_worker, h_worker),
    )
    if part is None and faults is None:
        if chunked:
            hs_chunks = sched.split(h_server)
            served = [
                comps[c].decode_sum_apply(stacked[c], n,
                                          cls_[c].padded_size, hs_chunks[c])
                for c in range(sched.n_chunks)
            ]
            ghat_flat = jnp.concatenate([g for g, _ in served])
            new_hs = jnp.concatenate([h for _, h in served])
        else:
            ghat_flat, new_hs = comp.decode_sum_apply(stacked, n, dp, h_server)
        # f32, like the per-leaf ref
        ghat = layout.unflatten(ghat_flat, cast=False)
        if node_size > 1:
            # Every worker of a node stores the identical node memory row.
            new_h = jnp.repeat(new_h, node_size, axis=0)
        return ghat, new_h, new_hs

    chunks = list(stacked) if chunked else [stacked]
    valid = None
    if faults is not None:
        # The wire mirror of the distributed fault path: fuse each worker's
        # own payload PER CHUNK wire, checksum each, inject that worker's
        # scheduled faults through the chunk's byte window, then verify each
        # stack exactly as the receivers do post-gather.  A worker is
        # excluded whole when ANY of its chunk wires fails.
        bufs = [fuse_payload(ch.select(0)) for ch in chunks]
        offs, body_total = _chunk_wire_meta(bufs)
        gathered_chunks, valids = [], []
        for c, ch in enumerate(chunks):
            wires = [
                apply_faults(add_checksum(fuse_payload(ch.select(w))),
                             faults, step, w,
                             byte_offset=offs[c],
                             body_total=body_total if chunked else None)
                for w in range(n)
            ]
            flat, v_c = verify_checksum(jnp.stack(wires))
            valids.append(v_c)
            gathered_chunks.append(unfuse_payload(
                flat.reshape(n, *bufs[c].shape), payload_recipe(ch.select(0))))
        valid = valids[0]
        for v_c in valids[1:]:
            valid = valid & v_c
    else:
        gathered_chunks = chunks

    m_eff = part.mask if valid is None else part.mask & valid
    if chunked:
        total = jnp.concatenate([
            comps[c].decode_sum(gathered_chunks[c].mask_workers(m_eff), n,
                                cls_[c].padded_size)
            for c in range(sched.n_chunks)])
    else:
        total = comp.decode_sum(gathered_chunks[0].mask_workers(m_eff), n, dp)
    ghat_flat, new_hs_f = _masked_server_tail(
        comp, h_server.astype(jnp.float32), total, n, part, m_eff)
    new_h = _where_rows(_participant_gate(part, valid), new_h, h_worker)
    return layout.unflatten(ghat_flat, cast=False), new_h, new_hs_f
