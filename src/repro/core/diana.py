"""DIANA (Algorithm 1) — compressed gradient-difference aggregation.

Two implementations, one semantics:

* :func:`aggregate_shardmap` — the production path, called *inside* a
  ``shard_map`` whose manual axes are the DIANA worker axes.  Each worker
  quantizes its gradient difference, bit-packs it, all-gathers the packed
  payload (the TPU analogue of the paper's MPI Gather + Broadcast — replicated
  deterministic decode replaces the server), and every device reconstructs the
  identical aggregated estimator ``ghat = h^k + mean_i dhat_i``.

* :func:`reference_step` — a single-process n-worker simulation (vmapped
  quantization) used by unit tests, the convex-experiment benchmarks and the
  paper-figure reproductions.  ``aggregate_shardmap`` is tested to agree with
  it bit-for-bit under a shared PRNG schedule.

The memory update is Algorithm 1 line 6/9:
    h_i^{k+1} = h_i^k + alpha * dhat_i^k
    h^{k+1}   = h^k   + alpha * mean_i dhat_i^k
and the returned direction is line 8: ``ghat^k = h^k + mean_i dhat_i^k``.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .compression import CompressionConfig, compress_tree
from .packing import unpack2bit
from .quantization import QuantizedBlocks, dequantize_blocks, quantize_blocks

__all__ = [
    "DianaState",
    "init_state",
    "aggregate_shardmap",
    "reference_init",
    "reference_step",
    "tree_zeros_like",
]


def tree_zeros_like(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


class DianaState(NamedTuple):
    """Compressor state carried by the training loop.

    Memories are stored FLAT (one 1-D leaf per param leaf, sharded evenly over
    the 'model' axis) — the same layout quantization blocks live in, so the
    entire compress -> gather -> decode -> h-update path is layout-local; the
    only relayouts per step are grads->flat and ghat->param-shape (both over
    the fast intra-pod ICI; see DESIGN.md §Perf notes).

    h_worker: pytree of (n_workers, d_leaf) f32/bf16 — axis 0 sharded over the
              worker mesh axes (each worker holds only its own memory).
    h_server: pytree of (d_leaf,) — replicated over worker axes — the paper's
              server-side ``h^k = mean_i h_i^k``.
    """

    h_worker: Any
    h_server: Any


def init_state(params, cfg: CompressionConfig, n_workers: int) -> DianaState:
    """h_i^0 = 0 (the paper's experimental choice) for all methods."""
    h_w = jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_workers, p.size), cfg.h_dtype), params
    )
    h_s = jax.tree_util.tree_map(lambda p: jnp.zeros((p.size,), cfg.h_dtype), params)
    return DianaState(h_worker=h_w, h_server=h_s)


# ---------------------------------------------------------------------------
# Distributed aggregation (inside shard_map over worker axes)
# ---------------------------------------------------------------------------

def _gathered_mean(payload, like, n_workers: int, axis_names):
    """mean_i dequant(payload_i) without materialising n dense copies.

    All-gathers the 2-bit packed payload (cheap: n * d/4 bytes) and then
    decodes sequentially with a fori_loop accumulator so peak memory stays at
    one dense gradient regardless of n.  The gathered buffers and the f32
    accumulator are explicitly re-constrained to stay sharded over 'model' on
    the block dim — ``all_gather`` output sharding does not propagate the auto
    axes by itself and would otherwise replicate n * d/4 bytes per device.
    """
    from repro.models.sharding import shard

    def gather(leaf):
        g = {
            "packed": jax.lax.all_gather(leaf["packed"], axis_names, tiled=False)
            if axis_names else leaf["packed"][None],
            "scales": jax.lax.all_gather(leaf["scales"], axis_names, tiled=False)
            if axis_names else leaf["scales"][None],
        }
        g["packed"] = shard(g["packed"], None, "model", None)
        g["scales"] = shard(g["scales"], None, "model")
        return g

    gathered = jax.tree_util.tree_map(
        gather, payload, is_leaf=lambda t: isinstance(t, dict) and "packed" in t
    )

    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    pay_leaves = jax.tree_util.tree_leaves(
        gathered, is_leaf=lambda t: isinstance(t, dict) and "packed" in t
    )

    outs = []
    for pay, l in zip(pay_leaves, like_leaves):
        packed, scales = pay["packed"], pay["scales"]           # (n, m, B/4), (n, m)
        m, bs4 = packed.shape[-2], packed.shape[-1]
        # statically-unrolled accumulation: dynamic-slice over the gathered
        # worker dim trips the SPMD partitioner under multiple manual axes
        # (RET_CHECK "Incompatible manual sharding"), and static slices also
        # fuse better; n_workers is a mesh constant so the unroll is bounded.
        acc = shard(jnp.zeros((m, bs4 * 4), jnp.float32), "model", None)
        for i in range(n_workers):
            signs = unpack2bit(packed[i]).astype(jnp.float32)   # (m, B)
            acc = acc + signs * scales[i][:, None].astype(jnp.float32)
        mean = (acc / n_workers).reshape(-1)[: l.size].reshape(l.shape)
        outs.append(mean.astype(l.dtype))
    return jax.tree_util.tree_unflatten(treedef, outs)


def _dequant_own(qtree, like):
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    q_leaves = jax.tree_util.tree_leaves(
        qtree, is_leaf=lambda t: isinstance(t, QuantizedBlocks)
    )
    outs = [
        dequantize_blocks(q, shape=l.shape, dtype=jnp.float32).astype(l.dtype)
        for q, l in zip(q_leaves, like_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, outs)


def _aggregate_local(grads_local, h_worker, h_server, key, cfg, axis_names, n_workers):
    """The core Algorithm-1 round on LOCAL arrays (no sharding decisions).

    grads_local leaves may have any shape — they are flattened locally; the
    h leaves are flat ``(1, d_local)`` / ``(d_local,)``.  ``axis_names`` are
    the (manual) worker axes the packed payload is gathered over.
    """
    g_flat = jax.tree_util.tree_map(
        lambda g: g.reshape(-1).astype(jnp.float32), grads_local
    )
    h_local = jax.tree_util.tree_map(lambda h: h[0], h_worker)

    if cfg.uses_memory:
        delta = jax.tree_util.tree_map(
            lambda g, h: g - h.astype(jnp.float32), g_flat, h_local
        )
    else:  # qsgd / terngrad / dqgd quantize the gradient itself
        delta = g_flat

    payload, qtree = compress_tree(delta, key, cfg)
    dhat_mean = _gathered_mean(payload, g_flat, n_workers, axis_names)

    alpha = cfg.effective_alpha()
    if cfg.uses_memory:
        dhat_own = _dequant_own(qtree, g_flat)
        new_h_local = jax.tree_util.tree_map(
            lambda h, d: (h.astype(jnp.float32) + alpha * d).astype(cfg.h_dtype),
            h_local, dhat_own,
        )
        new_h_server = jax.tree_util.tree_map(
            lambda h, d: (h.astype(jnp.float32) + alpha * d).astype(cfg.h_dtype),
            h_server, dhat_mean,
        )
        ghat_flat = jax.tree_util.tree_map(
            lambda h, d: h.astype(jnp.float32) + d, h_server, dhat_mean
        )
        new_hw = jax.tree_util.tree_map(lambda h: h[None], new_h_local)
    else:
        ghat_flat = dhat_mean
        new_hw, new_h_server = h_worker, h_server

    ghat = jax.tree_util.tree_map(
        lambda f, g: f.reshape(g.shape).astype(g.dtype), ghat_flat, grads_local
    )
    return ghat, new_hw, new_h_server


def aggregate_shardmap(
    grads_local,
    state: DianaState,
    key: jax.Array,
    cfg: CompressionConfig,
    *,
    axis_names: Sequence[str],
    n_workers: int,
    inner_axes: Sequence[str] = (),
    grad_specs=None,
    h_specs=None,
    mesh=None,
):
    """One DIANA aggregation round inside a shard_map body.

    grads_local — this worker's local gradient pytree (g_i^k).
    state.h_worker leaves arrive with local leading dim 1 (own memory only).
    key          — already folded with the worker index (deterministic stream).

    When ``inner_axes`` (the non-worker mesh axes, e.g. ('model',) or
    ('data','model')) are given together with per-leaf PartitionSpecs, the
    whole round runs inside a NESTED fully-manual shard_map: each inner
    device quantizes / packs / decodes ITS OWN shard of every gradient leaf
    and the packed all-gather runs over the (outer-manual) worker axes.  No
    relayout, no partitioner decisions — XLA's SPMD partitioner crashes on
    several of them under manual subgroups (DESIGN.md §6).  The h memory is
    stored in this shard-local flat layout, which is self-consistent step to
    step (its global ordering is internal state, never interpreted).

    Returns ``(ghat, new_state)`` with ``ghat`` identical on all workers and
    shaped/sharded like ``grads_local``.
    """
    axis_names = tuple(axis_names)
    inner_axes = tuple(inner_axes)

    if cfg.method == "none":
        ghat = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, axis_names) if axis_names else g, grads_local
        )
        return ghat, state

    if not inner_axes or grad_specs is None:
        # single-device / tests: everything already local
        ghat, new_hw, new_hs = _aggregate_local(
            grads_local, state.h_worker, state.h_server, key, cfg,
            axis_names, n_workers,
        )
        return ghat, DianaState(h_worker=new_hw, h_server=new_hs)

    from jax import shard_map as _shard_map
    from jax.sharding import PartitionSpec as P

    from repro.models.sharding import NoopPolicy, sharding_policy

    amesh = None
    try:
        amesh = jax.sharding.get_abstract_mesh()
    except Exception:
        pass
    if amesh is None or amesh.empty:
        amesh = mesh  # plain-jit caller (no outer shard_map): concrete mesh
    assert amesh is not None, "aggregate_shardmap needs a mesh for the nested map"

    def body(grads, h_w, h_s, k):
        with sharding_policy(NoopPolicy()):
            return _aggregate_local(grads, h_w, h_s, k, cfg, axis_names, n_workers)

    hw_specs = jax.tree_util.tree_map(lambda s: P(None, *s), h_specs)
    in_specs = (grad_specs, hw_specs, h_specs, P())
    out_specs = (grad_specs, hw_specs, h_specs)
    ghat, new_hw, new_hs = _shard_map(
        body, mesh=amesh, in_specs=in_specs, out_specs=out_specs,
        axis_names=set(inner_axes), check_vma=False,
    )(grads_local, state.h_worker, state.h_server, key)
    return ghat, DianaState(h_worker=new_hw, h_server=new_hs)


# ---------------------------------------------------------------------------
# Single-process n-worker reference (tests, convex experiments, figures)
# ---------------------------------------------------------------------------

class ReferenceState(NamedTuple):
    h_worker: Any  # (n, d) per leaf — flat, mirroring DianaState
    h_server: Any  # (d,) per leaf — flat
    v: Any         # momentum buffer, like params


def reference_init(params, cfg: CompressionConfig, n_workers: int) -> ReferenceState:
    return ReferenceState(
        h_worker=jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_workers, p.size), jnp.float32), params
        ),
        h_server=jax.tree_util.tree_map(
            lambda p: jnp.zeros((p.size,), jnp.float32), params
        ),
        v=tree_zeros_like(params, jnp.float32),
    )


def reference_step(
    grads_per_worker,
    state: ReferenceState,
    key: jax.Array,
    cfg: CompressionConfig,
    *,
    beta: float = 0.0,
):
    """Aggregate stacked per-worker grads (n, ...) exactly as Algorithm 1.

    Bit-for-bit aligned with :func:`aggregate_shardmap`: worker ``i`` draws
    from ``fold_in(key, i)`` through the same ``compress_tree`` path, and the
    mean accumulates in the same sequential f32 order as the distributed
    decode loop — tests assert exact equality between the two.

    Returns (v, new_state): ``v = beta*v + ghat`` — caller does the prox step.
    """
    from .compression import compress_tree  # local import to avoid cycle

    n = jax.tree_util.tree_leaves(grads_per_worker)[0].shape[0]

    if cfg.method == "none":
        ghat = jax.tree_util.tree_map(lambda g: g.mean(0), grads_per_worker)
        new_state = state
    else:
        alpha = cfg.effective_alpha()
        acc = None
        new_h_rows = []
        for w in range(n):
            gw = jax.tree_util.tree_map(
                lambda g: g[w].astype(jnp.float32).reshape(-1), grads_per_worker
            )
            if cfg.uses_memory:
                hw = jax.tree_util.tree_map(lambda h: h[w].astype(jnp.float32), state.h_worker)
                delta = jax.tree_util.tree_map(lambda g, h: g - h, gw, hw)
            else:
                delta = gw
            _, qtree = compress_tree(delta, jax.random.fold_in(key, w), cfg)
            dhat_w = _dequant_own(qtree, gw)
            acc = dhat_w if acc is None else jax.tree_util.tree_map(
                lambda a, d: a + d, acc, dhat_w
            )
            if cfg.uses_memory:
                new_h_rows.append(jax.tree_util.tree_map(
                    lambda h, d: h + alpha * d, hw, dhat_w
                ))
        dhat_mean = jax.tree_util.tree_map(lambda a: a / n, acc)

        if cfg.uses_memory:
            ghat_flat = jax.tree_util.tree_map(
                lambda h, d: h + d, state.h_server, dhat_mean
            )
            new_state = state._replace(
                h_worker=jax.tree_util.tree_map(
                    lambda *rows: jnp.stack(rows), *new_h_rows
                ),
                h_server=jax.tree_util.tree_map(
                    lambda h, d: h + alpha * d, state.h_server, dhat_mean
                ),
            )
        else:
            ghat_flat = dhat_mean
            new_state = state
        ghat = jax.tree_util.tree_map(
            lambda f, g: f.reshape(g.shape[1:]), ghat_flat, grads_per_worker
        )

    v = jax.tree_util.tree_map(lambda v0, g: beta * v0 + g, state.v, ghat)
    return v, new_state._replace(v=v)
