"""2-bit packing of ternary sign tensors for compressed collectives.

The paper communicates Elias-coded sparse vectors through MPI Gather; on TPU we
use fixed-width 2-bit codes (4 ternary values per int8 byte) so payloads have
static shapes, vectorize on 8-bit lanes, and can be moved by a single
all-gather.  Encoding: sign s in {-1, 0, +1} -> (s + 1) in {0, 1, 2} packed
little-endian within the byte.  Code 3 is unused.

These are the pure-jnp reference implementations; the Pallas kernels in
``repro.kernels`` fuse quantize+pack in one VMEM pass and are validated against
these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pack2bit", "unpack2bit", "packed_nbytes", "PACK_FACTOR"]

PACK_FACTOR = 4  # ternary values per byte


def packed_nbytes(n: int) -> int:
    """Bytes needed for ``n`` ternary values."""
    return -(-n // PACK_FACTOR)


def pack2bit(signs: jax.Array) -> jax.Array:
    """Pack an int8 {-1,0,1} tensor (..., B) into (..., B/4) uint8.

    Last dim must be a multiple of 4 (block sizes are; enforced statically).
    """
    if signs.shape[-1] % PACK_FACTOR:
        raise ValueError(f"last dim {signs.shape[-1]} not a multiple of {PACK_FACTOR}")
    codes = (signs + 1).astype(jnp.uint8)                       # {0,1,2}
    g = codes.reshape(*codes.shape[:-1], -1, PACK_FACTOR)       # (..., B/4, 4)
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    return jnp.sum(g << shifts, axis=-1).astype(jnp.uint8)


def unpack2bit(packed: jax.Array, n: int | None = None) -> jax.Array:
    """Inverse of :func:`pack2bit`; returns int8 {-1,0,1} with last dim 4x."""
    shifts = jnp.array([0, 2, 4, 6], dtype=jnp.uint8)
    g = (packed[..., None] >> shifts) & jnp.uint8(3)            # (..., B/4, 4)
    signs = g.astype(jnp.int8) - 1
    out = signs.reshape(*packed.shape[:-1], -1)
    if n is not None:
        out = out[..., :n]
    return out
