import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
with 512 placeholder host devices, prove the sharding config is coherent, and
extract the roofline terms (FLOPs / HBM bytes / collective bytes) from the
compiled per-device module.

Outputs one JSON per pair under --out (default experiments/dryrun/) that
benchmarks/roofline.py and EXPERIMENTS.md consume.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ASSIGNED_ARCHS,
    get_config,
    get_shape,
    input_specs,
    shape_applicable,
    SHAPES,
)
from repro.models import init_model, init_caches
from repro.optim.diana_optimizer import DianaOptState

# v5e roofline constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link
HBM_BYTES = 16 * 1024**3   # 16 GiB

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\(?([a-z0-9]+)\[([\d,]*)\][^)]*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> Dict:
    """Sum per-device bytes moved by every collective in the compiled module.

    Ring model per op (G = devices per replica group, S = result bytes):
      all-gather:        S * (G-1)/G     (result is the gathered buffer)
      reduce-scatter:    S * (G-1)       (operand = G * result shards pass through)
      all-reduce:        2 * S * (G-1)/G
      all-to-all:        S * (G-1)/G
      collective-permute: S
    """
    ops = []
    total = 0.0
    by_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dtype, dims, kind = m.groups()
        size = _shape_bytes(dtype, dims)
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                g = len(gl.group(1).split(","))
        g = g or 1
        if g <= 1 and kind != "collective-permute":
            continue
        if kind == "all-gather":
            moved = size * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = size * (g - 1)
        elif kind == "all-reduce":
            moved = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            moved = size * (g - 1) / g
        else:  # collective-permute
            moved = size
        total += moved
        by_kind[kind] = by_kind.get(kind, 0.0) + moved
        ops.append({"kind": kind, "bytes": size, "group": g, "moved": moved})
    return {"total_moved_bytes": total, "by_kind": by_kind, "n_ops": len(ops),
            "ops": sorted(ops, key=lambda o: -o["moved"])[:20]}


def _sds(tree, shardings):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), tree, shardings
    )


def lower_pair(arch: str, shape_name: str, mesh, *, compression: Optional[str] = None,
               remat: Optional[str] = None, worker_axes: Optional[str] = None,
               moe_chunk: Optional[int] = None, comp_block: Optional[int] = None):
    """Lower + compile one (arch, shape) on ``mesh``. Returns result dict."""
    from dataclasses import replace as dc_replace

    from repro.launch.serve import build_serve_step, decode_window, serve_cache_shardings
    from repro.launch.sharding_rules import batch_specs, param_specs
    from repro.launch.train import (
        build_train_step, make_optimizer, train_state_shardings,
    )
    from repro.launch.mesh import data_axes, resolve_train_mesh, worker_axes_in, worker_count

    cfg = get_config(arch)
    if compression:
        cfg = dc_replace(cfg, compression=compression)
    if remat:
        cfg = dc_replace(cfg, remat=remat)
    if worker_axes:
        cfg = dc_replace(cfg, comp_worker_axes=tuple(worker_axes.split(",")))
    if comp_block:
        cfg = dc_replace(cfg, comp_block=comp_block)
    if moe_chunk and cfg.moe is not None:
        cfg = dc_replace(cfg, moe=dc_replace(cfg.moe, token_chunk=moe_chunk))
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: init_model(cfg, k), key)
    n_params = sum(int(jnp.prod(jnp.array(l.shape))) for l in jax.tree_util.tree_leaves(params_shape))

    t0 = time.time()
    if shape.kind == "train":
        opt = make_optimizer(cfg)
        # NB: the step runs on the RESOLVED mesh (worker axes flattened
        # pod-major when they span pod x data — XLA cannot partition under
        # more than one manual axis; see mesh.resolve_train_mesh).
        smesh, waxes = resolve_train_mesh(mesh, opt.compression.worker_axes)
        from repro.launch.train import resolve_bucketed

        # same bucketed-vs-per-leaf resolution the step/shardings make, so
        # the eval_shape'd state layout matches what the step expects
        opt = resolve_bucketed(opt, smesh, waxes)
        n_workers = worker_count(smesh, waxes)
        opt_state_shape = jax.eval_shape(lambda p: opt.init(p, n_workers), params_shape)
        p_shard, o_shard = train_state_shardings(cfg, opt, mesh, params_shape, opt_state_shape)
        step_fn = build_train_step(cfg, opt, mesh, shape)

        batch_shape = input_specs(cfg, shape)
        b_specs = batch_specs(batch_shape, smesh)
        b_shard = jax.tree_util.tree_map(lambda s: NamedSharding(smesh, s), b_specs)
        args = (
            _sds(params_shape, p_shard),
            _sds(opt_state_shape, o_shard),
            _sds(batch_shape, b_shard),
            jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=NamedSharding(smesh, P())),
        )
        lowered = step_fn.lower(*args)
    elif shape.kind == "prefill":
        from repro.launch.serve import build_prefill

        pspecs = param_specs(params_shape, cfg, mesh)
        p_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
        batch_shape = input_specs(cfg, shape)
        b_specs = batch_specs(batch_shape, mesh)
        b_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), b_specs)
        step_fn = build_prefill(cfg, mesh, shape)
        lowered = step_fn.lower(_sds(params_shape, p_shard), _sds(batch_shape, b_shard))
    else:  # decode
        pspecs = param_specs(params_shape, cfg, mesh)
        p_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
        c_shard, caches_shape, window = serve_cache_shardings(cfg, mesh, shape)
        step_fn = build_serve_step(cfg, mesh, shape)
        tok = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32, sharding=NamedSharding(mesh, P())
        )
        lowered = step_fn.lower(_sds(params_shape, p_shard), _sds(caches_shape, c_shard), tok)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # old jax: one dict per program
        cost = cost[0] if cost else {}
    colls = parse_collectives(compiled.as_text())

    mem_bytes = (
        getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        + getattr(mem, "temp_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    n_chips = mesh.size
    result = {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "mesh_axes": list(mesh.axis_names),
        "n_chips": n_chips,
        "n_params": int(n_params),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "memory_bytes": int(mem_bytes),
            "hlo_flops": flops,
            "hlo_bytes_accessed": bytes_accessed,
            "collective_moved_bytes": colls["total_moved_bytes"],
        },
        "collectives": {"by_kind": colls["by_kind"], "n_ops": colls["n_ops"],
                        "top_ops": colls["ops"]},
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_accessed / HBM_BW,
            "collective_s": colls["total_moved_bytes"] / ICI_BW,
        },
        "fits_hbm": bool(mem_bytes <= HBM_BYTES),
    }
    dom = max(result["roofline"], key=result["roofline"].get)
    result["roofline"]["dominant"] = dom
    return result


def _isolated_sweep(args):
    """Run each (mesh, arch, shape) pair in its own subprocess."""
    import subprocess

    archs = args.arch or (list(ASSIGNED_ARCHS) if args.all else ["llama3.2-1b"])
    shapes = args.shape or (list(SHAPES) if args.all else ["train_4k"])
    pods = {"no": ["no"], "yes": ["yes"], "both": ["no", "yes"]}[args.multi_pod]

    failures = []
    for pod in pods:
        mesh_tag = "multipod" if pod == "yes" else "singlepod"
        for arch in archs:
            for shape_name in shapes:
                tag = f"{mesh_tag}/{arch}_{shape_name}"
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--multi-pod", pod, "--out", args.out]
                if args.devices:
                    cmd += ["--devices", str(args.devices)]
                if args.compression:
                    cmd += ["--compression", args.compression]
                if args.remat:
                    cmd += ["--remat", args.remat]
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                # process-level aborts (XLA CHECK failures) leave no JSON —
                # write an error artifact so the roofline table shows them
                path = os.path.join(args.out, mesh_tag, f"{arch}_{shape_name}.json")
                if r.returncode != 0 and not _fresh(path, t0):
                    os.makedirs(os.path.dirname(path), exist_ok=True)
                    tail = (r.stderr or r.stdout or "")[-1500:]
                    with open(path, "w") as f:
                        json.dump({"status": "error", "arch": arch, "shape": shape_name,
                                   "error": f"process exit {r.returncode}",
                                   "trace": tail}, f, indent=1)
                if r.returncode != 0:
                    failures.append(tag)
                for line in (r.stdout or "").splitlines():
                    if line.startswith("["):
                        print(line, flush=True)
                if r.returncode != 0:
                    print(f"[{time.time()-t0:6.1f}s] {tag}: PROCESS-FAIL rc={r.returncode}",
                          flush=True)
    if failures:
        print(f"\nFAILED pairs ({len(failures)}): {failures}", file=sys.stderr)
        sys.exit(1)
    print("\nall requested pairs lowered + compiled OK")


def _fresh(path, t0):
    return os.path.exists(path) and os.path.getmtime(path) >= t0


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--compression", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--worker-axes", default=None, help="e.g. 'pod' or 'pod,data'")
    ap.add_argument("--moe-chunk", type=int, default=None)
    ap.add_argument("--comp-block", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--devices", type=int, default=None,
                    help="test override: small mesh (e.g. 8 -> 2x2x2)")
    ap.add_argument("--isolate", action="store_true",
                    help="one subprocess per pair — XLA partitioner CHECK "
                         "failures abort the process and would kill the sweep")
    args = ap.parse_args(argv)

    if args.isolate:
        return _isolated_sweep(args)

    from repro.launch.mesh import make_production_mesh, make_mesh

    archs = args.arch or (list(ASSIGNED_ARCHS) if args.all else ["llama3.2-1b"])
    shapes = args.shape or (list(SHAPES) if args.all else ["train_4k"])
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi_pod in pods:
        if args.devices:
            if multi_pod:
                mesh = make_mesh((2, 2, args.devices // 4), ("pod", "data", "model"))
            else:
                mesh = make_mesh((2, args.devices // 2), ("data", "model"))
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_tag = "multipod" if multi_pod else "singlepod"
        for arch in archs:
            for shape_name in shapes:
                tag = f"{mesh_tag}/{arch}_{shape_name}"
                t0 = time.time()
                try:
                    res = lower_pair(arch, shape_name, mesh,
                                     compression=args.compression, remat=args.remat,
                                     worker_axes=args.worker_axes,
                                     moe_chunk=args.moe_chunk,
                                     comp_block=args.comp_block)
                except Exception as e:  # a failure here is a sharding bug
                    res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures.append(tag)
                res.setdefault("arch", arch)
                res.setdefault("shape", shape_name)
                path = os.path.join(args.out, mesh_tag)
                os.makedirs(path, exist_ok=True)
                with open(os.path.join(path, f"{arch}_{shape_name}.json"), "w") as f:
                    json.dump(res, f, indent=1, default=float)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f"mem={res['per_device']['memory_bytes']/2**30:.2f}GiB "
                             f"fits={res['fits_hbm']} compute={r['compute_s']*1e3:.2f}ms "
                             f"memory={r['memory_s']*1e3:.2f}ms coll={r['collective_s']*1e3:.2f}ms "
                             f"dom={r['dominant']} compile={res['compile_s']:.0f}s")
                elif status == "error":
                    extra = res["error"][:160]
                else:
                    extra = res.get("reason", "")[:100]
                print(f"[{time.time()-t0:6.1f}s] {tag}: {status} {extra}", flush=True)

    if failures:
        print(f"\nFAILED pairs ({len(failures)}): {failures}", file=sys.stderr)
        sys.exit(1)
    print("\nall requested pairs lowered + compiled OK")


if __name__ == "__main__":
    main()
