"""Mesh construction for the production topologies.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "data_axes", "worker_count", "worker_index"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256 chips / pod) single-pod mesh, or 2x16x16 = 512-chip two-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary test mesh, e.g. ((2,2,2), ('pod','data','model'))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch (everything except 'model').  'node' is the
    optional intra-/inter-node boundary axis of the hierarchical topology
    (DESIGN.md §Topology): workers are ('node', 'data'), node-major."""
    return tuple(a for a in ("pod", "node", "data") if a in mesh.axis_names)


def worker_axes_in(mesh, requested: Sequence[str]) -> Tuple[str, ...]:
    """The DIANA worker axes actually present in this mesh."""
    return tuple(a for a in requested if a in mesh.axis_names)


def worker_count(mesh, worker_axes: Sequence[str]) -> int:
    n = 1
    for a in worker_axes_in(mesh, worker_axes):
        n *= mesh.shape[a]
    return max(n, 1)


def resolve_train_mesh(mesh, worker_axes: Sequence[str]):
    """Mesh actually used by the training step.

    XLA's SPMD partitioner RET_CHECKs (spmd_partitioner.cc:2584) on several
    ops whenever a shard_map has MORE THAN ONE manual axis.  When the DIANA
    workers span multiple mesh axes (paper-faithful mode on the multi-pod
    mesh), we therefore flatten the worker axes into a single 'data' axis,
    pod-major — the device order (and thus which chips communicate over the
    slow inter-pod links) is unchanged; only the name partitioning is.
    Hierarchical mode (workers = pods) keeps the full 3-axis mesh: one manual
    axis, and the inner 'data' axis stays auto for FSDP.

    Returns (step_mesh, worker_axes_in_step_mesh).
    """
    waxes = worker_axes_in(mesh, worker_axes)
    if len(waxes) <= 1:
        return mesh, waxes
    assert tuple(mesh.axis_names[: len(waxes)]) == tuple(waxes), (
        "worker axes must be the leading mesh axes to flatten pod-major"
    )
    other = tuple(a for a in mesh.axis_names if a not in waxes)
    n_w = 1
    for a in waxes:
        n_w *= mesh.shape[a]
    new_shape = (n_w,) + tuple(mesh.shape[a] for a in other)
    devices = mesh.devices.reshape(new_shape)
    flat = jax.sharding.Mesh(devices, ("data",) + other)
    return flat, ("data",)


def worker_index(worker_axes: Sequence[str]):
    """Linearised worker index inside a shard_map body (row-major)."""
    import jax.numpy as jnp

    from repro.compat import axis_size

    idx = jnp.zeros((), jnp.int32)
    for a in worker_axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx
