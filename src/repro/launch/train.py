"""Distributed DIANA training step + CLI training driver.

Topology-aware composition (DESIGN.md §3):

    jit( shard_map(local_step, manual=worker_axes) )

* manual axes = the DIANA worker axes.  Inside the body ``jax.grad`` yields
  each worker's LOCAL gradient (no implicit cross-worker reduce) — exactly the
  ``g_i^k`` Algorithm 1 needs.
* everything else ('model', and 'data' in hierarchical mode) stays auto:
  GSPMD lowers the tensor/expert parallelism from the logical-axis
  annotations in the model code, and ZeRO/FSDP-shards params + optimizer
  state over the inner data axes when the workers are pods.
* the compressed all-gather + replicated decode inside
  ``core.diana.aggregate_shardmap`` is the paper's Gather+Broadcast.

Paper-faithful mode: ``worker_axes=('pod','data')`` — every data slice is a
worker, params replicated over data.  Hierarchical (beyond-paper):
``worker_axes=('pod',)`` — compress only the slow inter-pod link.
"""

from __future__ import annotations

import argparse
import functools
import warnings
import math
import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.configs import get_config, get_shape, input_specs
from repro.core.compression import CompressionConfig
from repro.core.diana import DianaState, aggregate_shardmap, bucket_layout
from repro.core.policy import CompressionPolicy, load_policy, partition_for
from repro.core.vr import VRState, resolve_vr_p
from repro.models import init_model, train_loss
from repro.models.sharding import GSPMDPolicy, sharding_policy
from repro.optim import DianaOptimizer, momentum, adamw, constant_schedule
from repro.optim.diana_optimizer import DianaOptState

from .mesh import (
    data_axes,
    make_mesh,
    make_production_mesh,
    resolve_train_mesh,
    worker_axes_in,
    worker_count,
)
from .sharding_rules import batch_specs, param_specs

__all__ = ["build_train_step", "train_state_shardings", "init_train_state", "make_optimizer",
           "resolve_bucketed", "resolved_layout", "resolve_policy_arg"]


def resolve_bucketed(opt: "DianaOptimizer", mesh, waxes) -> "DianaOptimizer":
    """Downgrade bucketed -> per-leaf aggregation when it cannot lower.

    The flat-buffer round concatenates every (model-sharded) leaf into ONE
    buffer, which requires resharding under the manual worker subgroup; old
    XLA's SPMD partitioner RET_CHECKs on those patterns whenever an auto
    inner axis (size > 1) is live inside the partial-manual body (DESIGN.md
    §6).  On such toolchains (no nested-manual support) the step silently
    falls back to the per-leaf layout — bitwise the same results, just more
    collectives.  Pure worker meshes (the paper's data-parallel setting) and
    nested-manual-capable toolchains keep the bucketed path.  The DOWNLINK
    flatten (core.diana.downlink_round) builds the same kind of whole-model
    buffer inside the same partial-manual body, so the downgrade forces its
    layout per-leaf too.  For a grouped policy the downgrade applies to
    EVERY group, both directions (``CompressionPolicy.force_perleaf``).

    Resolved HERE (not inside core.diana) because the choice fixes the
    DianaState layout: init and step must agree before the state is built.
    """
    pol = opt.policy
    if not pol.any_bucketed():
        return opt
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    inner_live = any(sizes[a] > 1 for a in mesh.axis_names if a not in waxes)
    from repro.compat import supports_nested_manual

    if inner_live and not supports_nested_manual():
        live = tuple(a for a in mesh.axis_names
                     if a not in waxes and sizes[a] > 1)
        warnings.warn(
            "resolve_bucketed: downgrading the aggregation layout "
            f"[reason=no-nested-manual inner_axes={live} "
            "resulting_layout=per-leaf topology=flat]: the flat-buffer round "
            "cannot lower with live auto inner axes on this toolchain "
            "(DESIGN.md §6).  Results are bitwise identical; step time and "
            "collective count are not.",
            RuntimeWarning, stacklevel=2)
        return opt.replace(policy=pol.force_perleaf())
    return opt


def resolved_layout(opt: "DianaOptimizer", mesh, waxes) -> str:
    """The layout :func:`resolve_bucketed` actually runs on this mesh —
    ``"bucketed"``, ``"per-leaf"``, or ``"per-leaf (downgraded)"`` when the
    config asked for bucketed but the toolchain forced the fallback.  Bench
    rows surface this so a silent-looking downgrade is visible in results."""
    if not opt.policy.any_bucketed():
        return "per-leaf"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        resolved = resolve_bucketed(opt, mesh, waxes)
    return ("bucketed" if resolved.policy.any_bucketed()
            else "per-leaf (downgraded)")


def resolve_policy_arg(cfg, policy) -> CompressionPolicy:
    """The trainer's ``--comp-policy`` surface -> a concrete policy.

    ``policy`` is a :class:`CompressionPolicy`, a ``.json`` file path, an
    inline rule string (``repro.core.policy.parse_rules`` syntax), or the
    literal ``"default"`` selecting the model's curated default
    (``ModelConfig.comp_policy``).  The model config supplies the model-wide
    fields (worker axes, layout default, h dtype, VR) unless a JSON document
    overrides them.
    """
    if policy == "default":
        if cfg.comp_policy is None:
            raise ValueError(
                f"--comp-policy default: {cfg.name} defines no default "
                f"policy (ModelConfig.comp_policy is None)")
        policy = cfg.comp_policy
    return load_policy(
        policy,
        bucketed=cfg.comp_bucketed,
        worker_axes=cfg.comp_worker_axes,
        h_dtype=cfg.h_dtype,
        vr=cfg.vr,
        vr_p=cfg.vr_p,
    )


def make_optimizer(cfg, *, lr: float = 3e-4, inner: str = "momentum", beta: float = 0.9,
                   compression: Optional[CompressionConfig] = None,
                   policy=None, participation=None) -> DianaOptimizer:
    """Build the training optimizer from a model config.

    ``policy`` (a :class:`CompressionPolicy` | inline rule string | ``.json``
    path | ``"default"``) selects per-parameter-group compression; without it
    the flat ``cfg.compression``/``comp_*`` fields build the legacy uniform
    config (bitwise the pre-policy behaviour).  ``participation`` (a
    :class:`~repro.core.participation.ParticipationSpec`) attaches elastic
    client sampling / dropout / churn to either surface — it is model-wide,
    so it rides the policy whole (DESIGN.md §Elasticity).
    """
    inner_opt = adamw() if inner == "adamw" else momentum(beta)
    if policy is not None:
        if compression is not None:
            raise ValueError("pass either compression= or policy=, not both")
        return DianaOptimizer(inner=inner_opt, schedule=constant_schedule(lr),
                              policy=resolve_policy_arg(cfg, policy),
                              participation=participation)
    comp = compression or CompressionConfig(
        method=cfg.compression,
        p=cfg.comp_p,
        block_size=cfg.comp_block,
        k=cfg.comp_k,
        worker_axes=cfg.comp_worker_axes,
        h_dtype=cfg.h_dtype,
        bucketed=cfg.comp_bucketed,
        vr=cfg.vr,
        vr_p=cfg.vr_p,
        down_method=cfg.comp_down_method,
        down_k=cfg.comp_down_k,
    )
    return DianaOptimizer(comp, inner_opt, schedule=constant_schedule(lr),
                          participation=participation)


# ---------------------------------------------------------------------------
# Sharding of the training state
# ---------------------------------------------------------------------------

def train_state_shardings(cfg, opt: DianaOptimizer, mesh, params_shape, opt_state_shape):
    """NamedSharding pytrees for (params, opt_state) — on the RESOLVED train
    mesh (see mesh.resolve_train_mesh); callers must place batches there too."""
    mesh, waxes = resolve_train_mesh(mesh, opt.policy.worker_axes)
    opt = resolve_bucketed(opt, mesh, waxes)
    fsdp = tuple(a for a in data_axes(mesh) if a not in waxes)
    pspecs = param_specs(params_shape, cfg, mesh, fsdp_axes=fsdp)
    p_shard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)

    wtuple = waxes if len(waxes) != 1 else waxes[0]

    vr_shard = None
    if opt.policy.vr:
        # VR (snapshot, mu) mirror the params' inner sharding with the worker
        # dim prepended (manual-sharded like h_worker) — fsdp axes and waxes
        # are disjoint by construction, so the specs never collide.
        def to_vr(s):
            return NamedSharding(mesh, P(wtuple if waxes else None, *s))

        vr_leaf = lambda s: isinstance(s, P)
        vr_shard = VRState(
            snapshot=jax.tree_util.tree_map(to_vr, pspecs, is_leaf=vr_leaf),
            mu=jax.tree_util.tree_map(to_vr, pspecs, is_leaf=vr_leaf),
        )

    msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    if not opt.policy.is_uniform:
        diana_shard = _grouped_diana_shardings(
            opt.policy, mesh, params_shape, pspecs, msize=msize,
            wtuple=wtuple, waxes=waxes, vr_shard=vr_shard)
        inner_shard = _inner_shardings(opt_state_shape.inner, p_shard, mesh)
        return p_shard, DianaOptState(
            step=NamedSharding(mesh, P()), inner=inner_shard, diana=diana_shard)

    # Downlink memory: replicated over the worker axes (server + every worker
    # evolve the same copy); the flat dim shards like the h_server analogue —
    # over 'model' when the bucketed downlink buffer divides evenly, per the
    # leaf's h spec in the per-leaf downlink layout.
    down_shard = None
    dcfg = opt.compression.down_config()
    if dcfg is not None:
        if dcfg.bucketed:
            dpd = bucket_layout(dcfg, params_shape).padded_size
            down_axis = "model" if msize > 1 and dpd % msize == 0 else None
            down_shard = NamedSharding(mesh, P(down_axis))
        else:
            down_shard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), h_flat_specs(pspecs)
            )

    if opt.compression.bucketed:
        # Single flat (n, Dp) / (Dp,) memory buffers: worker dim manual-
        # sharded; the flat dim shards over 'model' when the padded size
        # divides evenly (block-aligned layouts usually do), else replicates.
        # The replicate fallback only matters on nested-manual-capable
        # toolchains (resolve_bucketed downgrades live-model meshes on old
        # XLA) — for big align-1 operators there, pad the layout rather than
        # accept n_workers x Dp replicas; NOT done here because mesh-dependent
        # padding would fork the state layout across meshes and break the
        # bitwise per-leaf contract.
        dp = bucket_layout(opt.compression, params_shape).padded_size
        flat_axis = "model" if msize > 1 and dp % msize == 0 else None
        diana_shard = DianaState(
            h_worker=NamedSharding(mesh, P(wtuple if waxes else None, flat_axis)),
            h_server=NamedSharding(mesh, P(flat_axis)),
            vr=vr_shard,
            h_down=down_shard,
        )
    else:
        h_specs = h_flat_specs(pspecs)
        diana_shard = DianaState(
            h_worker=jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, P(wtuple if waxes else None, *s)), h_specs
            ),
            h_server=jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), h_specs),
            vr=vr_shard,
            h_down=down_shard,
        )
    # inner optimizer state mirrors params (momentum/adam buffers)
    inner_shard = _inner_shardings(opt_state_shape.inner, p_shard, mesh)
    opt_shard = DianaOptState(
        step=NamedSharding(mesh, P()), inner=inner_shard, diana=diana_shard
    )
    return p_shard, opt_shard


def _grouped_diana_shardings(pol, mesh, params_shape, pspecs, *, msize,
                             wtuple, waxes, vr_shard):
    """NamedSharding dicts for a grouped policy's per-group memory trees:
    each group gets the same treatment its layout would get model-wide —
    single flat (n, Dp_g)/(Dp_g,) buffers sharded over 'model' when the
    group's padded size divides evenly (bucketed), per-leaf h specs derived
    from the group's param specs otherwise; downlink memories replicated over
    the worker axes like the uniform case."""
    part = partition_for(pol, params_shape)
    p_groups = part.split(params_shape)
    pspec_groups = part.split(pspecs, is_leaf=lambda s: isinstance(s, P))
    h_w, h_s, h_d = {}, {}, {}
    for g, gname in enumerate(part.group_names):
        cfg_g, leaves = part.configs[g], p_groups[g]
        if cfg_g.bucketed:
            dp = bucket_layout(cfg_g, leaves).padded_size
            flat_axis = "model" if msize > 1 and dp % msize == 0 else None
            h_w[gname] = NamedSharding(mesh, P(wtuple if waxes else None, flat_axis))
            h_s[gname] = NamedSharding(mesh, P(flat_axis))
        else:
            hsp = h_flat_specs(pspec_groups[g])
            h_w[gname] = [NamedSharding(mesh, P(wtuple if waxes else None, *s))
                          for s in hsp]
            h_s[gname] = [NamedSharding(mesh, s) for s in hsp]
        dcfg = part.down_configs[g]
        if dcfg is not None:
            if dcfg.bucketed:
                dpd = bucket_layout(dcfg, leaves).padded_size
                ax = "model" if msize > 1 and dpd % msize == 0 else None
                h_d[gname] = NamedSharding(mesh, P(ax))
            else:
                h_d[gname] = [NamedSharding(mesh, s)
                              for s in h_flat_specs(pspec_groups[g])]
    return DianaState(h_worker=h_w, h_server=h_s, vr=vr_shard,
                      h_down=h_d if h_d else None)


def h_flat_specs(grad_specs):
    """Per-leaf PartitionSpec for the flat DIANA memories, derived from the
    gradient specs so that each h leaf's LOCAL length equals the flattened
    local gradient shard inside the nested manual aggregation: the flat dim
    shards over the combined tuple of the leaf's sharded axes (replicated
    leaves keep replicated memories)."""

    def to_h(spec):
        axes = []
        for entry in spec:
            if entry is None:
                continue
            if isinstance(entry, tuple):
                axes.extend(entry)
            else:
                axes.append(entry)
        if not axes:
            return P(None)
        return P(tuple(axes) if len(axes) > 1 else axes[0])

    return jax.tree_util.tree_map(
        to_h, grad_specs, is_leaf=lambda s: isinstance(s, P)
    )


def _inner_shardings(inner_shape, p_shard, mesh):
    """Momentum: a params-shaped tree; AdamW: two of them + a counter; SGD: ()."""
    from repro.optim.optimizers import AdamState

    if isinstance(inner_shape, AdamState):
        return AdamState(mu=p_shard, nu=p_shard, count=NamedSharding(mesh, P()))
    if isinstance(inner_shape, tuple) and len(inner_shape) == 0:
        return ()
    return p_shard


# ---------------------------------------------------------------------------
# The step
# ---------------------------------------------------------------------------

def build_train_step(cfg, opt: DianaOptimizer, mesh, shape=None, *, window: Optional[int] = None,
                     faults=None):
    """Returns a jitted ``step(params, opt_state, batch, key) -> (params, opt_state, metrics)``.

    ``faults`` (a :class:`~repro.core.participation.FaultPlan`) arms the
    wire checksum on the aggregation round — corrupted payloads are detected
    and excluded (DESIGN.md §Elasticity).  Requires the flat bucketed layout
    (the checksum rides the fused uint8 wire buffer).
    """
    mesh, waxes = resolve_train_mesh(mesh, opt.policy.worker_axes)
    opt = resolve_bucketed(opt, mesh, waxes)
    # What the aggregation round runs: the policy itself.  Uniform policies
    # collapse inside core.diana to the flat config — the bitwise pre-policy
    # path; grouped policies take the grouped driver.
    comp = opt.policy
    n_workers = worker_count(mesh, waxes)

    from repro.compat import supports_nested_manual

    if waxes and not supports_nested_manual() and not cfg.scan_unroll:
        # Old XLA RET_CHECKs on dynamic-slice over scan-stacked params inside
        # any manual subgroup; statically unrolling the layer scan removes
        # the dynamic-slice (same math, bigger HLO — fine at test scale).
        from dataclasses import replace as _dc_replace

        cfg = _dc_replace(cfg, scan_unroll=True)
    daxes = data_axes(mesh)
    wtuple = waxes if len(waxes) != 1 else waxes[0]

    inner_axes = tuple(a for a in mesh.axis_names if a not in waxes)
    fsdp = tuple(a for a in daxes if a not in waxes)

    def local_step(params, opt_state, batch, key, widx):
        # widx: (1,) int32 — this worker's linear index, fed in as sharded
        # data rather than computed via axis_index (which lowers to an
        # unpartitionable PartitionId under partial-manual on old XLA).
        policy = GSPMDPolicy(mesh, manual=waxes)
        with sharding_policy(policy):
            loss_fn = lambda p: train_loss(p, batch, cfg, window=window)
            loss, grads = jax.value_and_grad(loss_fn)(params)

            vr_kwargs = {}
            if opt_state.diana.vr is not None:
                # VR-DIANA: second backward at this worker's snapshot on the
                # SAME batch.  The refresh candidate for mu is the minibatch
                # gradient at x — the streaming stand-in for the finite-sum
                # mean (DESIGN.md §VR); step 0 forces a refresh so the
                # zeros-init mu never drives a whole epoch.
                snap_own = jax.tree_util.tree_map(
                    lambda s: s[0], opt_state.diana.vr.snapshot
                )
                g_snap = jax.grad(loss_fn)(snap_own)
                vr_kwargs = dict(
                    vr_aux=(g_snap, grads),
                    params_local=params,
                    vr_force_refresh=opt_state.step == 0,
                )

            down_kwargs = {}
            if opt_state.diana.h_down is not None:
                # Downlink draws are worker-INDEPENDENT (every worker decodes
                # the same broadcast): fold DOWN_FOLD into the step key
                # before the worker fold below.
                from repro.core.diana import DOWN_FOLD

                down_kwargs = dict(down_key=jax.random.fold_in(key, DOWN_FOLD))

            part_kwargs = {}
            if comp.participation is not None or faults is not None:
                # Elastic round: the participation mask is drawn from the
                # step key folded with PART_FOLD — like down_key, BEFORE the
                # worker fold below, so every worker sees the identical (n,)
                # mask.  The step counter drives the churn schedule / fault
                # plan; widx locates this worker's own bit.
                from repro.core.diana import PART_FOLD

                part_kwargs = dict(
                    part_key=jax.random.fold_in(key, PART_FOLD),
                    step=opt_state.step,
                    worker_index=widx[0],
                    faults=faults,
                )

            # Hierarchical topology: every worker of a node runs the SAME
            # inter-node DIANA round (node-leader memories), so the stream is
            # folded by NODE index — the core.diana key contract.
            nsz = comp.node_size if comp.topology == "hierarchical" else 1
            wkey = jax.random.fold_in(key, widx[0] // nsz)
            # Nested fully-manual aggregation where the toolchain supports
            # it; otherwise keep the inner axes auto (GSPMD constraints) —
            # old XLA RET_CHECKs on completing manualization in a nested map.
            from repro.compat import supports_nested_manual

            gspecs = (
                param_specs(params, cfg, mesh, fsdp_axes=fsdp)
                if supports_nested_manual() else None
            )
            ghat, new_diana = aggregate_shardmap(
                grads, opt_state.diana, wkey, comp,
                axis_names=waxes, n_workers=n_workers,
                inner_axes=inner_axes,
                grad_specs=gspecs,
                h_specs=h_flat_specs(gspecs) if gspecs is not None else None,
                mesh=mesh,
                **vr_kwargs,
                **down_kwargs,
                **part_kwargs,
            )
            if waxes:
                loss = jax.lax.pmean(loss, waxes)
            new_params, new_opt = opt.apply_direction(params, ghat, opt_state, new_diana)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(ghat)))
        metrics = {"loss": loss, "ghat_norm": gnorm, "step": new_opt.step}
        return new_params, new_opt, metrics

    if not waxes:
        def single(params, opt_state, batch, key):
            return local_step(params, opt_state, batch, key,
                              jnp.zeros((1,), jnp.int32))
        return jax.jit(single, donate_argnums=(0, 1))

    # --- shard_map in/out specs: manual axes only ---
    rep = P()

    def p_spec(_):
        return rep

    def opt_spec_tree(opt_state_shape):
        dvr = opt_state_shape.diana.vr
        vr_spec = None
        if dvr is not None:
            vr_spec = VRState(
                snapshot=jax.tree_util.tree_map(lambda _: P(wtuple), dvr.snapshot),
                mu=jax.tree_util.tree_map(lambda _: P(wtuple), dvr.mu),
            )
        down_spec = None
        if opt_state_shape.diana.h_down is not None:
            down_spec = jax.tree_util.tree_map(
                lambda _: rep, opt_state_shape.diana.h_down
            )
        diana_spec = DianaState(
            h_worker=jax.tree_util.tree_map(lambda _: P(wtuple), opt_state_shape.diana.h_worker),
            h_server=jax.tree_util.tree_map(lambda _: rep, opt_state_shape.diana.h_server),
            vr=vr_spec,
            h_down=down_spec,
        )
        return DianaOptState(
            step=rep,
            inner=jax.tree_util.tree_map(lambda _: rep, opt_state_shape.inner),
            diana=diana_spec,
        )

    def batch_spec_tree(batch_shape):
        return jax.tree_util.tree_map(lambda _: P(wtuple), batch_shape)

    def wrapped(params, opt_state, batch, key):
        in_specs = (
            jax.tree_util.tree_map(p_spec, params),
            opt_spec_tree(opt_state),
            batch_spec_tree(batch),
            rep,
            P(wtuple),
        )
        out_specs = (
            jax.tree_util.tree_map(p_spec, params),
            opt_spec_tree(opt_state),
            {"loss": rep, "ghat_norm": rep, "step": rep},
        )
        fn = shard_map(
            local_step,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            axis_names=set(waxes),
            check_vma=False,
        )
        return fn(params, opt_state, batch, key,
                  jnp.arange(n_workers, dtype=jnp.int32))

    return jax.jit(wrapped, donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# State init (concrete, for real runs)
# ---------------------------------------------------------------------------

def init_train_state(cfg, opt: DianaOptimizer, mesh, key):
    smesh, rwaxes = resolve_train_mesh(mesh, opt.policy.worker_axes)
    opt = resolve_bucketed(opt, smesh, rwaxes)
    waxes = worker_axes_in(mesh, opt.policy.worker_axes)
    n_workers = worker_count(mesh, waxes)

    params_shape = jax.eval_shape(lambda k: init_model(cfg, k), key)
    opt_state_shape = jax.eval_shape(lambda p: opt.init(p, n_workers), params_shape)
    p_shard, o_shard = train_state_shardings(cfg, opt, mesh, params_shape, opt_state_shape)

    params = jax.jit(lambda k: init_model(cfg, k), out_shardings=p_shard)(key)
    opt_state = jax.jit(lambda p: opt.init(p, n_workers), out_shardings=o_shard)(params)
    return params, opt_state, (p_shard, o_shard)


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description="DIANA distributed trainer")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--inner", default="momentum", choices=["momentum", "adamw"])
    from repro.core import available_methods

    ap.add_argument("--compression", default=None,
                    choices=[None, *available_methods()])
    ap.add_argument("--comp-k", type=int, default=None,
                    help="kept coordinates for rand-k / top-k compressors")
    ap.add_argument("--down-method", default=None,
                    choices=[None, *available_methods()],
                    help="compress the server->worker broadcast too "
                         "(bidirectional DIANA): any registry operator, with "
                         "its own downlink memory h_down; default keeps the "
                         "broadcast full-precision")
    ap.add_argument("--down-k", type=int, default=None,
                    help="kept coordinates for a sparse downlink operator "
                         "(default: --comp-k)")
    ap.add_argument("--comp-policy", default=None,
                    help="per-parameter-group compression policy: a policy "
                         ".json file, inline rules "
                         "(pattern=method[:opt=v...][/down_method...],...; "
                         "'*' = catch-all), or 'default' for the model's "
                         "curated ModelConfig.comp_policy.  Overrides the "
                         "flat --compression/--comp-k/--down-* surface")
    ap.add_argument("--chunk-bytes", type=int, default=None,
                    help="split the bucketed wire into ~this many bytes per "
                         "chunk (ChunkedSchedule): chunk i+1's all-gather is "
                         "issued before chunk i's decode so communication "
                         "overlaps decode work.  0/default keeps the "
                         "monolithic single-chunk wire; results are bitwise "
                         "identical either way")
    ap.add_argument("--topology", default=None,
                    choices=[None, "flat", "hierarchical"],
                    help="aggregation topology: 'flat' (default) exchanges "
                         "compressed payloads between all workers; "
                         "'hierarchical' runs an uncompressed intra-node "
                         "mean first, then the compressed DIANA exchange "
                         "between node leaders (h kept per node, so "
                         "h == mean(h_i) holds exactly).  Bucketed only")
    ap.add_argument("--node-size", type=int, default=None,
                    help="workers per node for --topology hierarchical "
                         "(must divide the worker count; inferred from a "
                         "'node' mesh axis when present)")
    ap.add_argument("--per-leaf-agg", action="store_true",
                    help="disable the bucketed (flat-buffer) aggregation and "
                         "compress/gather/decode each parameter leaf separately")
    ap.add_argument("--vr", action="store_true",
                    help="VR-DIANA (arXiv:1904.05115): per-worker L-SVRG "
                         "control variates under the compressed-difference "
                         "loop (one extra backward pass per step)")
    ap.add_argument("--vr-p", type=float, default=None,
                    help="L-SVRG snapshot-refresh probability; default is the "
                         "paper's 1/m with m = the per-worker batch size")
    ap.add_argument("--participation-q", type=float, default=None,
                    help="elastic rounds: independent per-worker sampling "
                         "probability q (partial participation; the masked "
                         "sum is rescaled to stay unbiased).  Default 1.0 "
                         "keeps the exact pre-elastic path")
    ap.add_argument("--participation-dropout", type=float, default=None,
                    help="straggler model: probability a sampled worker "
                         "misses the round deadline and is dropped (its "
                         "DIANA memory freezes; the rescale stays unbiased)")
    ap.add_argument("--min-workers", type=int, default=None,
                    help="degraded-step floor: with fewer than this many "
                         "participants the round applies no update (ghat=0, "
                         "all state frozen) instead of a high-variance step")
    ap.add_argument("--faults", default=None,
                    help="fault-injection plan: ';'-separated "
                         "'kind:step=S,worker=W[,byte=B|delay=D]' events with "
                         "kind in {drop,delay,corrupt} (e.g. "
                         "'corrupt:step=3,worker=1'), or the bare word "
                         "'checksum' to arm the wire checksum with no "
                         "injected faults.  Requires the bucketed layout")
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 (data x model) or 2x2x2")
    ap.add_argument("--reduced", action="store_true", help="toy config for CPU runs")
    ap.add_argument("--batch", type=int, default=None, help="override global batch")
    ap.add_argument("--seq", type=int, default=None, help="override sequence length")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args(argv)

    from dataclasses import replace as dc_replace

    from repro.configs import reduced as make_reduced
    from repro.configs.base import ShapeConfig
    from repro.data import make_lm_batch

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    if args.compression:
        cfg = dc_replace(cfg, compression=args.compression)
    if args.comp_k:
        cfg = dc_replace(cfg, comp_k=args.comp_k)
    if args.down_method:
        cfg = dc_replace(cfg, comp_down_method=args.down_method)
    if args.down_k:
        cfg = dc_replace(cfg, comp_down_k=args.down_k)
    if args.per_leaf_agg:
        cfg = dc_replace(cfg, comp_bucketed=False)
    shape = get_shape(args.shape)
    if args.batch or args.seq:
        shape = ShapeConfig(shape.name, args.seq or shape.seq_len,
                            args.batch or shape.global_batch, shape.kind)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        # Under --topology hierarchical a 3-dim mesh is (node, data, model):
        # the leading axis marks the node boundary the two-level round uses.
        axes = (("node", "data", "model") if args.topology == "hierarchical"
                and len(dims) == 3 else ("pod", "data", "model"))[-len(dims):]
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_mesh((jax.device_count(), 1), ("data", "model"))

    if args.vr:
        smesh0, waxes0 = resolve_train_mesh(mesh, cfg.comp_worker_axes)
        m_local = max(1, shape.global_batch // max(worker_count(smesh0, waxes0), 1))
        cfg = dc_replace(cfg, vr=True,
                         vr_p=resolve_vr_p(args.vr_p, m_local))

    participation = None
    if (args.participation_q is not None or args.participation_dropout is not None
            or args.min_workers is not None):
        from repro.core.participation import ParticipationSpec

        participation = ParticipationSpec(
            q=1.0 if args.participation_q is None else args.participation_q,
            dropout=args.participation_dropout or 0.0,
            min_workers=args.min_workers or 1,
        )
    from repro.core.participation import parse_faults

    faults = parse_faults(args.faults)
    if faults is not None and (args.per_leaf_agg or not cfg.comp_bucketed
                               or args.comp_policy):
        raise SystemExit("--faults needs the flat bucketed layout (the "
                         "checksum rides the fused wire buffer)")

    opt = make_optimizer(cfg, lr=args.lr, inner=args.inner,
                         policy=args.comp_policy, participation=participation)
    if args.chunk_bytes is not None or args.topology or args.node_size:
        pol = opt.policy
        node_size = args.node_size or pol.node_size
        topology = args.topology or pol.topology
        waxes_pol = pol.worker_axes
        if topology == "hierarchical" and "node" in mesh.axis_names:
            # A 'node' worker mesh axis declares the node boundary: it joins
            # the worker axes (leading, so resolve_train_mesh flattens
            # node-major) and the workers of one node are the contiguous
            # non-'node' remainder.
            if "node" not in waxes_pol:
                waxes_pol = ("node",) + tuple(waxes_pol)
            if args.node_size is None:
                node_size = (worker_count(mesh, waxes_pol)
                             // mesh.shape["node"])
        opt = opt.replace(policy=pol.replace(
            chunk_bytes=pol.chunk_bytes if args.chunk_bytes is None
            else args.chunk_bytes,
            topology=topology, node_size=node_size,
            worker_axes=waxes_pol))
    key = jax.random.PRNGKey(0)
    params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
    step_fn = build_train_step(cfg, opt, mesh, shape, faults=faults)
    smesh, _ = resolve_train_mesh(mesh, opt.policy.worker_axes)

    from repro.launch.sharding_rules import batch_specs as bspecs

    for step in range(args.steps):
        host_batch = make_lm_batch(cfg, shape, step)
        bs = bspecs(host_batch, smesh)
        batch = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, NamedSharding(smesh, s)), host_batch, bs
        )
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch, jax.random.fold_in(key, step))
        loss = float(metrics["loss"])
        print(f"step {step:4d} loss {loss:8.4f} ghat {float(metrics['ghat_norm']):9.4f} "
              f"({time.perf_counter() - t0:5.2f}s)")

    if args.checkpoint_dir:
        from repro.checkpoint import save_checkpoint

        # The policy rides in the manifest metadata so a restore can rebuild
        # the matching (possibly grouped) state template without the CLI args.
        save_checkpoint(args.checkpoint_dir, args.steps, {"params": params},
                        metadata={"policy": opt.policy.to_json_dict()})
        print(f"checkpoint written to {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
