"""Boundary sharding derivation: param / optimizer-state / cache / batch specs.

JAX requires *even* divisibility for jit in_shardings, so every rule checks
divisibility and falls back to replication for that dim — interior
``with_sharding_constraint`` annotations (which tolerate padding) still guide
GSPMD where it matters.  FSDP: when the DIANA workers are coarser than the
data axes (hierarchical mode), the inner data axes are free to ZeRO-shard
params/optimizer state; ``fsdp_axes`` names them.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "batch_specs", "cache_specs", "named", "replicated"]


def replicated(mesh):
    return NamedSharding(mesh, P())


def named(mesh, spec):
    return NamedSharding(mesh, spec)


def _fits(dim: int, mesh, axes) -> bool:
    if not axes:
        return False
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0


def _dim(mesh, dim_size, axes):
    """axes (str | tuple | None) if divisible else None."""
    if axes is None:
        return None
    ax = tuple(a for a in ((axes,) if isinstance(axes, str) else axes))
    return (ax if len(ax) > 1 else ax[0]) if _fits(dim_size, mesh, ax) else None


def param_specs(params, cfg, mesh, *, fsdp_axes: Tuple[str, ...] = ()) -> Any:
    """PartitionSpec pytree for the model params.

    Rules (DESIGN.md): attention/MLP weights shard their feature dim over
    'model' (flattened H*Dh — always divisible); the other matmul dim FSDPs
    over the inner data axes in hierarchical mode; embeddings shard the padded
    vocab over 'model'; norms/bias/small vectors replicate.
    """
    model_ax = "model" if "model" in mesh.axis_names else None
    fsdp = tuple(a for a in fsdp_axes if a in mesh.axis_names) or None

    def spec_for(path, leaf):
        names = [_path_str(p) for p in path]
        name = names[-1]
        nd = leaf.ndim
        in_blocks = "blocks" in names
        lead = (None,) if in_blocks else ()   # stacked layer dim

        def mk(*dims):
            return P(*(lead + dims))

        d = {a: None for a in ()}
        if name in ("embed",):
            # vocab dim stays UNsharded: XLA's SPMD partitioner cannot handle
            # the token-gather into a sharded dim under manual subgroups
            # (spmd_partitioner_util CHECK failure) — shard the feature dim.
            return P(None, _dim(mesh, leaf.shape[1], model_ax))
        if name in ("lm_head",):
            return P(_dim(mesh, leaf.shape[0], fsdp), _dim(mesh, leaf.shape[1], model_ax))
        if name in ("wq", "wk", "wv", "w_in", "w_gate", "in_proj"):
            # (.., D, F): column-parallel -> F over model, D over fsdp
            if nd - len(lead) == 2:
                return mk(_dim(mesh, leaf.shape[-2], fsdp), _dim(mesh, leaf.shape[-1], model_ax))
        if name in ("wo", "w_out", "out_proj"):
            if nd - len(lead) == 2:
                return mk(_dim(mesh, leaf.shape[-2], model_ax), _dim(mesh, leaf.shape[-1], fsdp))
        if "mlp" in names and name in ("w_in", "w_gate", "w_out") and nd - len(lead) == 3:
            # MoE experts (E, D, F) / (E, F, D)
            e = leaf.shape[-3]
            if cfg.moe and cfg.moe.partition == "expert" and _fits(e, mesh, (model_ax,)):
                return mk(model_ax, _dim(mesh, leaf.shape[-2], fsdp), None)
            # ffn-partitioned experts: shard the hidden dim
            if name == "w_out":
                return mk(None, _dim(mesh, leaf.shape[-2], model_ax), _dim(mesh, leaf.shape[-1], fsdp))
            return mk(None, _dim(mesh, leaf.shape[-2], fsdp), _dim(mesh, leaf.shape[-1], model_ax))
        if name == "router":
            return mk(_dim(mesh, leaf.shape[-2], fsdp), None)
        if name == "conv_w":
            return mk(None, _dim(mesh, leaf.shape[-1], model_ax))
        if name == "w" and "frontend_proj" in names:
            return P(_dim(mesh, leaf.shape[0], fsdp), _dim(mesh, leaf.shape[1], model_ax))
        # norms, biases, scalars, dt_bias, A_log, D, conv_b, norm_scale ...
        return P(*((None,) * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _path_str(entry) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def batch_specs(batch_like, mesh, *, data_only: bool = False) -> Any:
    """Batch dim over all data axes (boundary: global batch divisible by them)."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec_for(leaf):
        b = leaf.shape[0]
        first = _dim(mesh, b, ax)
        return P(*((first,) + (None,) * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(spec_for, batch_like)


def cache_specs(caches, cfg, mesh, *, batch: int) -> Any:
    """Decode-cache sharding: batch over data axes when it divides, else the
    cache sequence dim (long_500k batch=1 -> sequence parallelism); SSD/conv
    states shard their channel/head dims over 'model'."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    model_ax = "model" if "model" in mesh.axis_names else None
    batch_fits = _fits(batch, mesh, dax) if dax else False

    def spec_for(path, leaf):
        names = [_path_str(p) for p in path]
        name = names[-1]
        # all caches are stacked over blocks -> leading n_blocks dim
        if name in ("k", "v"):       # (nb, B, S, Hkv, Dh)
            # kv_heads rarely divide the model axis (GQA), so the HEAD_DIM
            # shards over 'model' instead — score contractions become partial
            # sums + a tiny all-reduce, and the cache bytes drop 16x.
            hd = _dim(mesh, leaf.shape[3], model_ax) or None
            dh = None if hd else _dim(mesh, leaf.shape[4], model_ax)
            if batch_fits:
                return P(None, dax, None, hd, dh)
            return P(None, None, _dim(mesh, leaf.shape[2], dax), hd, dh)
        if name == "conv":           # (nb, B, W-1, CH)
            return P(None, dax if batch_fits else None, None, _dim(mesh, leaf.shape[3], model_ax))
        if name == "ssm":            # (nb, B, H, P, N)
            return P(None, dax if batch_fits else None, _dim(mesh, leaf.shape[2], model_ax), None, None)
        return P(*((None,) * leaf.ndim))  # pos counters etc.

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(treedef, [spec_for(p, l) for p, l in flat])
