"""Launchers: mesh construction, training/serving steps, multi-pod dry-run.

NOTE: do not import ``dryrun`` from here — it sets XLA_FLAGS at import time
and must only be executed as ``python -m repro.launch.dryrun``.
"""

from . import mesh, sharding_rules

__all__ = ["mesh", "sharding_rules"]
