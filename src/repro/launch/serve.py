"""Serving: batched prefill + single-token decode steps under pure GSPMD.

decode_32k: 128 sequences, KV/SSM caches sharded over the batch dim.
long_500k:  batch=1 — the KV cache shards its *sequence* dim over the data
axes; distributed softmax (max/sum all-reduces) falls out of GSPMD, i.e.
flash-decoding-style sequence parallelism without manual collectives.
Attention-only archs run their sliding-window variant (ring-buffer cache of
``cfg.sliding_window``), SSM/hybrid archs use their native O(1) state.

DIANA is a training-time technique; serve steps do not compress (paper scope).
"""

from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.models import decode_step, forward, init_caches, init_model
from repro.models.sharding import GSPMDPolicy, sharding_policy

from .mesh import make_mesh
from .sharding_rules import cache_specs, param_specs

__all__ = ["decode_window", "build_serve_step", "build_prefill", "serve_cache_shardings"]


def decode_window(cfg, shape) -> Optional[int]:
    """long_500k engages the sliding window on attention archs (hybrids keep
    full attention — their mamba layers carry the long context)."""
    if shape.name == "long_500k" and not cfg.has_mamba():
        return cfg.sliding_window
    return None


def serve_cache_shardings(cfg, mesh, shape):
    window = decode_window(cfg, shape)
    caches_shape = jax.eval_shape(
        lambda: init_caches(cfg, shape.global_batch, shape.seq_len, window=window)
    )
    specs = cache_specs(caches_shape, cfg, mesh, batch=shape.global_batch)
    return (
        jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs),
        caches_shape,
        window,
    )


def build_serve_step(cfg, mesh, shape):
    """jitted decode: (params, caches, tokens (B,1)) -> (logits, new_caches).

    bf16 caches are stored as bit-equal uint16 (see models.layers.AttnCache):
    integer dynamic-update-slice avoids the XLA-CPU bf16->f32 promotion that
    would otherwise triple the measured decode memory in the dry-run.
    """
    window = decode_window(cfg, shape)

    def step(params, caches, tokens):
        with sharding_policy(GSPMDPolicy(mesh)):
            logits, new_caches = decode_step(params, tokens, caches, cfg, window=window)
        return logits, new_caches

    return jax.jit(step, donate_argnums=(1,))


def build_prefill(cfg, mesh, shape):
    """jitted prefill forward returning next-token logits (B, 1, V) — full
    (B, S, V) logits would be ~0.5 TB at prefill_32k scale and no serving
    path needs them."""

    def step(params, batch):
        with sharding_policy(GSPMDPolicy(mesh)):
            logits, aux, _ = forward(params, batch, cfg, last_token_only=True)
        return logits

    return jax.jit(step)


# ---------------------------------------------------------------------------
# CLI: batched-request serving demo
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description="DIANA-framework serving demo")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--tokens", type=int, default=16, help="tokens to decode")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args(argv)

    from repro.configs import reduced as make_reduced
    from repro.configs.base import ShapeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
        shape = ShapeConfig("reduced-decode", args.cache_len, args.batch, "decode")
    else:
        shape = get_shape(args.shape)

    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    window = decode_window(cfg, shape)
    caches = init_caches(cfg, shape.global_batch, shape.seq_len, window=window)
    step_fn = build_serve_step(cfg, mesh, shape)

    tokens = jax.random.randint(key, (shape.global_batch, 1), 0, cfg.vocab)
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, caches = step_fn(params, caches, tokens)
        tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x {shape.global_batch} seqs in {dt:.2f}s "
          f"({args.tokens * shape.global_batch / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
