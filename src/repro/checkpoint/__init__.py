from .checkpoint import (
    latest_step,
    load_metadata,
    participation_restore_hint,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "load_metadata", "participation_restore_hint"]
