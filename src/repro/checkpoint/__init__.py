from .checkpoint import (
    latest_step,
    load_metadata,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "load_metadata"]
