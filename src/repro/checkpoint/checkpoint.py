"""Checkpointing: pytree <-> .npz with a JSON manifest (no orbax dependency).

Handles bf16 leaves via ml_dtypes (a JAX dependency), preserves tree structure
through key-path flattening, and round-trips DianaOptState / model params /
caches alike — including the optional VR-DIANA slot (`DianaState.vr`) and the
optional downlink memory (`DianaState.h_down`): when present their leaves
flatten under `.../vr/...` / `.../h_down/...` key paths like any other state,
and when None the NamedTuple child flattens away, so checkpoints written with
those features off carry no dead keys.  Writes are atomic (tmp + rename) — a
crashed save never corrupts the previous checkpoint.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import ml_dtypes
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "load_metadata", "participation_restore_hint"]

_MANIFEST = "manifest.json"

# dtypes numpy cannot natively save/cast — stored as bit-equal uint views
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, tree, metadata=None) -> str:
    """``metadata`` (a JSON-serializable dict, e.g. the serialized
    :class:`~repro.core.policy.CompressionPolicy` that shaped a grouped
    DianaState) rides in the manifest next to the keys/dtypes — read it back
    with :func:`load_metadata` to rebuild a matching state template."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    dtypes: Dict[str, str] = {}
    stored: Dict[str, np.ndarray] = {}
    for k, v in flat.items():
        name = str(v.dtype)
        dtypes[k] = name
        if name in _EXOTIC:
            stored[k] = v.view(_EXOTIC[name][1])
        else:
            stored[k] = v
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **stored)
    os.replace(tmp, path)
    manifest = {"step": step, "keys": sorted(flat), "dtypes": dtypes,
                "file": os.path.basename(path)}
    if metadata is not None:
        manifest["metadata"] = metadata
    mtmp = path + ".manifest.tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(mtmp, os.path.join(directory, _MANIFEST))
    return path


def restore_checkpoint(directory: str, template, step: int | None = None):
    """Restore into the structure of ``template`` (dtypes/shapes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with open(os.path.join(directory, _MANIFEST)) as f:
        dtypes = json.load(f).get("dtypes", {})
    data = np.load(path, allow_pickle=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kpath, leaf in flat:
        key = "/".join(_path_str(p) for p in kpath)
        if key not in data:
            hint = ""
            parts = key.split("/")
            if "vr" in parts:
                hint = (" — the checkpoint was saved without a VR slot "
                        "(vr=False); restore into a matching template or "
                        "re-init the VR state after restoring the rest")
            elif "h_down" in parts:
                hint = (" — the checkpoint was saved without a downlink "
                        "memory (down_method=None); restore into a matching "
                        "template or re-init h_down (zeros) after restoring "
                        "the rest")
            raise KeyError(f"checkpoint missing leaf {key!r}{hint}")
        arr = data[key]
        saved_dtype = dtypes.get(key, str(arr.dtype))
        if saved_dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[saved_dtype][0])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def latest_step(directory: str) -> int | None:
    mpath = os.path.join(directory, _MANIFEST)
    if not os.path.exists(mpath):
        return None
    with open(mpath) as f:
        return int(json.load(f)["step"])


def load_metadata(directory: str):
    """The manifest's ``metadata`` dict (``None`` for checkpoints written
    without one — every pre-policy checkpoint)."""
    mpath = os.path.join(directory, _MANIFEST)
    if not os.path.exists(mpath):
        return None
    with open(mpath) as f:
        return json.load(f).get("metadata")


def participation_restore_hint(directory: str, policy) -> str | None:
    """A human-readable warning when the restore template's elastic spec
    differs from the one the checkpoint was trained under, else ``None``.

    Participation adds NO state leaves (the mask algebra is fixed-shape
    SPMD — DESIGN.md §Elasticity), so :func:`restore_checkpoint` cannot
    catch a changed spec the way a missing ``vr``/``h_down`` key catches a
    changed feature flag.  The mismatch is legal — every worker memory is a
    valid h_i regardless of who produced it — but the participation mask is
    keyed by the step counter, so resuming under a different spec (or a
    shifted churn schedule) samples a different worker sequence from the
    resume step onward.  Callers that care (tests, the CLI trainer) compare
    here and surface the hint instead of silently proceeding.

    ``policy`` is the :class:`~repro.core.policy.CompressionPolicy` of the
    restore template; the saved side comes from the manifest metadata's
    serialized policy (``metadata["policy"]``, absent = pre-elastic save).
    """
    meta = load_metadata(directory)
    saved = (meta or {}).get("policy", {}).get("participation")
    spec = getattr(policy, "participation", None)
    live = spec.to_json_dict() if spec is not None and not spec.is_trivial else None
    if saved == live:
        return None
    return (
        f"participation spec changed between save and restore "
        f"(checkpoint: {saved!r}, template: {live!r}) — state shapes are "
        f"unaffected, but the step-keyed participation mask (and any churn "
        f"schedule) will sample a different worker sequence from step "
        f"{latest_step(directory)} onward; pass the saved spec to resume "
        f"the exact trajectory"
    )
