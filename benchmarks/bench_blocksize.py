"""Table 4 / Figure 5 reproduction: optimal quantization block (bucket) size.

Paper finding: with l-inf quantization the optimal block is the FULL vector
(112 for mushrooms); with l-2 quantization smaller blocks (~25) win.  We sweep
block sizes on the synthetic mushrooms-scale problem and report the best.
"""

from __future__ import annotations

import math

from .common import fstar_logreg, run_logreg

STEPS = 600
BLOCKS = (4, 12, 28, 56, 112)   # 112 = full dim (multiples of 4 for packing)


def run():
    fstar = fstar_logreg()
    rows, best = [], {}
    for p, pname in ((2.0, "l2"), (math.inf, "linf")):
        gaps = {}
        for b in BLOCKS:
            res = run_logreg("diana", p, steps=STEPS, gamma=1.0, block=b)
            gaps[b] = max(res["final_loss"] - fstar, 1e-12)
            rows.append({
                "name": f"tab4_blocksize/{pname}_b{b}",
                "us_per_call": round(res["us_per_step"], 1),
                "derived": f"gap={gaps[b]:.3e}",
            })
        best[pname] = min(gaps, key=gaps.get)
        rows.append({
            "name": f"tab4_blocksize/{pname}_optimal",
            "us_per_call": 0.0,
            "derived": f"block={best[pname]}",
        })
    rows.append({
        "name": "tab4_blocksize/CLAIM_linf_prefers_larger_blocks",
        "us_per_call": 0.0,
        "derived": str(best["linf"] >= best["l2"]),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
