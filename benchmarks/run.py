"""Benchmark aggregator — one module per paper table/figure.

Prints the required ``name,us_per_call,derived`` CSV.  Modules:

  bench_compressors     Fig. 1 extended   bits/dim vs suboptimality, all operators
  bench_convergence     Fig. 1 / Fig. 3   DIANA vs QSGD/TernGrad/DQGD/SGD
  bench_norm_power      Tab. 3 / Cor. 1   iteration complexity vs p
  bench_blocksize       Tab. 4 / Fig. 5   optimal bucket sizes per norm
  bench_comm            Fig. 2 / 6 / 8    bytes on the wire, crossover n
  bench_sparsity        Fig. 13 / Thm. 1  transmitted-vector sparsity
  bench_variance        Lem. 2            quantization variance + kernel time
  bench_rosenbrock      Sec. M.1          nonconvex toy comparison
  bench_decreasing_step Thm. 3 / Cor. 2   O(1/k) with noise
  bench_vr              1904.05115 Thm3.1 VR-DIANA linear vs stochastic floors
  bench_step_time       ISSUE 2           bucketed vs per-leaf step time
  roofline              deliverable (g)   3-term roofline from dry-run artifacts

Run:  PYTHONPATH=src python -m benchmarks.run [--only <module substring>]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "bench_compressors",
    "bench_convergence",
    "bench_norm_power",
    "bench_blocksize",
    "bench_comm",
    "bench_sparsity",
    "bench_variance",
    "bench_rosenbrock",
    "bench_decreasing_step",
    "bench_vr",
    "bench_step_time",
    "roofline",
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on module name")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
        except Exception as e:
            failures.append(name)
            print(f"{name}/ERROR,0,\"{type(e).__name__}: {str(e)[:120]}\"")
            traceback.print_exc(file=sys.stderr)
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']},\"{derived}\"")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILED modules: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
