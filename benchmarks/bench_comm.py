"""Figure 2 / Figure 6 / Figure 8 reproduction: communication cost.

The paper measures MPI wall-clock on a Cray network; offline CI measures
*bytes moved* exactly — per-device ring-model bytes for (a) FP32 all-reduce of
dense gradients vs (b) DIANA's 2-bit packed all-gather + scales — across the
assigned model sizes and worker counts, plus projected wall time at v5e ICI
bandwidth (50 GB/s/link).  Crossover worker counts are derived, motivating the
hierarchical worker mode (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.compression import CompressionConfig, payload_bits_per_dim
from repro.models import init_model

ICI = 50e9


def ring_allreduce_bytes(d: int, n: int, bytes_per=4.0) -> float:
    return 2 * d * bytes_per * (n - 1) / n


def diana_gather_bytes(d: int, n: int, cfg: CompressionConfig) -> float:
    per_dim = payload_bits_per_dim(cfg) / 8.0
    full = n * d * per_dim          # gathered buffer
    return full * (n - 1) / n


def run():
    rows = []
    cfg_c = CompressionConfig(block_size=2048)
    sizes = {}
    for arch in ("llama3.2-1b", "mamba2-130m", "granite-8b"):
        mc = get_config(arch)
        params = jax.eval_shape(lambda k: init_model(mc, k), jax.random.PRNGKey(0))
        sizes[arch] = sum(l.size for l in jax.tree_util.tree_leaves(params))

    for arch, d in sizes.items():
        for n in (2, 4, 8, 16, 32, 64):
            fp32 = ring_allreduce_bytes(d, n)
            diana = diana_gather_bytes(d, n, cfg_c)
            rows.append({
                "name": f"fig2_comm/{arch}_n{n}",
                "us_per_call": round(diana / ICI * 1e6, 1),   # projected wire time
                "derived": f"fp32_MB={fp32/1e6:.0f} diana_MB={diana/1e6:.0f} ratio={fp32/diana:.1f}x",
            })
        # crossover: diana wins while n/16 < 2 (2-bit vs 32-bit, gather vs ring)
        cross = next((n for n in range(2, 128)
                      if diana_gather_bytes(d, n, cfg_c) > ring_allreduce_bytes(d, n)), None)
        rows.append({
            "name": f"fig2_comm/{arch}_crossover_n",
            "us_per_call": 0.0,
            "derived": f"{cross} (hierarchical workers beyond this)",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
