"""Step-time benchmark: bucketed vs per-leaf aggregation (ISSUE 2).

Measures wall-clock time of one DIANA aggregation step across operators,
model sizes, and execution paths, and emits ``BENCH_step_time.json`` at the
repo root so every PR from here on has a perf trajectory:

* ``reference`` — the n-worker single-process `reference_step` (the path the
  convex benchmarks and figure reproductions run);
* ``shardmap``  — `aggregate_shardmap` inside a real worker shard_map (only
  when >= 4 devices are available, e.g. under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Each (size, operator, path) cell is timed for both layouts; the JSON also
records the per-layout payload collective count implied by the leaf count
(leaves x fields vs 1) for the HBM/collective table in DESIGN.md §Perf, and
PER-DIRECTION wire accounting: ``uplink_bits_per_dim`` (worker -> server
payload), ``downlink_bits_per_dim`` (the broadcast — 32 for uplink-only
configs, the downlink operator's rate for bidirectional rows, DESIGN.md
§Bidirectional) and their ``bits_per_dim_total``.  The operator grid includes
a bidirectional ``diana+down`` row so the uplink-vs-total trade-off is part
of the committed trajectory.

Each row also carries ``fraction_of_roofline_{perleaf,bucketed}``: the
ANALYTIC minimum memory traffic of one aggregation round (grads read, worker
memory read+write, wire payload, server memory + ghat — a floor, not the
achieved traffic) divided by measured time x the MEASURED streaming peak from
:func:`benchmarks.roofline.measure_peak_bandwidth` (memoized, so every row
divides by the same number).  It answers "how far is this step from pure
bandwidth-bound data movement" — on CPU CI with interpreted kernels it is a
trajectory signal, on TPU a real roofline fraction.

Run directly (``python -m benchmarks.bench_step_time [--smoke]``) or via
``benchmarks.run``.  ``--smoke`` cuts steps/reps for CI but keeps the full
size x operator grid, so the uploaded artifact always satisfies the >= 2
sizes x >= 3 operators acceptance shape.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.core import (
    ChannelSpec,
    CompressionConfig,
    CompressionPolicy,
    Rule,
    policy_bits_per_dim,
    reference_init,
    reference_step,
)
from repro.core.diana import DianaState, aggregate_shardmap, bucket_layout, init_state

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "BENCH_step_time.json")


def smoke_out_path(committed: str) -> str:
    """Scratch destination for a --smoke run of a trajectory artifact.

    Smoke rows measure a cut-down grid on whatever machine CI landed on —
    they must NEVER land next to the committed repo-root JSON (a sibling
    file still pollutes `git status` and invites an accidental commit), so
    without an explicit ``--out`` they go to the system temp dir.
    """
    import tempfile

    base = os.path.basename(committed).replace(".json", ".smoke.json")
    return os.path.join(tempfile.gettempdir(), base)

N_WORKERS = 4

# Synthetic multi-leaf "models": many leaves is exactly the regime the
# bucketed layout targets (a transformer has ~100), sized for CPU CI.
def _layered(n_layers, d, emb):
    return [("emb", emb)] + [
        (f"l{i}.{nm}", shp)
        for i in range(n_layers)
        for nm, shp in [("wq", (d, d)), ("wo", (d, d)), ("mlp", (d, 2 * d)), ("b", (2 * d,))]
    ]


# full grid: ~34 leaves / ~66k params and ~66 leaves / ~530k params
SIZES = {
    "small": _layered(8, 32, (64, 32)),
    "medium": _layered(16, 64, (256, 64)),
}
# smoke keeps the 2-sizes x >=3-operators shape (incl. the bidirectional
# diana+down row) but compiles ~4x less
SIZES_SMOKE = {
    "tiny": _layered(4, 16, (32, 16)),
    "small": SIZES["small"],
}

# (row label, registry method, CompressionConfig kwargs); method=None rows
# run the MIXED policy built by _mixed_policy instead of a flat config
OPERATORS = [
    ("diana", "diana", dict(block_size=256, p=math.inf)),
    ("natural", "natural", {}),
    ("randk", "randk", dict(k=32)),
    # bidirectional: compressed broadcast with downlink memory
    ("diana+down", "diana", dict(block_size=256, p=math.inf,
                                 down_method="diana")),
    # grouped CompressionPolicy: exact biases + top-k embedding + ternary
    # dense in ONE aggregation step (DESIGN.md §Policy) — the per-group
    # collective count is what the grouped-bucketed layout is for
    ("policy-mix", None, {}),
]


def _mixed_policy(bucketed: bool) -> CompressionPolicy:
    return CompressionPolicy(
        rules=(
            Rule(r"\.b$", ChannelSpec(method="identity")),
            Rule("^emb$", ChannelSpec(method="topk_ef", k=32)),
            Rule(".*", ChannelSpec(method="diana", block_size=256)),
        ),
        bucketed=bucketed,
        worker_axes=("data",),
    )


def _params(spec):
    return {name: jnp.zeros(shape, jnp.float32) for name, shape in spec}


def _grads(params, n, key):
    return {
        k: jax.random.normal(jax.random.fold_in(key, i), (n,) + v.shape)
        for i, (k, v) in enumerate(params.items())
    }


def _timeit_interleaved(cells: dict, reps: int) -> dict:
    """Median wall time in us per cell, post-warmup, with the cells'
    executions INTERLEAVED rep by rep: ambient load on a shared CPU then
    perturbs every layout equally instead of poisoning one cell's whole
    measurement window (which flips individual comparisons run to run)."""
    for fn, args in cells.values():
        jax.block_until_ready(fn(*args))
    ts = {k: [] for k in cells}
    for _ in range(reps):
        for k, (fn, args) in cells.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts[k].append(time.perf_counter() - t0)
    return {k: sorted(v)[len(v) // 2] * 1e6 for k, v in ts.items()}


def _setup_reference(params, cfg, key, faults=None, node_size=1):
    del node_size  # reference_step derives the node structure from cfg
    grads = _grads(params, N_WORKERS, key)
    state = reference_init(params, cfg, N_WORKERS)
    kw = dict(step=0, faults=faults) if faults is not None else {}
    step = jax.jit(lambda g, s, k: reference_step(g, s, k, cfg, **kw))
    return step, (grads, state, key)


def _setup_shardmap(params, cfg, key, faults=None, node_size=1):
    """The real distributed round over a 4-worker mesh (needs >= 4 devices)."""
    if jax.device_count() < N_WORKERS:
        return None
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.diana import DOWN_FOLD
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((N_WORKERS, 1), ("data", "model"))
    grads = _grads(params, N_WORKERS, key)
    state = init_state(params, cfg, N_WORKERS)
    has_down = state.h_down is not None

    elastic = (getattr(cfg, "participation", None) is not None
               and not cfg.participation.is_trivial)

    def body(gs, h_w, h_s, h_d, k):
        g_local = jax.tree_util.tree_map(lambda g: g[0], gs)
        widx = jax.lax.axis_index("data")
        # hierarchical: node-folded key (core.diana's caller contract)
        wkey = jax.random.fold_in(k, widx // node_size)
        kw = dict(down_key=jax.random.fold_in(k, DOWN_FOLD)) if has_down else {}
        if elastic or faults is not None:
            from repro.core.diana import PART_FOLD

            kw.update(part_key=jax.random.fold_in(k, PART_FOLD),
                      worker_index=widx)
        if faults is not None:
            kw.update(faults=faults, step=jnp.zeros((), jnp.int32))
        ghat, new = aggregate_shardmap(
            g_local, DianaState(h_w, h_s, None, h_d), wkey, cfg,
            axis_names=("data",), n_workers=N_WORKERS, **kw)
        return ghat, new.h_worker, new.h_server, new.h_down

    tmap = jax.tree_util.tree_map
    hd_spec = tmap(lambda _: P(), state.h_down)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(tmap(lambda _: P("data"), grads),
                  tmap(lambda _: P("data"), state.h_worker),
                  tmap(lambda _: P(), state.h_server), hd_spec, P()),
        out_specs=(tmap(lambda _: P(), params),
                   tmap(lambda _: P("data"), state.h_worker),
                   tmap(lambda _: P(), state.h_server), hd_spec),
        axis_names={"data"}, check_vma=False)
    return jax.jit(fn), (grads, state.h_worker, state.h_server, state.h_down, key)


PATHS = {
    "reference": _setup_reference,
    "shardmap": _setup_shardmap,
}


def _resolved_layout(cfg) -> str:
    """What layout ``resolve_bucketed`` actually runs on the bench mesh —
    surfaced per row so a toolchain downgrade (old XLA forcing per-leaf)
    is visible in the committed JSON instead of silently skewing a
    'bucketed' column."""
    from repro.launch.mesh import make_mesh
    from repro.launch.train import resolved_layout
    from repro.optim import DianaOptimizer

    n = N_WORKERS if jax.device_count() >= N_WORKERS else 1
    mesh = make_mesh((n, 1), ("data", "model"))
    return resolved_layout(DianaOptimizer(compression=cfg), mesh, ("data",))


def collect(smoke: bool = False, faults: bool = False):
    reps = 5 if smoke else 15
    key = jax.random.PRNGKey(0)
    rows = []
    sizes = SIZES_SMOKE if smoke else SIZES
    for size_name, spec in sizes.items():
        params = _params(spec)
        for label, method, kw in OPERATORS:
            for path, setup in PATHS.items():
                cells = {}
                for layout in ("perleaf", "bucketed"):
                    if method is None:
                        cfg = _mixed_policy(bucketed=(layout == "bucketed"))
                    else:
                        cfg = CompressionConfig(method=method,
                                                bucketed=(layout == "bucketed"), **kw)
                    made = setup(params, cfg, key)
                    if made is not None:
                        cells[layout] = made
                if not cells:
                    continue
                cell = _timeit_interleaved(cells, reps)
                if method is None:
                    pol = _mixed_policy(bucketed=True)
                    n_params = sum(int(v.size) for v in params.values())
                    n_leaves = len(params)
                    up_bits, down_bits = policy_bits_per_dim(pol, params), 32.0
                    layout_resolved = _resolved_layout(pol)
                else:
                    cfg_b = CompressionConfig(method=method, bucketed=True, **kw)
                    lay = bucket_layout(cfg_b, params)
                    n_params, n_leaves = lay.size, lay.n_leaves
                    up_bits, down_bits = _direction_bits(cfg_b, params, lay)
                    layout_resolved = _resolved_layout(cfg_b)
                floor_bytes = _round_bytes_floor(n_params, up_bits, down_bits)
                rows.append({
                    "size": size_name,
                    "n_params": n_params,
                    "n_leaves": n_leaves,
                    "operator": label,
                    "path": path,
                    "resolved_layout": layout_resolved,
                    "us_perleaf": cell.get("perleaf"),
                    "us_bucketed": cell.get("bucketed"),
                    "speedup": (cell["perleaf"] / cell["bucketed"]
                                if "perleaf" in cell and "bucketed" in cell else None),
                    "uplink_bits_per_dim": round(up_bits, 4),
                    "downlink_bits_per_dim": round(down_bits, 4),
                    "bits_per_dim_total": round(up_bits + down_bits, 4),
                    "fraction_of_roofline_perleaf": _roofline_fraction(
                        floor_bytes, cell.get("perleaf")),
                    "fraction_of_roofline_bucketed": _roofline_fraction(
                        floor_bytes, cell.get("bucketed")),
                })
    rows += collect_elastic(smoke, faults=faults)
    rows += collect_topology(smoke)
    return rows


# elastic grid: sampling rate x {memory, error-feedback} operator — the
# step-time cost of the mask algebra plus the honest wire accounting (a
# non-participant sends nothing, so EXPECTED bits/step scale with q)
ELASTIC_QS = (1.0, 0.5, 0.25)
ELASTIC_OPERATORS = [
    ("diana", dict(block_size=256, p=math.inf)),
    ("topk", dict(k=32)),
]


def collect_elastic(smoke: bool = False, faults: bool = False):
    """q x operator rows: bucketed step time under partial participation.

    ``q=1.0`` runs participation=None — the exact pre-elastic code path, the
    baseline the masked rows are compared against.  ``effective`` bits/step
    multiply the operator's wire rate by the a-priori participation rate
    (``repro.core.participation.expected_rate``): the uplink payload of a
    non-participant is never sent, so the expected per-step traffic shrinks
    linearly in q even though the SPMD buffers stay fixed-shape.

    ``faults=True`` (the --faults flag) arms the wire checksum: every wire
    buffer then carries the 8-byte tail — one PER CHUNK of the bucketed
    schedule (``checksum_tail_bits_per_dim``) — and the effective bits
    include it (a participant ships payload + tail; a non-participant ships
    neither).
    """
    from repro.core.participation import (ParticipationSpec, expected_rate,
                                          parse_faults)
    from repro.core import bucketed_compressor
    from repro.core.bucket import checksum_tail_bits_per_dim

    reps = 5 if smoke else 15
    key = jax.random.PRNGKey(1)
    size_name = "tiny" if smoke else "small"
    params = _params((SIZES_SMOKE if smoke else SIZES)[size_name])
    method = {"diana": "diana", "topk": "topk_ef"}
    plan = parse_faults("checksum") if faults else None
    rows = []
    for label, kw in ELASTIC_OPERATORS:
        for q in ELASTIC_QS:
            spec = None if q >= 1.0 else ParticipationSpec(q=q)
            cfg = CompressionConfig(method=method[label], bucketed=True,
                                    participation=spec, **kw)
            cells = {}
            for path, setup in PATHS.items():
                made = setup(params, cfg, key, faults=plan)
                if made is not None:
                    cells[path] = made
            cell = _timeit_interleaved(cells, reps)
            lay = bucket_layout(cfg, params)
            up_bits = bucketed_compressor(cfg, lay).bits_per_dim()
            tail = (checksum_tail_bits_per_dim(lay, cfg.chunk_bytes)
                    if plan is not None else 0.0)
            rate = 1.0 if spec is None else expected_rate(spec)
            rows.append({
                "size": size_name,
                "n_params": lay.size,
                "operator": f"elastic/{label}",
                "participation_q": q,
                "checksum": plan is not None,
                "resolved_layout": _resolved_layout(cfg),
                "us_reference": cell.get("reference"),
                "us_shardmap": cell.get("shardmap"),
                "uplink_bits_per_dim": round(up_bits, 4),
                "checksum_tail_bits_per_dim": round(tail, 6),
                "effective_uplink_bits_per_dim": round((up_bits + tail) * rate, 4),
                "effective_uplink_bits_per_step": round(
                    (up_bits + tail) * rate * lay.size * N_WORKERS, 1),
            })
    return rows


# topology grid (DESIGN.md §Topology): the chunked wire schedule and the
# two-level hierarchical exchange are pure EXECUTION layouts of the same
# round (bitwise-equal results), so these rows measure layout cost exactly
# like the perleaf-vs-bucketed columns do.  (label, chunk_bytes, node_size)
TOPOLOGY_GRID = [
    ("flat", 0, 1),
    ("flat+chunk", 16384, 1),
    ("hier", 0, 2),
    ("hier+chunk", 16384, 2),
]


def collect_topology(smoke: bool = False):
    """``topology`` rows: diana bucketed at the small size across the
    (topology, chunk_bytes, node_size) grid.  The hierarchical rows compress
    only the inter-node exchange (n_eff = n/node_size payload rows), the
    chunked rows overlap each chunk's collective with the previous chunk's
    decode — the committed trajectory shows what each layout buys."""
    reps = 5 if smoke else 15
    key = jax.random.PRNGKey(2)
    size_name = "tiny" if smoke else "small"
    params = _params((SIZES_SMOKE if smoke else SIZES)[size_name])
    rows = []
    for label, cb, ns in TOPOLOGY_GRID:
        cfg = CompressionConfig(
            method="diana", bucketed=True, block_size=256, p=math.inf,
            chunk_bytes=cb, topology="hierarchical" if ns > 1 else "flat",
            node_size=ns)
        cells = {}
        for path, setup in PATHS.items():
            made = setup(params, cfg, key, node_size=ns)
            if made is not None:
                cells[path] = made
        cell = _timeit_interleaved(cells, reps)
        lay = bucket_layout(cfg, params)
        from repro.core.bucket import ChunkedSchedule

        rows.append({
            "size": size_name,
            "n_params": lay.size,
            "operator": f"topology/{label}",
            "topology": cfg.topology,
            "chunk_bytes": cb,
            "n_chunks": ChunkedSchedule.for_layout(lay, cb).n_chunks,
            "node_size": ns,
            "resolved_layout": _resolved_layout(cfg),
            "us_reference": cell.get("reference"),
            "us_shardmap": cell.get("shardmap"),
        })
    return rows


def _round_bytes_floor(n_params: int, up_bits: float, down_bits: float) -> float:
    """Analytic minimum memory traffic of ONE n-worker aggregation round, in
    bytes: per worker, read the gradient and read+write the DIANA memory
    (3 x 4 bytes/dim); the server reads every worker's wire payload and the
    downlink broadcast payload, and reads+writes its own memory plus the ghat
    output (3 x 4 bytes/dim).  A floor — intermediates, padding and collective
    staging all add traffic on top."""
    per_worker = 3 * 4 * n_params + up_bits / 8 * n_params
    server = 3 * 4 * n_params + down_bits / 8 * n_params
    return N_WORKERS * per_worker + server


def _roofline_fraction(nbytes: float, us):
    """``nbytes`` over measured time x the MEASURED peak (memoized in
    :mod:`benchmarks.roofline` — the same denominator as BENCH_roofline.json)."""
    if not us:
        return None
    from benchmarks.roofline import measure_peak_bandwidth

    return round(nbytes / (us * 1e-6) / measure_peak_bandwidth(), 6)


def _direction_bits(cfg, params, lay):
    """Honest per-direction wire cost per coordinate: size-weighted per-leaf
    accounting for the uplink payload, the downlink operator's rate (or the
    32-bit f32 broadcast) for the server direction."""
    from repro.core import bucketed_compressor

    up = bucketed_compressor(cfg, lay).bits_per_dim()
    dcfg = cfg.down_config()
    if dcfg is None:
        return up, 32.0
    return up, bucketed_compressor(dcfg, bucket_layout(dcfg, params)).bits_per_dim()


def write_json(rows, path=OUT_PATH):
    doc = {
        "bench": "step_time",
        "n_workers": N_WORKERS,
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def run():
    """benchmarks.run entry point: returns CSV rows and writes the JSON.

    Runs the smoke grid by default (the aggregator sweeps every module; the
    full grid is ~10 min of compiles) — set ``BENCH_FULL=1`` or invoke
    ``python -m benchmarks.bench_step_time`` directly for the full sizes.
    Only the full grid overwrites the committed repo-root JSON; smoke rows
    go to a scratch file so an aggregator sweep cannot degrade the
    trajectory artifact.
    """
    full = bool(os.environ.get("BENCH_FULL"))
    rows = collect(smoke=not full)
    write_json(rows, OUT_PATH if full else smoke_out_path(OUT_PATH))
    out = []
    for r in rows:
        if "participation_q" in r:
            out.append({
                "name": f"step_time/{r['size']}/{r['operator']}"
                        f"/q{r['participation_q']}",
                "us_per_call": r["us_shardmap"] or r["us_reference"],
                "derived": f"eff_bits_per_dim="
                           f"{r['effective_uplink_bits_per_dim']}",
            })
            continue
        if r["operator"].startswith("topology/"):
            out.append({
                "name": f"step_time/{r['size']}/{r['operator']}",
                "us_per_call": r["us_shardmap"] or r["us_reference"],
                "derived": f"n_chunks={r['n_chunks']} node_size={r['node_size']}",
            })
            continue
        out.append({
            "name": f"step_time/{r['size']}/{r['operator']}/{r['path']}/bucketed",
            "us_per_call": r["us_bucketed"],
            "derived": f"speedup_vs_perleaf={r['speedup']:.2f}x" if r["speedup"] else "",
        })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="fewer reps (CI) — same size x operator grid")
    ap.add_argument("--faults", action="store_true",
                    help="arm the wire checksum on the elastic grid: rows "
                         "then time the per-chunk checksum+verify path and "
                         "the effective bits include the 8-byte tail per "
                         "wire buffer (one per chunk)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: the committed repo-root "
                         "file for full runs, a temp-dir scratch file for "
                         "--smoke so the trajectory artifact is never "
                         "clobbered or shadowed by a sibling)")
    args = ap.parse_args(argv)
    rows = collect(smoke=args.smoke, faults=args.faults)
    out = args.out or (OUT_PATH if not args.smoke else smoke_out_path(OUT_PATH))
    path = write_json(rows, out)
    for r in rows:
        if r["operator"].startswith("topology/"):
            rf = f"{r['us_reference']:10.0f}" if r["us_reference"] else "         -"
            sm = f"{r['us_shardmap']:10.0f}" if r["us_shardmap"] else "         -"
            print(f"{r['size']:7s} {r['operator']:14s} chunks={r['n_chunks']:<3} "
                  f"nodes={r['node_size']:<2} reference{rf}us shardmap{sm}us")
            continue
        if "participation_q" in r:
            rf = f"{r['us_reference']:10.0f}" if r["us_reference"] else "         -"
            sm = f"{r['us_shardmap']:10.0f}" if r["us_shardmap"] else "         -"
            print(f"{r['size']:7s} {r['operator']:14s} q={r['participation_q']:<5} "
                  f"reference{rf}us shardmap{sm}us "
                  f"eff_bits/dim {r['effective_uplink_bits_per_dim']}")
            continue
        pl = f"{r['us_perleaf']:10.0f}" if r["us_perleaf"] else "         -"
        bk = f"{r['us_bucketed']:10.0f}" if r["us_bucketed"] else "         -"
        sp = f"{r['speedup']:6.2f}x" if r["speedup"] else "      -"
        print(f"{r['size']:7s} {r['operator']:8s} {r['path']:10s} "
              f"perleaf{pl}us bucketed{bk}us {sp}")
    print(f"wrote {path} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
