"""Figure 13 + Theorem 1 reproduction: sparsity of quantized gradients.

Tracks E||qhat||_0 of the transmitted vectors over logistic-regression
training for DIANA / QSGD / TernGrad and checks the Theorem-1 identity
``E||qhat||_0 = ||Delta||_1 / ||Delta||_p`` along the trajectory.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.diana_paper import LogRegProblem
from repro.core import CompressionConfig, expected_sparsity, reference_init, reference_step
from repro.core.compression import compress_tree
from repro.data import logreg_data


def run():
    prob = LogRegProblem(n_workers=10)
    X, y = jnp.asarray(logreg_data(prob)[0]), jnp.asarray(logreg_data(prob)[1])
    l2 = prob.l2

    def worker_grads(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wij,wi->wj", X, y * sig) / X.shape[1] + l2 * w

    rows = []
    for method, p in (("diana", math.inf), ("qsgd", 2.0), ("terngrad", math.inf)):
        cfg = CompressionConfig(method=method, p=p, block_size=28)
        params = {"x": jnp.zeros((prob.dim,))}
        state = reference_init(params, cfg, prob.n_workers)
        key = jax.random.PRNGKey(0)
        nnz_traj, theory_err = [], []
        for k in range(300):
            key = jax.random.fold_in(key, k)
            g = {"x": worker_grads(params["x"])}
            if k % 50 == 0:
                # measure worker 0's transmitted vector
                base = state.h_worker["x"][0] if cfg.uses_memory else 0.0
                delta = g["x"][0].reshape(-1) - base
                _, qt = compress_tree({"d": delta}, jax.random.fold_in(key, 0), cfg)
                nnz = int((qt["d"].signs != 0).sum())
                theo = float(expected_sparsity(delta, cfg.effective_p(), cfg.block_size))
                nnz_traj.append(nnz)
                theory_err.append(abs(nnz - theo) / max(theo, 1))
            v, state = reference_step(g, state, key, cfg)
            params = {"x": params["x"] - 0.5 * v["x"]}
        rows.append({
            "name": f"fig13_sparsity/{method}",
            "us_per_call": 0.0,
            "derived": f"nnz_traj={nnz_traj} dim={prob.dim}",
        })
        rows.append({
            "name": f"fig13_sparsity/{method}_thm1_relerr",
            "us_per_call": 0.0,
            "derived": f"{np.mean(theory_err):.3f}",
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
