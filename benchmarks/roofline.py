"""Roofline analysis — deliverable (g), plus the KERNEL roofline (ISSUE 6).

Two independent sections:

1. **Model roofline** (``run()``): reads the dry-run artifacts
   (experiments/dryrun/*/*.json, produced by ``python -m repro.launch.dryrun``),
   computes the three roofline terms per (arch x shape x mesh), adds
   MODEL_FLOPS = 6*N_active*D and the useful-compute ratio, identifies the
   dominant bottleneck, and emits both a CSV and the markdown table
   EXPERIMENTS.md §Roofline embeds.

2. **Kernel roofline** (``kernel_rows()`` / ``python -m benchmarks.roofline``):
   the compression kernels are pure data movement (a handful of VPU ops per
   element), so their ceiling is MEMORY BANDWIDTH, not flops.  The peak is
   MEASURED, not quoted: one jitted read+write stream over a large buffer
   (:func:`measure_peak_bandwidth`, memoized per process so
   ``bench_step_time`` can reuse the same number for its
   ``fraction_of_roofline`` columns).  Each kernel row reports analytic bytes
   moved / median wall time / fraction of that measured peak, and the emitted
   ``BENCH_roofline.json`` records whether the kernels ran compiled or under
   ``interpret=True`` (CPU CI: fractions are then a correctness-weighted
   smoke trace of the SAME harness that reports real numbers on TPU, not a
   perf claim).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import functools
import glob
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ROOFLINE_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_roofline.json")

_IMPROVE_HINTS = {
    "compute_s": "raise arithmetic intensity (larger per-device batch, fuse elementwise chains)",
    "memory_s": "cut HBM traffic: flash/blocked attention, bf16 intermediates, better remat policy",
    "collective_s": "shrink payloads/hops: hierarchical DIANA workers, overlap all-gather with decode, FSDP prefetch",
}


def model_flops_per_step(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N_active*D tokens (train) / forward-only for serving."""
    from repro.configs import get_config, get_shape
    from repro.models import count_active_params, init_model

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    params = jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))
    n_active = count_active_params(cfg, params)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 token/seq


def load_rows(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            d = json.load(f)
        mesh_tag = os.path.basename(os.path.dirname(path))
        if d.get("status") != "ok":
            rows.append({"arch": d.get("arch"), "shape": d.get("shape"),
                         "mesh": mesh_tag, "status": d.get("status", "?"),
                         "note": (d.get("reason") or d.get("error", ""))[:90]})
            continue
        r = d["roofline"]
        n_chips = d["n_chips"]
        hlo_flops_total = d["per_device"]["hlo_flops"] * n_chips
        try:
            mf = model_flops_per_step(d["arch"], d["shape"])
            coverage = hlo_flops_total / mf if mf else float("nan")
        except Exception:
            mf, coverage = float("nan"), float("nan")
        # XLA CPU's cost_analysis does NOT scale flops by while-loop trip
        # counts (the scanned layer stack!), so the HLO compute term is a
        # floor; the analytic 6/2*N*D term is the honest compute estimate.
        compute_model_s = (mf / n_chips / PEAK_FLOPS) if mf == mf else 0.0
        compute_s = max(r["compute_s"], compute_model_s)
        terms = {"compute_s": compute_s, "memory_s": r["memory_s"],
                 "collective_s": r["collective_s"]}
        dom = max(terms, key=terms.get)
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": mesh_tag, "status": "ok",
            "compute_s": compute_s, "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": dom,
            "mem_gib": d["per_device"]["memory_bytes"] / 2**30,
            "fits_hbm": d["fits_hbm"],
            "model_flops": mf, "useful_ratio": coverage,
            "note": _IMPROVE_HINTS.get(dom, ""),
        })
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("compute = max(HLO flops, analytic 6/2·N_active·tokens) / peak — XLA CPU's\n"
           "cost_analysis does not scale while-loop (layer-scan) bodies by trip count.\n"
           "`HLO/model flops` = HLO_FLOPs·chips / MODEL_FLOPS (>1: remat/redundancy;\n"
           "<1: the loop-undercount).\n\n"
           "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | mem GiB | fits | HLO/model flops | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                       f"{r['status']} | — | — | — | {r['note']} |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']*1e3:.2f} "
            f"| {r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | {r['dominant'].replace('_s','')} "
            f"| {r['mem_gib']:.2f} | {'y' if r['fits_hbm'] else 'N'} "
            f"| {r['useful_ratio']:.2f} | {r['note']} |\n")
    return "".join(out)


def run():
    rows = load_rows()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write(to_markdown(rows))
    import csv

    keys = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "mem_gib", "fits_hbm", "model_flops",
            "useful_ratio", "note"]
    with open("experiments/roofline.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        w.writerows(rows)

    out = []
    for r in rows:
        if r["status"] != "ok":
            out.append({"name": f"roofline/{r['mesh']}/{r['arch']}_{r['shape']}",
                        "us_per_call": 0.0, "derived": r["status"]})
        else:
            out.append({
                "name": f"roofline/{r['mesh']}/{r['arch']}_{r['shape']}",
                "us_per_call": round(max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6, 1),
                "derived": f"dom={r['dominant']} useful={r['useful_ratio']:.2f} fits={r['fits_hbm']}",
            })
    if not out:
        out.append({"name": "roofline/NO_DRYRUN_ARTIFACTS", "us_per_call": 0.0,
                    "derived": "run python -m repro.launch.dryrun --all first"})
    return out


# ---------------------------------------------------------------------------
# Kernel roofline (ISSUE 6): measured peak bandwidth, per-kernel fractions
# ---------------------------------------------------------------------------

def _median_us(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


@functools.lru_cache(maxsize=None)
def measure_peak_bandwidth(nbytes: int = 1 << 26, reps: int = 9) -> float:
    """Measured streaming bandwidth in bytes/s: one jitted read+write pass
    over an ``nbytes`` f32 buffer (2 bytes of traffic per stored byte).
    Memoized so every caller in a process — this module's kernel rows AND
    ``bench_step_time``'s fraction columns — divides by the SAME peak."""
    x = jnp.zeros((nbytes // 4,), jnp.float32)
    stream = jax.jit(lambda a: a * jnp.float32(1.000001))
    us = _median_us(stream, (x,), reps)
    return 2.0 * nbytes / (us * 1e-6)


def _kernel_cases(n: int, d: int, block: int, k: int):
    """(name, fn, args, analytic bytes) per public kernel wrapper.

    Bytes are the ANALYTIC minimum HBM traffic (operands in + results out,
    f32/uint8/int16 wire widths as laid out) — the numerator of a roofline
    fraction is always the ideal, never the achieved traffic."""
    from repro.kernels import ops as kops

    key = jax.random.PRNGKey(0)
    m = d // block
    delta2 = jax.random.normal(key, (m, block), jnp.float32)
    bits2 = jax.random.bits(key, (m, block), dtype=jnp.uint32)
    packed = jnp.stack([kops.quantize_pack_op(delta2, bits2, p=2.0)[0]] * n)
    scales = jnp.abs(jax.random.normal(key, (n, m, 1), jnp.float32)) + 1.0
    x = jax.random.normal(key, (d,), jnp.float32)
    bits1 = jax.random.bits(key, (d,), dtype=jnp.uint32)
    codes = jnp.stack([kops.nat_pack_op(x, bits1)] * n)
    idx = jnp.stack([
        jax.lax.top_k(jax.random.bits(jax.random.fold_in(key, i), (d,),
                                      dtype=jnp.uint32), k)[1]
        for i in range(n)
    ])
    vals = jax.random.normal(key, (n, k), jnp.float32)
    scale = jnp.full((k,), jnp.float32(d / k))
    dense = jax.random.normal(key, (n, d), jnp.float32)
    h = jnp.zeros((d,), jnp.float32)

    f32, u8, i16, u32 = 4, 1, 2, 4
    return [
        ("quantize_pack", lambda: kops.quantize_pack_op(delta2, bits2, p=2.0),
         d * f32 + d * u32 + d // 4 * u8 + m * f32),
        ("unpack_reduce", lambda: kops.unpack_reduce_op(packed, scales),
         n * (d // 4 * u8 + m * f32) + d * f32),
        ("unpack_reduce_apply",
         lambda: kops.unpack_reduce_apply_op(packed, scales, h, alpha=0.5),
         n * (d // 4 * u8 + m * f32) + 3 * d * f32),
        ("nat_pack", lambda: kops.nat_pack_op(x, bits1),
         d * f32 + d * u32 + d * i16),
        ("nat_decode_sum", lambda: kops.nat_decode_sum_op(codes),
         n * d * i16 + d * f32),
        ("nat_decode_sum_apply",
         lambda: kops.nat_decode_sum_apply_op(codes, h, alpha=0.5),
         n * d * i16 + 3 * d * f32),
        ("sparse_gather", lambda: kops.sparse_gather_op(x, idx[0]),
         d * f32 + 2 * k * f32),
        ("sparse_decode_sum",
         lambda: kops.sparse_decode_sum_op(idx, vals, scale, d=d),
         n * 2 * k * f32 + d * f32),
        ("dense_decode_sum", lambda: kops.dense_decode_sum_op(dense),
         n * d * f32 + d * f32),
    ]


def kernel_rows(smoke: bool = False) -> List[Dict]:
    from repro.kernels import ops as kops

    n, block, k = 4, 128, 64
    d = 128 * 128 if not smoke else 32 * 128
    reps = 5 if smoke else 15
    peak = measure_peak_bandwidth()
    rows = []
    for name, fn, nbytes in _kernel_cases(n, d, block, k):
        us = _median_us(lambda: jax.block_until_ready(fn()), (), reps)
        gbs = nbytes / (us * 1e-6) / 1e9
        rows.append({
            "kernel": name,
            "n_workers": n, "d": d,
            "bytes": int(nbytes),
            "us": round(us, 2),
            "achieved_gbs": round(gbs, 4),
            "fraction_of_roofline": round(nbytes / (us * 1e-6) / peak, 6),
            "interpret": kops.default_interpret(),
        })
    return rows


def write_kernel_json(rows: List[Dict], path: str = ROOFLINE_OUT) -> str:
    doc = {
        "bench": "roofline",
        "backend": jax.default_backend(),
        "interpret": bool(rows and rows[0]["interpret"]),
        "peak_gbs_measured": round(measure_peak_bandwidth() / 1e9, 3),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(
        description="Kernel roofline: measured peak bandwidth + per-kernel "
                    "fraction-of-roofline rows -> BENCH_roofline.json")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller buffers / fewer reps (CI)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: the committed repo-root "
                         "file for full runs, a temp-dir scratch file for "
                         "--smoke so the trajectory artifact is never "
                         "clobbered or shadowed by a sibling)")
    args = ap.parse_args(argv)
    rows = kernel_rows(smoke=args.smoke)
    from benchmarks.bench_step_time import smoke_out_path

    out = args.out or (ROOFLINE_OUT if not args.smoke
                       else smoke_out_path(ROOFLINE_OUT))
    path = write_kernel_json(rows, out)
    peak = measure_peak_bandwidth() / 1e9
    print(f"measured peak bandwidth: {peak:.1f} GB/s "
          f"(interpret={rows[0]['interpret']})")
    for r in rows:
        print(f"{r['kernel']:22s} {r['us']:10.1f}us {r['achieved_gbs']:9.3f} GB/s "
              f"fraction {r['fraction_of_roofline']:.4f}")
    print(f"wrote {path} ({len(rows)} rows)")


if __name__ == "__main__":
    import sys as _sys

    if len(_sys.argv) > 1 or not glob.glob("experiments/dryrun/*/*.json"):
        main()
    else:
        for r in run():
            print(r)
