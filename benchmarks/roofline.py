"""Roofline analysis — deliverable (g).

Reads the dry-run artifacts (experiments/dryrun/*/*.json, produced by
``python -m repro.launch.dryrun``), computes the three roofline terms per
(arch x shape x mesh), adds MODEL_FLOPS = 6*N_active*D and the useful-compute
ratio, identifies the dominant bottleneck, and emits both a CSV and the
markdown table EXPERIMENTS.md §Roofline embeds.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_IMPROVE_HINTS = {
    "compute_s": "raise arithmetic intensity (larger per-device batch, fuse elementwise chains)",
    "memory_s": "cut HBM traffic: flash/blocked attention, bf16 intermediates, better remat policy",
    "collective_s": "shrink payloads/hops: hierarchical DIANA workers, overlap all-gather with decode, FSDP prefetch",
}


def model_flops_per_step(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N_active*D tokens (train) / forward-only for serving."""
    from repro.configs import get_config, get_shape
    from repro.models import count_active_params, init_model

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    params = jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))
    n_active = count_active_params(cfg, params)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 token/seq


def load_rows(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            d = json.load(f)
        mesh_tag = os.path.basename(os.path.dirname(path))
        if d.get("status") != "ok":
            rows.append({"arch": d.get("arch"), "shape": d.get("shape"),
                         "mesh": mesh_tag, "status": d.get("status", "?"),
                         "note": (d.get("reason") or d.get("error", ""))[:90]})
            continue
        r = d["roofline"]
        n_chips = d["n_chips"]
        hlo_flops_total = d["per_device"]["hlo_flops"] * n_chips
        try:
            mf = model_flops_per_step(d["arch"], d["shape"])
            coverage = hlo_flops_total / mf if mf else float("nan")
        except Exception:
            mf, coverage = float("nan"), float("nan")
        # XLA CPU's cost_analysis does NOT scale flops by while-loop trip
        # counts (the scanned layer stack!), so the HLO compute term is a
        # floor; the analytic 6/2*N*D term is the honest compute estimate.
        compute_model_s = (mf / n_chips / PEAK_FLOPS) if mf == mf else 0.0
        compute_s = max(r["compute_s"], compute_model_s)
        terms = {"compute_s": compute_s, "memory_s": r["memory_s"],
                 "collective_s": r["collective_s"]}
        dom = max(terms, key=terms.get)
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": mesh_tag, "status": "ok",
            "compute_s": compute_s, "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": dom,
            "mem_gib": d["per_device"]["memory_bytes"] / 2**30,
            "fits_hbm": d["fits_hbm"],
            "model_flops": mf, "useful_ratio": coverage,
            "note": _IMPROVE_HINTS.get(dom, ""),
        })
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("compute = max(HLO flops, analytic 6/2·N_active·tokens) / peak — XLA CPU's\n"
           "cost_analysis does not scale while-loop (layer-scan) bodies by trip count.\n"
           "`HLO/model flops` = HLO_FLOPs·chips / MODEL_FLOPS (>1: remat/redundancy;\n"
           "<1: the loop-undercount).\n\n"
           "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms) "
           "| dominant | mem GiB | fits | HLO/model flops | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                       f"{r['status']} | — | — | — | {r['note']} |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']*1e3:.2f} "
            f"| {r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | {r['dominant'].replace('_s','')} "
            f"| {r['mem_gib']:.2f} | {'y' if r['fits_hbm'] else 'N'} "
            f"| {r['useful_ratio']:.2f} | {r['note']} |\n")
    return "".join(out)


def run():
    rows = load_rows()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write(to_markdown(rows))
    import csv

    keys = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "mem_gib", "fits_hbm", "model_flops",
            "useful_ratio", "note"]
    with open("experiments/roofline.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
        w.writeheader()
        w.writerows(rows)

    out = []
    for r in rows:
        if r["status"] != "ok":
            out.append({"name": f"roofline/{r['mesh']}/{r['arch']}_{r['shape']}",
                        "us_per_call": 0.0, "derived": r["status"]})
        else:
            out.append({
                "name": f"roofline/{r['mesh']}/{r['arch']}_{r['shape']}",
                "us_per_call": round(max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6, 1),
                "derived": f"dom={r['dominant']} useful={r['useful_ratio']:.2f} fits={r['fits_hbm']}",
            })
    if not out:
        out.append({"name": "roofline/NO_DRYRUN_ARTIFACTS", "us_per_call": 0.0,
                    "derived": "run python -m repro.launch.dryrun --all first"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
