"""Figure 1 / Figure 3 reproduction: DIANA (momentum 0.95) vs QSGD, TernGrad,
DQGD and uncompressed SGD on regularized logistic regression.

Paper claim validated: DIANA reaches a (much) lower objective gap than the
memory-less compressors at equal step budget, approaching uncompressed SGD.
"""

from __future__ import annotations

import math

from .common import fstar_logreg, run_logreg

STEPS = 800
GAMMA = 1.0
BLOCK = 28   # ~paper's optimal l2 bucket (~25) rounded to a multiple of 4


def run():
    fstar = fstar_logreg()
    rows = []
    settings = [
        ("sgd_fp32", "none", 2.0, 0.0),
        ("diana_linf_m095", "diana", math.inf, 0.95),
        ("diana_l2", "diana", 2.0, 0.0),
        ("qsgd_l2", "qsgd", 2.0, 0.0),
        ("terngrad_linf", "terngrad", math.inf, 0.0),
        ("dqgd_l2", "dqgd", 2.0, 0.0),
    ]
    gaps = {}
    for name, method, p, beta in settings:
        res = run_logreg(method, p, steps=STEPS, gamma=GAMMA if beta == 0 else GAMMA * (1 - beta),
                         block=BLOCK, beta=beta)
        gap = max(res["final_loss"] - fstar, 1e-12)
        gaps[name] = gap
        rows.append({
            "name": f"fig1_convergence/{name}",
            "us_per_call": round(res["us_per_step"], 1),
            "derived": f"gap={gap:.3e}",
        })
    # headline check rows
    rows.append({
        "name": "fig1_convergence/CLAIM_diana_beats_qsgd",
        "us_per_call": 0.0,
        "derived": str(gaps["diana_l2"] < gaps["qsgd_l2"]),
    })
    rows.append({
        "name": "fig1_convergence/CLAIM_diana_beats_terngrad",
        "us_per_call": 0.0,
        "derived": str(gaps["diana_linf_m095"] < gaps["terngrad_linf"]),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
