"""Stochastic-regime comparison (arXiv:1904.05115, Fig. 1 analogue):
VR-DIANA's L-SVRG control variates restore LINEAR convergence to the exact
optimum with single-sample gradients, while plain DIANA and memoryless QSGD
stall at their variance floors.

Paper claims validated:
  * VR-DIANA's final gap is orders of magnitude below DIANA's at an equal
    step budget (>= 10x asserted as a CLAIM row, mirrored as a tier-1 test
    in tests/test_convergence_laws.py);
  * DIANA's stochastic gap is a FLOOR: it stops improving between half and
    full budget, where VR-DIANA keeps contracting.
"""

from __future__ import annotations

import math

from .common import fstar_logreg, run_logreg_stochastic, stoch_problem

STEPS = 600
GAMMA = 0.5
BLOCK = 8
GAP_FLOOR = 1e-7


def _gap_at(losses, step, fstar):
    """Objective gap at the recorded step nearest to ``step``."""
    t, loss = min(losses, key=lambda tl: abs(tl[0] - step))
    return max(loss - fstar, GAP_FLOOR)


def run():
    prob = stoch_problem()
    fstar = fstar_logreg(prob, 400)

    settings = [
        ("vr_diana_linf", "diana", math.inf, True),
        ("diana_linf", "diana", math.inf, False),
        ("qsgd_l2", "qsgd", 2.0, False),
    ]
    rows, gaps, half_gaps = [], {}, {}
    for name, method, p, vr in settings:
        res = run_logreg_stochastic(method, p, steps=STEPS, gamma=GAMMA,
                                    block=BLOCK, vr=vr, problem=prob)
        gaps[name] = _gap_at(res["losses"], STEPS, fstar)
        half_gaps[name] = _gap_at(res["losses"], STEPS // 2, fstar)
        rows.append({
            "name": f"vr_stochastic/{name}",
            "us_per_call": round(res["us_per_step"], 1),
            "derived": f"gap={gaps[name]:.3e};gap_half={half_gaps[name]:.3e}",
        })

    rows.append({
        "name": "vr_stochastic/CLAIM_vr_beats_diana_floor_10x",
        "us_per_call": 0.0,
        "derived": str(gaps["diana_linf"] >= 10.0 * gaps["vr_diana_linf"]),
    })
    rows.append({
        "name": "vr_stochastic/CLAIM_diana_gap_is_a_floor",
        "us_per_call": 0.0,
        # no order-of-magnitude progress in the second half of the budget
        "derived": str(gaps["diana_linf"] > 0.1 * half_gaps["diana_linf"]),
    })
    rows.append({
        "name": "vr_stochastic/CLAIM_qsgd_stalls_above",
        "us_per_call": 0.0,
        "derived": str(gaps["qsgd_l2"] >= 10.0 * gaps["vr_diana_linf"]),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
