"""Shared harness for the paper-reproduction benchmarks.

Each ``bench_*`` module exposes ``run() -> list[dict]`` with at least
``name``, ``us_per_call`` (wall-clock of the measured inner op, microseconds)
and ``derived`` (the paper-relevant quantity).  ``benchmarks.run`` aggregates
everything into the required ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import math
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig, reference_init, reference_step


def timed(fn: Callable, *args, reps: int = 3) -> float:
    """Median wall time of fn(*args) in microseconds (post-warmup)."""
    fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, (jax.Array, tuple, list, dict)
        ) else None
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def run_logreg(method: str, p: float, *, steps: int, gamma: float, block: int,
               beta: float = 0.0, alpha=None, k: int = 64, l1=0.0,
               n_workers: int = 10, seed: int = 0, problem=None):
    """Distributed (reference-simulated) regularized logistic regression.

    Returns dict with loss trajectory, final distance to x*, sparsity stats.
    """
    from repro.configs.diana_paper import LogRegProblem
    from repro.core.prox import l1 as l1_reg, none as no_reg
    from repro.data import logreg_data

    prob = problem or LogRegProblem(n_workers=n_workers, seed=seed)
    X, y = logreg_data(prob)
    X, y = jnp.asarray(X), jnp.asarray(y)
    l2 = prob.l2
    reg = l1_reg(l1) if l1 > 0 else no_reg()

    def worker_grads(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wij,wi->wj", X, y * sig) / X.shape[1] + l2 * w

    def full_loss(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        return float(jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * l2 * w @ w
                     + reg.tree_value({"w": w}))

    cfg = CompressionConfig(method=method, p=p, block_size=block, alpha=alpha, k=k)
    params = {"x": jnp.zeros((prob.dim,))}
    state = reference_init(params, cfg, prob.n_workers)
    key = jax.random.PRNGKey(seed)
    losses = []
    t0 = time.perf_counter()
    for k in range(steps):
        key = jax.random.fold_in(key, k)
        g = {"x": worker_grads(params["x"])}
        v, state = reference_step(g, state, key, cfg, beta=beta)
        params = reg.tree_prox({"x": params["x"] - gamma * v["x"]}, gamma)
        if k % max(1, steps // 50) == 0 or k == steps - 1:
            losses.append((k, full_loss(params["x"])))
    wall = (time.perf_counter() - t0) / steps * 1e6
    return {"losses": losses, "final_loss": losses[-1][1], "x": params["x"],
            "us_per_step": wall, "cfg": cfg}


def fstar_logreg(problem=None, steps: int = 4000, l1: float = 0.0):
    """High-accuracy reference optimum via uncompressed full-gradient descent."""
    res = run_logreg("none", 2.0, steps=steps, gamma=2.0, block=64, l1=l1, problem=problem)
    return res["final_loss"]
