"""Shared harness for the paper-reproduction benchmarks.

Each ``bench_*`` module exposes ``run() -> list[dict]`` with at least
``name``, ``us_per_call`` (wall-clock of the measured inner op, microseconds)
and ``derived`` (the paper-relevant quantity).  ``benchmarks.run`` aggregates
everything into the required ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import functools
import math
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig, reference_init, reference_step, resolve_vr_p


def timed(fn: Callable, *args, reps: int = 3) -> float:
    """Median wall time of fn(*args) in microseconds (post-warmup)."""
    fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, (jax.Array, tuple, list, dict)
        ) else None
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def run_logreg(method: str, p: float, *, steps: int, gamma: float, block: int,
               beta: float = 0.0, alpha=None, k: int = 64, l1=0.0,
               n_workers: int = 10, seed: int = 0, problem=None,
               down_method=None, down_k=None):
    """Distributed (reference-simulated) regularized logistic regression.

    ``down_method`` compresses the server broadcast too (bidirectional
    DIANA, DESIGN.md §Bidirectional).  Returns dict with loss trajectory,
    final distance to x*, sparsity stats.
    """
    from repro.configs.diana_paper import LogRegProblem
    from repro.core.prox import l1 as l1_reg, none as no_reg
    from repro.data import logreg_data

    prob = problem or LogRegProblem(n_workers=n_workers, seed=seed)
    X, y = logreg_data(prob)
    X, y = jnp.asarray(X), jnp.asarray(y)
    l2 = prob.l2
    reg = l1_reg(l1) if l1 > 0 else no_reg()

    def worker_grads(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wij,wi->wj", X, y * sig) / X.shape[1] + l2 * w

    def full_loss(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        return float(jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * l2 * w @ w
                     + reg.tree_value({"w": w}))

    cfg = CompressionConfig(method=method, p=p, block_size=block, alpha=alpha,
                            k=k, down_method=down_method, down_k=down_k)
    params = {"x": jnp.zeros((prob.dim,))}
    state = reference_init(params, cfg, prob.n_workers)
    key = jax.random.PRNGKey(seed)
    losses = []
    t0 = time.perf_counter()
    for k in range(steps):
        key = jax.random.fold_in(key, k)
        g = {"x": worker_grads(params["x"])}
        v, state = reference_step(g, state, key, cfg, beta=beta)
        params = reg.tree_prox({"x": params["x"] - gamma * v["x"]}, gamma)
        if k % max(1, steps // 50) == 0 or k == steps - 1:
            losses.append((k, full_loss(params["x"])))
    wall = (time.perf_counter() - t0) / steps * 1e6
    return {"losses": losses, "final_loss": losses[-1][1], "x": params["x"],
            "us_per_step": wall, "cfg": cfg}


@functools.lru_cache(maxsize=None)
def fstar_logreg(problem=None, steps: int = 4000, l1: float = 0.0):
    """High-accuracy reference optimum via uncompressed full-gradient descent.

    Cached per ``(problem, steps, l1)`` (``LogRegProblem`` is frozen, hence
    hashable): every benchmark module used to re-derive f* on each ``run()``,
    so a full ``benchmarks.run`` sweep paid the 4000-step solve several times
    over — now it is solved once per problem and shared across
    bench_convergence / bench_norm_power / bench_blocksize / bench_vr and the
    convergence-law tests.
    """
    res = run_logreg("none", 2.0, steps=steps, gamma=2.0, block=64, l1=l1, problem=problem)
    return res["final_loss"]


# ---------------------------------------------------------------------------
# Stochastic finite-sum regime (VR-DIANA vs DIANA/QSGD — arXiv:1904.05115)
# ---------------------------------------------------------------------------

def stoch_problem(dim: int = 24, n_workers: int = 4, m_per_worker: int = 32,
                  l2: float = 0.1, seed: int = 3):
    """The seeded strongly-convex fixture of the stochastic-regime runs: small
    enough that a few hundred eager reference steps finish in seconds, convex
    enough (l2 ~ L/3) that the rate laws separate cleanly."""
    from repro.configs.diana_paper import LogRegProblem

    return LogRegProblem(name=f"stoch-{dim}d", n_samples=n_workers * m_per_worker,
                         dim=dim, n_workers=n_workers, l2=l2, seed=seed)


_SAMPLE_FOLD = 0x534A  # 'SJ': the per-step minibatch draw, distinct from every
                       # compression / VR fold so schedules never collide


def run_logreg_stochastic(method: str, p: float = math.inf, *, steps: int,
                          gamma: float, block: int = 8, batch: int = 1,
                          vr: bool = False, vr_p: Optional[float] = None,
                          alpha=None, k: int = 8, beta: float = 0.0,
                          seed: int = 0, problem=None, record_every: int = 25):
    """Single-sample (finite-sum) stochastic logistic regression through the
    reference DIANA/VR-DIANA aggregation.

    Every worker holds ``m`` samples; each step it samples a size-``batch``
    minibatch (shared draw schedule across methods: comparisons at equal
    step budget see the same data order) and feeds its stochastic gradient —
    control-variated against the L-SVRG (snapshot, mu) state when
    ``vr=True`` — through :func:`repro.core.diana.reference_step`.  VR runs
    exact L-SVRG semantics: ``mu^0`` is the true local full gradient at
    ``x^0`` and every refresh recomputes it at the current iterate
    (``O(m d)`` — trivial at fixture scale).  ``vr_p=None`` resolves to the
    paper's ``1/m``.

    Returns losses trajectory, final full loss, per-step wall time and cfg.
    """
    from repro.data import logreg_data

    prob = problem or stoch_problem()
    X, y = logreg_data(prob)
    X, y = jnp.asarray(X), jnp.asarray(y)
    w_, m, d = X.shape
    l2 = prob.l2

    cfg = CompressionConfig(
        method=method, p=p, block_size=block, alpha=alpha, k=k,
        vr=vr, vr_p=resolve_vr_p(vr_p, m) if vr else None,
    )

    def full_grads(xmat):
        """Per-worker full local gradients at per-worker points (w, d)."""
        z = y * jnp.einsum("wij,wj->wi", X, xmat)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wij,wi->wj", X, y * sig) / m + l2 * xmat

    def sampled_grads(xmat, idx):
        """Per-worker minibatch gradients at per-worker points.

        xmat (w, d); idx (w, batch) sample indices into each worker's shard.
        """
        Xb = jnp.take_along_axis(X, idx[..., None], axis=1)      # (w, b, d)
        yb = jnp.take_along_axis(y, idx, axis=1)                 # (w, b)
        z = yb * jnp.einsum("wbj,wj->wb", Xb, xmat)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wbj,wb->wj", Xb, yb * sig) / idx.shape[1] + l2 * xmat

    def full_loss(xv):
        z = y * jnp.einsum("wij,j->wi", X, xv)
        return float(jnp.mean(jnp.log1p(jnp.exp(-z))) + 0.5 * l2 * xv @ xv)

    params = {"x": jnp.zeros((d,))}
    state = reference_init(params, cfg, w_)
    if vr:
        x0 = jnp.broadcast_to(params["x"], (w_, d))
        state = state._replace(vr=state.vr._replace(mu={"x": full_grads(x0)}))

    # One jitted step: unlike the eager convex experiments (one reference_step
    # per paper figure point), the stochastic regime runs hundreds of tiny
    # steps — dispatch overhead would dominate, and the compiled program is
    # identical math (reference_step's unrolled loops trace once).
    @jax.jit
    def step(params, state, kt):
        idx = jax.random.randint(
            jax.random.fold_in(kt, _SAMPLE_FOLD), (w_, batch), 0, m)
        xb = jnp.broadcast_to(params["x"], (w_, d))
        g = {"x": sampled_grads(xb, idx)}
        if vr:
            g_snap = {"x": sampled_grads(state.vr.snapshot["x"], idx)}
            mu_cand = {"x": full_grads(xb)}
            v, state = reference_step(g, state, kt, cfg, beta=beta,
                                      vr_aux=(g_snap, mu_cand), params=params)
        else:
            v, state = reference_step(g, state, kt, cfg, beta=beta)
        return {"x": params["x"] - gamma * v["x"]}, state

    key = jax.random.PRNGKey(seed)
    # warm-up: compile outside the timed region (step is pure; the discarded
    # call does not advance the trajectory), so us_per_step is step time, not
    # amortized XLA compile time
    jax.block_until_ready(step(params, state, jax.random.fold_in(key, 0)))
    losses = []
    t0 = time.perf_counter()
    for t in range(steps):
        params, state = step(params, state, jax.random.fold_in(key, t))
        if t % record_every == 0 or t == steps - 1:
            losses.append((t, full_loss(params["x"])))
    wall = (time.perf_counter() - t0) / steps * 1e6
    return {"losses": losses, "final_loss": losses[-1][1], "x": params["x"],
            "us_per_step": wall, "cfg": cfg}
