"""Table 3 / Corollary 1 reproduction: iteration complexity vs norm power p.

Empirical: steps to reach ||x - x*|| <= eps on the strongly convex quadratic,
for p in {1, 2, inf}.  Theory: complexity is DECREASING in p (p = inf optimal),
with leading term max{2/alpha_p, (kappa+1)(1/2 - 1/n + 1/(n alpha_p))}.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig, alpha_p, reference_init, reference_step

from .common import timed

D, N_WORKERS, BLOCK = 64, 10, 16
EPS = 1e-3


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    As = rng.standard_normal((N_WORKERS, D, D)) / math.sqrt(D) + np.eye(D) * 0.8
    bs = rng.standard_normal((N_WORKERS, D))
    x_star = np.linalg.lstsq(np.concatenate(As), np.concatenate(bs), rcond=None)[0]
    As, bs = jnp.asarray(As), jnp.asarray(bs)

    def grads(x):
        r = jnp.einsum("wij,j->wi", As, x) - bs
        return jnp.einsum("wji,wj->wi", As, r)

    return grads, jnp.asarray(x_star)


def steps_to_eps(p: float, gamma: float = 0.25, max_steps: int = 3000) -> int:
    grads, x_star = _problem()
    cfg = CompressionConfig(method="diana", p=p, block_size=BLOCK)
    params = {"x": jnp.zeros((D,))}
    state = reference_init(params, cfg, N_WORKERS)
    key = jax.random.PRNGKey(0)
    for k in range(max_steps):
        key = jax.random.fold_in(key, k)
        v, state = reference_step({"x": grads(params["x"])}, state, key, cfg)
        params = {"x": params["x"] - gamma * v["x"]}
        if float(jnp.linalg.norm(params["x"] - x_star)) < EPS:
            return k + 1
    return max_steps


def theory_leading_term(p: float, kappa: float = 10.0, n: int = N_WORKERS) -> float:
    ap = alpha_p(p, BLOCK)
    return max(2 / ap, (kappa + 1) * (0.5 - 1 / n + 1 / (n * ap)))


def run():
    rows = []
    emp = {}
    for p in (1.0, 2.0, math.inf):
        pname = {1.0: "p1", 2.0: "p2", math.inf: "pinf"}[p]
        k = steps_to_eps(p)
        emp[p] = k
        rows.append({
            "name": f"tab3_norm_power/{pname}",
            "us_per_call": 0.0,
            "derived": f"steps_to_eps={k} theory_term={theory_leading_term(p):.1f}",
        })
    rows.append({
        "name": "tab3_norm_power/CLAIM_decreasing_in_p",
        "us_per_call": 0.0,
        "derived": str(emp[1.0] >= emp[2.0] >= emp[math.inf]),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
