"""Theorem 3 / Corollary 2 reproduction: decreasing stepsizes give O(1/k)
convergence of E V^k to the EXACT optimum even with gradient noise.

We run DIANA with gamma_k = 2/(mu k + theta) on the strongly convex quadratic
with injected gradient noise and check (a) the error keeps decreasing (no
noise floor) and (b) the empirical rate is ~1/k (log-log slope in [-1.6, -0.5]).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig, alpha_p, reference_init, reference_step

D, N, BLOCK, SIGMA = 32, 8, 16, 0.3


def run():
    rng = np.random.default_rng(0)
    As = rng.standard_normal((N, D, D)) / math.sqrt(D) + np.eye(D)
    bs = rng.standard_normal((N, D))
    x_star = np.linalg.lstsq(np.concatenate(As), np.concatenate(bs), rcond=None)[0]
    As_j, bs_j = jnp.asarray(As), jnp.asarray(bs)

    mu = float(min(np.linalg.eigvalsh(sum(a.T @ a for a in As) / N)))
    ap = alpha_p(math.inf, BLOCK)
    theta = 2 * mu / ap * 4          # ~ paper's theta scale

    cfg = CompressionConfig(method="diana", p=math.inf, block_size=BLOCK)
    params = {"x": jnp.zeros((D,))}
    state = reference_init(params, cfg, N)
    key = jax.random.PRNGKey(0)
    errs = []
    steps = 3000
    for k in range(steps):
        key = jax.random.fold_in(key, k)
        nkey, key2 = jax.random.split(key)
        r = jnp.einsum("wij,j->wi", As_j, params["x"]) - bs_j
        g = jnp.einsum("wji,wj->wi", As_j, r)
        g = g + SIGMA * jax.random.normal(nkey, g.shape)
        gamma = 2.0 / (mu * k + theta)
        v, state = reference_step({"x": g}, state, key2, cfg)
        params = {"x": params["x"] - gamma * v["x"]}
        if k in (100, 300, 1000, 2999):
            errs.append((k, float(jnp.linalg.norm(params["x"] - x_star) ** 2)))

    ks = np.array([e[0] for e in errs], float)
    vs = np.array([e[1] for e in errs], float)
    slope = np.polyfit(np.log(ks), np.log(vs), 1)[0]
    rows = [{
        "name": "thm3_decreasing_step/errors",
        "us_per_call": 0.0,
        "derived": " ".join(f"k={k}:{v:.2e}" for k, v in errs),
    }, {
        "name": "thm3_decreasing_step/CLAIM_O(1/k)",
        "us_per_call": 0.0,
        "derived": f"loglog_slope={slope:.2f} in [-1.8,-0.4]={-1.8 <= slope <= -0.4}",
    }]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
