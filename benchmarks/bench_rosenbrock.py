"""Section M.1 reproduction: DIANA vs QSGD vs TernGrad on the distributed
Rosenbrock decomposition (2 workers, deterministic gradients, 1-bit regime).

Paper claim: DIANA vastly outperforms the memory-less methods.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.diana_paper import ROSENBROCK
from repro.core import CompressionConfig, reference_init, reference_step


def run():
    f1, f2 = ROSENBROCK["f1"], ROSENBROCK["f2"]
    opt = jnp.asarray(ROSENBROCK["optimum"])

    g1 = jax.grad(lambda v: f1(v[0], v[1]))
    g2 = jax.grad(lambda v: f2(v[0], v[1]))

    rows, finals = [], {}
    for method, p, beta, gamma in (
        ("diana", math.inf, 0.9, 2e-3),
        ("qsgd", 2.0, 0.0, 2e-3),
        ("terngrad", math.inf, 0.0, 2e-3),
        ("none", 2.0, 0.9, 2e-3),
    ):
        cfg = CompressionConfig(method=method, p=p, block_size=4, alpha=0.5 if method == "diana" else None)
        params = {"v": jnp.asarray([-0.5, 0.5])}
        # pad to 4 dims for packing alignment (extra coords have zero gradient)
        params = {"v": jnp.concatenate([params["v"], jnp.zeros(2)])}
        state = reference_init(params, cfg, 2)
        key = jax.random.PRNGKey(0)
        for k in range(4000):
            key = jax.random.fold_in(key, k)
            v2 = params["v"][:2]
            grads = jnp.stack([
                jnp.concatenate([g1(v2), jnp.zeros(2)]),
                jnp.concatenate([g2(v2), jnp.zeros(2)]),
            ])
            v, state = reference_step({"v": grads}, state, key, cfg, beta=beta)
            params = {"v": params["v"] - gamma * v["v"]}
        dist = float(jnp.linalg.norm(params["v"][:2] - opt))
        finals[method] = dist
        rows.append({
            "name": f"rosenbrock/{method}",
            "us_per_call": 0.0,
            "derived": f"dist_to_opt={dist:.4f}",
        })
    rows.append({
        "name": "rosenbrock/CLAIM_diana_beats_memoryless",
        "us_per_call": 0.0,
        "derived": str(finals["diana"] < finals["qsgd"] and finals["diana"] < finals["terngrad"]),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
