"""Lemma 2 validation + kernel micro-bench.

(a) Empirical quantization variance vs the closed form
    ``Psi = sum_l ||x(l)||_1 ||x(l)||_p - ||x(l)||_2^2`` for p in {1, 2, inf}.
(b) Microseconds/call of the fused Pallas quantize+pack kernel (interpret
    mode on CPU — correctness path; Mosaic path on real TPUs) vs the jnp
    reference, at DIANA's production block geometry.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization_variance, quantize_blocks, dequantize_blocks
from repro.kernels import quantize_pack
from repro.kernels.ref import ref_quantize_pack

from .common import timed


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096,))
    n = 2000
    ks = jax.random.split(jax.random.PRNGKey(1), n)
    for p, pname in ((1.0, "p1"), (2.0, "p2"), (math.inf, "pinf")):
        f = jax.jit(jax.vmap(lambda k: dequantize_blocks(
            quantize_blocks(x, k, p=p, block_size=512), shape=(4096,))))
        samp = np.asarray(f(ks))
        emp = float(((samp - np.asarray(x)) ** 2).sum(-1).mean())
        theo = float(quantization_variance(x, p, 512))
        rows.append({
            "name": f"lem2_variance/{pname}",
            "us_per_call": 0.0,
            "derived": f"emp={emp:.1f} theo={theo:.1f} relerr={abs(emp-theo)/theo:.3f}",
        })

    # kernel micro-bench (m=512 blocks x 2048 lanes = 1M dims / call)
    delta = jax.random.normal(key, (512, 2048))
    bits = jax.random.bits(key, (512, 2048), dtype=jnp.uint32)
    t_kernel = timed(lambda: quantize_pack(delta, bits, p=math.inf, interpret=True))
    ref_j = jax.jit(lambda d, b: ref_quantize_pack(d, b, math.inf))
    t_ref = timed(lambda: ref_j(delta, bits))
    rows.append({
        "name": "kernel/quantize_pack_1M_interpret",
        "us_per_call": round(t_kernel, 1),
        "derived": f"ref_jnp_us={t_ref:.1f} (interpret-mode CPU; TPU path is Mosaic)",
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
