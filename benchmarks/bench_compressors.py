"""Compressor trade-off sweep: bits/dim vs suboptimality on logreg.

Extends the paper's Fig. 1 trade-off curve to every operator in the registry
(ternary-DIANA, natural, rand-k, top-k-EF, identity): each runs the same
step budget on the regularized logistic-regression problem through
``reference_step``, and the row reports the wire cost per coordinate next to
the achieved objective gap — the frontier DIANA's modular-compressor story
is about (unbiased + memory => the gap collapses at any bits/dim; the biased
EF operator trades a small floor for determinism).
"""

from __future__ import annotations

import math

from repro.configs.diana_paper import LogRegProblem
from repro.core.compression import CompressionConfig, payload_bits_per_dim

from .common import fstar_logreg, run_logreg

STEPS = 1000
GAMMA = 2.0
BLOCK = 28
PROBLEM = LogRegProblem(n_workers=4)

# name, method, p, extra kwargs for run_logreg
SETTINGS = [
    ("identity_fp32", "none", 2.0, {}),
    ("ternary_diana_linf", "diana", math.inf, {}),
    ("ternary_qsgd_l2", "qsgd", 2.0, {}),
    ("natural_9bit", "natural", math.inf, {}),
    ("randk_k28", "randk", math.inf, {"k": 28}),
    ("topk_ef_k28", "topk_ef", math.inf, {"k": 28}),
]


def run():
    fstar = fstar_logreg(problem=PROBLEM)
    d = PROBLEM.dim
    rows = []
    gaps = {}
    for name, method, p, kw in SETTINGS:
        res = run_logreg(method, p, steps=STEPS, gamma=GAMMA, block=BLOCK,
                         problem=PROBLEM, **kw)
        cfg = CompressionConfig(method=method, p=p, block_size=BLOCK,
                                k=kw.get("k", 64))
        bits = payload_bits_per_dim(cfg, d)
        gap = max(res["final_loss"] - fstar, 1e-12)
        gaps[name] = gap
        rows.append({
            "name": f"compressor_tradeoff/{name}",
            "us_per_call": round(res["us_per_step"], 1),
            "derived": f"bits_per_dim={bits:.2f} gap={gap:.3e}",
        })
    # headline rows: every unbiased operator matches the uncompressed gap
    for name in ("ternary_diana_linf", "natural_9bit", "randk_k28"):
        rows.append({
            "name": f"compressor_tradeoff/CLAIM_{name}_matches_fp32",
            "us_per_call": 0.0,
            "derived": str(gaps[name] < gaps["identity_fp32"] + 1e-3),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
