"""Quickstart: DIANA in 60 seconds on one CPU.

Builds a reduced llama3.2-1b, trains a few steps with compressed gradient
differences on a (data=ndev, model=1) mesh using the model's curated
per-parameter-group COMPRESSION POLICY (norms/biases exact, embeddings top-k
with error feedback, the dense bulk ternary — DESIGN.md §Policy), and prints
the losses plus the per-group operators and the size-weighted wire cost.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import partition_for, policy_bits_per_dim
from repro.data import make_lm_batch
from repro.launch.mesh import make_mesh
from repro.launch.sharding_rules import batch_specs
from repro.launch.train import build_train_step, init_train_state, make_optimizer
from repro.models import count_params


def main():
    cfg = reduced(get_config("llama3.2-1b"))
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train")
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))

    # policy="default" selects the model's curated ModelConfig.comp_policy;
    # omit it for the legacy flat single-operator config, or pass inline
    # rules / a policy .json (see README "Compression policies").
    opt = make_optimizer(cfg, lr=0.02, policy="default")
    key = jax.random.PRNGKey(0)
    params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
    step_fn = build_train_step(cfg, opt, mesh, shape)

    print(f"model: {cfg.name}  params: {count_params(params):,}")
    part = partition_for(opt.policy, params)
    groups = part.split(params)
    for g, gname in enumerate(part.group_names):
        comp = part.configs[g].make()
        n_par = sum(int(l.size) for l in groups[g])
        print(f"  group {gname}: {len(part.group_leaf_ids[g])} leaves, "
              f"{n_par:,} params -> {comp.name} "
              f"(unbiased={comp.unbiased}, memory={comp.carries_state})")
    print(f"policy wire cost: {policy_bits_per_dim(opt.policy, params):.2f} "
          f"bits/dim size-weighted (vs 32 uncompressed)")

    for step in range(10):
        hb = make_lm_batch(cfg, shape, step)
        bs = batch_specs(hb, mesh)
        batch = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), hb, bs)
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jax.random.fold_in(key, step))
        print(f"step {step}: loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
