"""Section M.1's illustration: 2 workers minimize the Rosenbrock function,
each holding one piece of the decomposition.  DIANA's memory lets the ternary
updates converge; QSGD/TernGrad wander.

Run:  PYTHONPATH=src python examples/rosenbrock.py
"""

import math

import jax
import jax.numpy as jnp

from repro.configs.diana_paper import ROSENBROCK
from repro.core import CompressionConfig, reference_init, reference_step


def main():
    f1, f2 = ROSENBROCK["f1"], ROSENBROCK["f2"]
    g1 = jax.grad(lambda v: f1(v[0], v[1]))
    g2 = jax.grad(lambda v: f2(v[0], v[1]))
    opt = jnp.asarray(ROSENBROCK["optimum"])

    for method, p, beta in (("diana", math.inf, 0.9),
                            ("qsgd", 2.0, 0.0),
                            ("terngrad", math.inf, 0.0)):
        cfg = CompressionConfig(method=method, p=p, block_size=4,
                                alpha=0.5 if method == "diana" else None)
        x = jnp.asarray([-0.5, 0.5, 0.0, 0.0])       # padded to 4 for packing
        state = reference_init({"v": x}, cfg, 2)
        key = jax.random.PRNGKey(0)
        for k in range(4000):
            key = jax.random.fold_in(key, k)
            grads = jnp.stack([
                jnp.concatenate([g1(x[:2]), jnp.zeros(2)]),
                jnp.concatenate([g2(x[:2]), jnp.zeros(2)]),
            ])
            v, state = reference_step({"v": grads}, state, key, cfg, beta=beta)
            x = x - 2e-3 * v["v"]
            if k % 1000 == 0:
                print(f"{method:9s} k={k:5d} x=({float(x[0]):+.3f},{float(x[1]):+.3f}) "
                      f"dist={float(jnp.linalg.norm(x[:2]-opt)):.4f}")
        print(f"{method:9s} final dist to optimum: "
              f"{float(jnp.linalg.norm(x[:2]-opt)):.5f}\n")


if __name__ == "__main__":
    main()
