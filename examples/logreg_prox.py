"""The paper's convex experiment: l1-regularized logistic regression with
DIANA + proximal steps — the setting where QSGD/TernGrad provably fail
(their quantization noise never vanishes, so the prox iterates oscillate).

Prints the objective trajectory for DIANA vs QSGD and the sparsity of the
DIANA solution (the l1 prox actually zeroes coordinates because DIANA's
direction converges).

Run:  PYTHONPATH=src python examples/logreg_prox.py
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.diana_paper import LogRegProblem
from repro.core import CompressionConfig, reference_init, reference_step
from repro.core.prox import l1
from repro.data import logreg_data


def main():
    prob = LogRegProblem(n_workers=10)
    Xs, ys = logreg_data(prob)
    X, y = jnp.asarray(Xs), jnp.asarray(ys)
    reg = l1(prob.l1)
    gamma, steps = 1.0, 600

    def worker_grads(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        sig = jax.nn.sigmoid(-z)
        return -jnp.einsum("wij,wi->wj", X, y * sig) / X.shape[1] + prob.l2 * w

    def objective(w):
        z = y * jnp.einsum("wij,j->wi", X, w)
        return float(jnp.mean(jnp.log1p(jnp.exp(-z)))
                     + 0.5 * prob.l2 * w @ w + prob.l1 * jnp.abs(w).sum())

    for method, p in (("diana", math.inf), ("qsgd", 2.0)):
        cfg = CompressionConfig(method=method, p=p, block_size=28)
        params = {"x": jnp.zeros((prob.dim,))}
        state = reference_init(params, cfg, prob.n_workers)
        key = jax.random.PRNGKey(0)
        for k in range(steps):
            key = jax.random.fold_in(key, k)
            v, state = reference_step({"x": worker_grads(params["x"])}, state, key, cfg)
            params = reg.tree_prox({"x": params["x"] - gamma * v["x"]}, gamma)
            if k % 100 == 0:
                print(f"{method:8s} step {k:4d}  obj {objective(params['x']):.6f}")
        nnz = int((jnp.abs(params["x"]) > 1e-8).sum())
        print(f"{method:8s} final obj {objective(params['x']):.6f}  "
              f"nnz {nnz}/{prob.dim}\n")


if __name__ == "__main__":
    main()
