"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
DIANA, checkpointing and loss logging — deliverable (b)'s end-to-end example.

The model is a 12-layer / d_model=768 llama-family config (~110M params with
the padded vocab head).  On this CPU container a full run takes a while; the
defaults train 300 steps at seq 256.  Compare compressors with --compression.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --compression none   # baseline
"""

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import make_lm_batch
from repro.launch.mesh import make_mesh
from repro.launch.sharding_rules import batch_specs
from repro.launch.train import build_train_step, init_train_state, make_optimizer
from repro.models import count_params


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", arch_type="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
        act="swiglu", param_dtype=jnp.float32, compute_dtype=jnp.float32,
        remat="none", comp_block=2048,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compression", default="diana",
                    choices=["diana", "qsgd", "terngrad", "none"])
    ap.add_argument("--checkpoint-dir", default="/tmp/diana_lm100m")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = replace(lm_100m(), compression=args.compression)
    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch, kind="train")
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))

    opt = make_optimizer(cfg, lr=args.lr)
    key = jax.random.PRNGKey(0)
    params, opt_state, _ = init_train_state(cfg, opt, mesh, key)
    step_fn = build_train_step(cfg, opt, mesh, shape)
    print(f"{cfg.name}: {count_params(params):,} params, "
          f"compression={args.compression}, mesh={dict(mesh.shape)}")

    t0 = time.time()
    for step in range(args.steps):
        hb = make_lm_batch(cfg, shape, step)
        bs = batch_specs(hb, mesh)
        batch = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), hb, bs)
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jax.random.fold_in(key, step))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"ghat {float(m['ghat_norm']):.3f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")

    path = save_checkpoint(args.checkpoint_dir, args.steps, {"params": params})
    print(f"saved {path}")


if __name__ == "__main__":
    main()
