"""Serving example: batched-request decode with KV/SSM caches.

Loads (or inits) a reduced model, prefixes each request with a short prompt
and decodes greedily — demonstrating the cached decode path used by the
decode_32k / long_500k dry-run shapes.  Works for any --arch, including the
attention-free mamba2 (O(1) state) and the jamba hybrid.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-130m
      PYTHONPATH=src python examples/serve_decode.py --arch llama3.2-1b --window 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.serve import build_serve_step
from repro.models import init_caches, init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--decode-tokens", type=int, default=24)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window size (ring-buffer cache)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    max_len = args.prompt_len + args.decode_tokens
    shape = ShapeConfig("serve", seq_len=max_len, global_batch=args.batch, kind="decode")
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))

    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    caches = init_caches(cfg, args.batch, max_len, window=args.window)
    step = build_serve_step(cfg, mesh, shape) if args.window is None else None

    from repro.models import decode_step

    jit_step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg, window=args.window))

    # "prompt": feed random tokens one at a time (teacher forcing)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    for t in range(args.prompt_len):
        logits, caches = jit_step(params, prompt[:, t : t + 1], caches)

    # greedy decode
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
    outs = [tok]
    t0 = time.time()
    for _ in range(args.decode_tokens - 1):
        logits, caches = jit_step(params, tok, caches)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32) % cfg.vocab
        outs.append(tok)
    dt = time.time() - t0

    seqs = jnp.concatenate(outs, axis=1)
    print(f"{cfg.name}: decoded {args.decode_tokens} tokens x {args.batch} requests "
          f"in {dt:.2f}s ({args.decode_tokens*args.batch/max(dt,1e-9):.1f} tok/s)")
    for i in range(args.batch):
        print(f"  req{i}: {list(map(int, seqs[i][:12]))} ...")


if __name__ == "__main__":
    main()
